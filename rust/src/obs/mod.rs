//! Unified telemetry: metrics registry, latency histograms, scoped
//! timers, exporters, and the distributed flight recorder.
//!
//! The paper's whole argument is an accounting exercise — per-stage
//! memory-access and compute overhead (Tables 6/7) — so the repro
//! carries a measurement backbone every layer reports into:
//!
//! * [`Metrics`] — a registry of named [`Counter`]s, [`Gauge`]s, and
//!   log-bucketed [`Hist`]ograms. Registration locks once; recording is
//!   a relaxed atomic op through an `Arc` handle, cheap enough to stay
//!   on in every serve worker and trainer epoch.
//! * [`SpanTimer`] / [`span!`](crate::span) — scoped wall-time timers
//!   recording nanoseconds into a histogram on drop.
//! * [`MetricsFile`] / [`render_text`] — the `metrics.jsonl` exporter
//!   (one flushed, `"kind"`-tagged JSON object per line) and the
//!   human-readable one-shot dump.
//! * [`FlightRecorder`] — a bounded ring of every dist `Event` /
//!   `Directive` with coordinator-tick stamps, dumped into the same
//!   JSONL file on completion or watchdog abort.
//!
//! Everything here is strictly **passive**: recording never branches
//! the computation, so trajectories are bit-identical with telemetry on
//! or off (pinned by `tests/session.rs`). The user-facing switch is
//! `--metrics FILE` on `train` and `serve` (`RunSpec.metrics`).
//!
//! Quantiles use the same nearest-rank rule as [`crate::bench::percentile`]
//! so `metrics.jsonl` p50/p95/p99 and the bench suite's numbers are
//! directly comparable (cross-checked in this module's tests).

mod export;
mod flight;
mod hist;
mod registry;

pub use export::{render_text, MetricsFile};
pub use flight::{FlightEntry, FlightRecorder, DEFAULT_FLIGHT_CAP};
pub use hist::{bucket_hi, bucket_index, bucket_lo, Hist, HistSnapshot, FIRST_BUCKETS, NUM_BUCKETS};
pub use registry::{Counter, Gauge, Metrics, MetricsSnapshot, SpanTimer};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::percentile;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    // -- bucket grid ---------------------------------------------------

    #[test]
    fn bucket_boundaries_are_exact() {
        // Every bucket's own bounds map back to it, and the grid tiles
        // u64 with no gaps or overlaps: hi(i) == lo(i+1).
        for i in 0..NUM_BUCKETS {
            let lo = bucket_lo(i);
            assert_eq!(bucket_index(lo), i, "lo of bucket {i}");
            let hi = bucket_hi(i);
            assert!(hi > lo, "bucket {i} must be non-empty");
            assert_eq!(bucket_index(hi - 1), i, "last value of bucket {i}");
            if i + 1 < NUM_BUCKETS {
                assert_eq!(hi, bucket_lo(i + 1), "gap/overlap after bucket {i}");
            }
        }
        // Extremes.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_hi(NUM_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn unit_range_is_exact() {
        for v in 0..FIRST_BUCKETS as u64 {
            assert_eq!(bucket_lo(bucket_index(v)), v);
            assert_eq!(bucket_hi(bucket_index(v)), v + 1);
        }
    }

    #[test]
    fn relative_width_bounded() {
        // Above the unit range, bucket width / lo <= 1/8 = 12.5%.
        for i in FIRST_BUCKETS..NUM_BUCKETS {
            let lo = bucket_lo(i) as f64;
            let width = (bucket_hi(i) - bucket_lo(i)) as f64;
            assert!(
                width / lo <= 0.125 + 1e-12,
                "bucket {i}: width {width} at lo {lo}"
            );
        }
    }

    // -- snapshot merge ------------------------------------------------

    fn random_snapshot(rng: &mut Pcg32) -> HistSnapshot {
        let h = Hist::new();
        let n = rng.next_u32() % 50;
        for _ in 0..n {
            // spread over several octaves
            let v = (rng.next_u32() as u64) >> (rng.next_u32() % 24);
            h.record(v);
        }
        h.snapshot()
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let mut rng = Pcg32::new(0xA11CE, 7);
        for round in 0..64 {
            let a = random_snapshot(&mut rng);
            let b = random_snapshot(&mut rng);
            let c = random_snapshot(&mut rng);

            // (a + b) + c
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ab_c = ab.clone();
            ab_c.merge(&c);

            // a + (b + c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);

            assert_eq!(ab_c, a_bc, "associativity failed on round {round}");

            // a + b == b + a
            let mut ba = b.clone();
            ba.merge(&a);
            assert_eq!(ab, ba, "commutativity failed on round {round}");

            // empty is the identity
            let mut a_id = a.clone();
            a_id.merge(&HistSnapshot::empty());
            assert_eq!(a_id, a, "identity failed on round {round}");

            // counts and sums add exactly
            assert_eq!(ab.count(), a.count() + b.count());
            assert_eq!(ab.sum, a.sum + b.sum);
        }
    }

    // -- concurrent recording -------------------------------------------

    #[test]
    fn concurrent_records_are_all_counted() {
        let h = Arc::new(Hist::new());
        let threads = 8;
        let per_thread = 10_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Pcg32::new(42, t as u64);
                    let mut local_sum = 0u64;
                    for _ in 0..per_thread {
                        let v = (rng.next_u32() % 100_000) as u64;
                        h.record(v);
                        local_sum += v;
                    }
                    local_sum
                })
            })
            .collect();
        let expect_sum: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let snap = h.snapshot();
        assert_eq!(snap.count(), threads as u64 * per_thread);
        assert_eq!(snap.sum, expect_sum);
    }

    // -- quantiles vs bench::percentile ---------------------------------

    #[test]
    fn quantiles_agree_with_bench_percentile() {
        let mut rng = Pcg32::new(0xBEEF, 3);
        for n in [1usize, 2, 3, 10, 100, 1000] {
            let h = Hist::new();
            let mut sample: Vec<f64> = Vec::with_capacity(n);
            for _ in 0..n {
                let v = ((rng.next_u32() as u64) >> (rng.next_u32() % 20)) + 1;
                h.record(v);
                sample.push(v as f64);
            }
            let snap = h.snapshot();
            for p in [0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                let exact = percentile(&mut sample, p) as u64;
                // The histogram reports the lower bound of the bucket the
                // exact nearest-rank percentile falls in — same rank rule,
                // bucketed value.
                assert_eq!(
                    snap.quantile(p),
                    bucket_lo(bucket_index(exact)),
                    "n={n} p={p} exact={exact}"
                );
            }
        }
    }

    #[test]
    fn quantiles_are_monotone() {
        let mut rng = Pcg32::new(99, 1);
        let h = Hist::new();
        for _ in 0..500 {
            h.record((rng.next_u32() % 1_000_000) as u64);
        }
        let snap = h.snapshot();
        let (p50, p95, p99) = (
            snap.quantile(50.0),
            snap.quantile(95.0),
            snap.quantile(99.0),
        );
        assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");
    }

    #[test]
    fn empty_hist_quantile_is_zero() {
        assert_eq!(Hist::new().snapshot().quantile(50.0), 0);
        assert_eq!(Hist::new().snapshot().mean(), 0.0);
    }

    // -- registry --------------------------------------------------------

    #[test]
    fn registry_handles_share_state() {
        let m = Metrics::new();
        m.counter("a.hits").add(3);
        m.counter("a.hits").inc(); // same instrument, second handle
        m.gauge("a.depth").set(7);
        m.hist("a.lat").record(12);
        let snap = m.snapshot();
        assert_eq!(snap.counters["a.hits"], 4);
        assert_eq!(snap.gauges["a.depth"], 7);
        assert_eq!(snap.hists["a.lat"].count(), 1);
    }

    #[test]
    fn snapshot_merge_and_json_roundtrip_shape() {
        let m = Metrics::new();
        m.counter("x").add(2);
        m.hist("h").record(100);
        let mut a = m.snapshot();
        let b = m.snapshot();
        a.merge(&b);
        assert_eq!(a.counters["x"], 4);
        assert_eq!(a.hists["h"].count(), 2);
        // JSON dump parses back and has the three sections
        let j = crate::util::json::Json::parse(&a.to_json().dump()).unwrap();
        assert!(j.get("counters").is_some());
        assert!(j.get("gauges").is_some());
        assert!(j.get("hists").is_some());
        assert_eq!(
            j.get("hists").unwrap().get("h").unwrap().get("count"),
            Some(&crate::util::json::Json::Num(2.0))
        );
    }

    #[test]
    fn snapshot_json_roundtrips_losslessly() {
        let m = Metrics::new();
        m.counter("serve.net.requests").add(17);
        m.gauge("serve.net.queue_depth").set(-3);
        let h = m.hist("serve.net.latency.predict");
        let mut rng = Pcg32::new(0xD0C, 5);
        for _ in 0..200 {
            h.record(((rng.next_u32() as u64) >> (rng.next_u32() % 20)) + 1);
        }
        let snap = m.snapshot();
        let wire = snap.to_json().dump();
        let back =
            MetricsSnapshot::from_json(&crate::util::json::Json::parse(&wire).unwrap()).unwrap();
        // full structural equality: counters, gauges, and dense hist
        // tables all survive the sparse wire form
        assert_eq!(back, snap);
        assert_eq!(
            back.hists["serve.net.latency.predict"].quantile(99.0),
            snap.hists["serve.net.latency.predict"].quantile(99.0)
        );
        // a tampered (non-canonical) bucket bound is rejected, not
        // silently rebinned: 17 lies inside bucket [16, 18)
        let bad = wire.replace("\"buckets\":[[", "\"buckets\":[[17,1],[");
        let parsed = crate::util::json::Json::parse(&bad).unwrap();
        assert!(MetricsSnapshot::from_json(&parsed).is_err());
    }

    #[test]
    fn span_timer_records_on_drop() {
        let m = Metrics::new();
        {
            let _t = SpanTimer::new(m.hist("t"));
            std::thread::yield_now();
        }
        assert_eq!(m.snapshot().hists["t"].count(), 1);
    }

    // -- flight recorder -------------------------------------------------

    #[test]
    fn flight_ring_bounds_and_sequences() {
        let r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.record(i, "event", crate::util::json::num(i as f64));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        let e = r.entries();
        let seqs: Vec<u64> = e.iter().map(|x| x.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        let dump = r.to_jsonl();
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 5); // head + 4 entries
        let head = crate::util::json::Json::parse(lines[0]).unwrap();
        assert_eq!(head.get("kind").unwrap().as_str(), Some("flight_head"));
        assert_eq!(head.get("dropped").unwrap().as_usize(), Some(6));
        for l in &lines[1..] {
            let j = crate::util::json::Json::parse(l).unwrap();
            assert_eq!(j.get("kind").unwrap().as_str(), Some("flight"));
            assert!(j.get("tick").is_some() && j.get("role").is_some());
        }
    }

    // -- text dump -------------------------------------------------------

    #[test]
    fn text_dump_mentions_every_instrument() {
        let m = Metrics::new();
        m.counter("serve.requests").add(5);
        m.gauge("serve.queue_depth").set(2);
        m.hist("serve.latency.predict").record(1234);
        let text = render_text(&m.snapshot());
        for needle in ["serve.requests", "serve.queue_depth", "serve.latency.predict"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
        assert!(render_text(&MetricsSnapshot::default()).contains("no metrics"));
    }
}
