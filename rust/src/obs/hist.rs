//! Fixed log-bucketed histogram with lock-free recording.
//!
//! The bucket layout is an HDR-style log-linear grid over `u64`:
//!
//! * values `0..8` get exact unit buckets (`FIRST_BUCKETS`), so tiny
//!   counts (batch sizes, queue depths) are never smeared;
//! * every octave `[2^k, 2^(k+1))` for `k >= 3` is split into 8 linear
//!   sub-buckets, bounding the relative bucket width at 12.5%.
//!
//! That yields `8 + 61*8 = 496` buckets covering the full `u64` range
//! with a fixed-size table, so [`Hist::record`] is two relaxed atomic
//! adds — no allocation, no locks, safe to call from every serve worker
//! and trainer thread concurrently.
//!
//! [`HistSnapshot`] is the frozen view: mergeable across workers
//! (bucket-wise addition, associative + commutative) and queryable for
//! nearest-rank quantiles with the same rank rule as
//! [`crate::bench::percentile`], which the tests cross-check.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::{arr, num, obj, Json};

/// Number of exact unit buckets for values `0..FIRST_BUCKETS`.
pub const FIRST_BUCKETS: usize = 8;

/// Sub-buckets per octave above the unit range (2^3 = 8).
const SUB_PER_OCT: u64 = 8;

/// Total bucket count: 8 unit buckets + octaves 3..=63, 8 sub-buckets each.
pub const NUM_BUCKETS: usize = FIRST_BUCKETS + 61 * SUB_PER_OCT as usize;

/// The bucket index a value lands in.
pub fn bucket_index(v: u64) -> usize {
    if v < FIRST_BUCKETS as u64 {
        return v as usize;
    }
    let oct = 63 - v.leading_zeros() as u64; // >= 3 since v >= 8
    let sub = (v >> (oct - 3)) & (SUB_PER_OCT - 1);
    (FIRST_BUCKETS as u64 + (oct - 3) * SUB_PER_OCT + sub) as usize
}

/// Inclusive lower bound of bucket `i` — the canonical value a quantile
/// query reports for a sample that landed in this bucket.
pub fn bucket_lo(i: usize) -> u64 {
    assert!(i < NUM_BUCKETS, "bucket index {i} out of range");
    if i < FIRST_BUCKETS {
        return i as u64;
    }
    let k = (i - FIRST_BUCKETS) as u64;
    let oct = k / SUB_PER_OCT + 3;
    let sub = k % SUB_PER_OCT;
    (1u64 << oct) + (sub << (oct - 3))
}

/// Exclusive upper bound of bucket `i` (saturating: the top bucket's
/// bound is `u64::MAX` since `2^64` is unrepresentable).
pub fn bucket_hi(i: usize) -> u64 {
    assert!(i < NUM_BUCKETS, "bucket index {i} out of range");
    if i < FIRST_BUCKETS {
        return i as u64 + 1;
    }
    let k = (i - FIRST_BUCKETS) as u64;
    let oct = k / SUB_PER_OCT + 3;
    bucket_lo(i).saturating_add(1u64 << (oct - 3))
}

/// A concurrent log-bucketed histogram.
///
/// `record` is lock-free (relaxed atomics); readers take a point-in-time
/// [`snapshot`](Hist::snapshot) which, under concurrent recording, is
/// consistent per bucket but may be mid-update across buckets — fine for
/// monitoring, which is all this is for.
pub struct Hist {
    buckets: Box<[AtomicU64]>,
    sum: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    /// A fresh, empty histogram.
    pub fn new() -> Self {
        let buckets: Vec<AtomicU64> = (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Hist {
            buckets: buckets.into_boxed_slice(),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Freeze the current contents into a mergeable snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Debug for Hist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let snap = self.snapshot();
        f.debug_struct("Hist")
            .field("count", &snap.count())
            .field("sum", &snap.sum)
            .finish()
    }
}

/// A frozen histogram: plain bucket counts, mergeable and queryable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket observation counts (`NUM_BUCKETS` entries).
    pub counts: Vec<u64>,
    /// Sum of all recorded values (for the mean).
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl HistSnapshot {
    /// An empty snapshot (identity element for [`merge`](Self::merge)).
    pub fn empty() -> Self {
        HistSnapshot {
            counts: vec![0; NUM_BUCKETS],
            sum: 0,
        }
    }

    /// Total number of recorded observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Mean of the recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum as f64 / n as f64
        }
    }

    /// Fold another snapshot in (bucket-wise add — associative and
    /// commutative, so per-worker snapshots merge in any order).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum += other.sum;
    }

    /// Nearest-rank quantile, `p` in percent.
    ///
    /// Uses the same rank rule as [`crate::bench::percentile`]
    /// (`rank = round(p/100 * (n-1))` over the sorted sample) and
    /// reports the lower bound of the bucket holding that rank, so for
    /// any sample the result equals
    /// `bucket_lo(bucket_index(percentile_of_sample))` exactly.
    /// Returns 0 for an empty snapshot.
    pub fn quantile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = (p.clamp(0.0, 100.0) / 100.0 * (n - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_lo(i);
            }
        }
        // unreachable for rank < n, but stay total
        bucket_lo(NUM_BUCKETS - 1)
    }

    /// JSON form: summary stats plus the non-empty buckets as
    /// `[lo, count]` pairs (sparse — most of the 496 buckets are zero).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| arr(vec![num(bucket_lo(i) as f64), num(c as f64)]))
            .collect();
        obj(vec![
            ("count", num(self.count() as f64)),
            ("sum", num(self.sum as f64)),
            ("mean", num(self.mean())),
            ("p50", num(self.quantile(50.0) as f64)),
            ("p95", num(self.quantile(95.0) as f64)),
            ("p99", num(self.quantile(99.0) as f64)),
            ("buckets", arr(buckets)),
        ])
    }

    /// Decode the [`HistSnapshot::to_json`] form back into a snapshot.
    ///
    /// Bucket lower bounds are canonical (`bucket_lo` of the bucket a
    /// sample landed in), so `bucket_index(lo)` recovers the dense table
    /// exactly and `to_json -> from_json` round-trips losslessly — this is
    /// how `query --connect --stats` turns a wire reply back into a
    /// queryable snapshot.
    pub fn from_json(v: &Json) -> Result<HistSnapshot, String> {
        let sum = v
            .get("sum")
            .and_then(Json::as_usize)
            .ok_or("hist missing sum")? as u64;
        let mut counts = vec![0u64; NUM_BUCKETS];
        let buckets = v
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or("hist missing buckets")?;
        for b in buckets {
            let pair = b.as_arr().ok_or("hist bucket is not a pair")?;
            let (lo, c) = match pair {
                [lo, c] => (
                    lo.as_usize().ok_or("hist bucket lo not an integer")? as u64,
                    c.as_usize().ok_or("hist bucket count not an integer")? as u64,
                ),
                _ => return Err("hist bucket is not a [lo, count] pair".into()),
            };
            let i = bucket_index(lo);
            if bucket_lo(i) != lo {
                return Err(format!("hist bucket lower bound {lo} is not canonical"));
            }
            counts[i] += c;
        }
        let snap = HistSnapshot { counts, sum };
        if let Some(want) = v.get("count").and_then(Json::as_usize) {
            if snap.count() != want as u64 {
                return Err(format!(
                    "hist count mismatch: header {} vs buckets {}",
                    want,
                    snap.count()
                ));
            }
        }
        Ok(snap)
    }
}
