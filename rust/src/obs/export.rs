//! Exporters: the `metrics.jsonl` file sink and the one-shot text dump.
//!
//! The JSONL file is append-only, one self-describing object per line,
//! tagged by `"kind"`:
//!
//! * `{"kind":"metrics","scope":..,"seq":..,"elapsed_ms":..,
//!   "counters":{..},"gauges":{..},"hists":{..}}` — a registry
//!   snapshot (periodic: per epoch for train, post-burst for serve);
//! * `{"kind":"flight_head",..}` / `{"kind":"flight",..}` — the dist
//!   flight-recorder dump (see [`super::FlightRecorder::to_jsonl`]).
//!
//! Every write flushes, so the file survives a watchdog abort or panic
//! mid-run — the whole point of a flight recorder.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};

use super::flight::FlightRecorder;
use super::registry::MetricsSnapshot;

/// An open `metrics.jsonl` sink.
#[derive(Debug)]
pub struct MetricsFile {
    out: BufWriter<File>,
    seq: u64,
    t0: Instant,
}

impl MetricsFile {
    /// Create (truncate) the metrics file at `path`.
    pub fn create(path: &Path) -> io::Result<MetricsFile> {
        Ok(MetricsFile {
            out: BufWriter::new(File::create(path)?),
            seq: 0,
            t0: Instant::now(),
        })
    }

    fn write_line(&mut self, line: &Json) -> io::Result<()> {
        self.out.write_all(line.dump().as_bytes())?;
        self.out.write_all(b"\n")?;
        self.out.flush()
    }

    /// Append one registry snapshot line. `scope` names the emitting
    /// layer/moment (e.g. `"epoch"`, `"serve"`, `"final"`).
    pub fn write_snapshot(&mut self, scope: &str, snap: &MetricsSnapshot) -> io::Result<()> {
        let seq = self.seq;
        self.seq += 1;
        let mut fields = vec![
            ("kind", s("metrics")),
            ("scope", s(scope)),
            ("seq", num(seq as f64)),
            ("elapsed_ms", num(self.t0.elapsed().as_millis() as f64)),
        ];
        match snap.to_json() {
            Json::Obj(m) => {
                let mut line: Vec<(&str, Json)> = Vec::new();
                line.append(&mut fields);
                for (k, v) in &m {
                    match k.as_str() {
                        "counters" => line.push(("counters", v.clone())),
                        "gauges" => line.push(("gauges", v.clone())),
                        "hists" => line.push(("hists", v.clone())),
                        _ => {}
                    }
                }
                self.write_line(&obj(line))
            }
            other => self.write_line(&other),
        }
    }

    /// Append the flight-recorder tape (header + one line per entry).
    pub fn write_flight(&mut self, rec: &FlightRecorder) -> io::Result<()> {
        self.out.write_all(rec.to_jsonl().as_bytes())?;
        self.out.flush()
    }
}

/// Render a snapshot as a human-readable text table — the one-shot dump
/// printed at the end of a `--metrics` serve run.
pub fn render_text(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    if !snap.counters.is_empty() {
        out.push_str("counters:\n");
        for (k, v) in &snap.counters {
            out.push_str(&format!("  {k:<28} {v}\n"));
        }
    }
    if !snap.gauges.is_empty() {
        out.push_str("gauges:\n");
        for (k, v) in &snap.gauges {
            out.push_str(&format!("  {k:<28} {v}\n"));
        }
    }
    if !snap.hists.is_empty() {
        out.push_str("histograms:\n");
        for (k, h) in &snap.hists {
            out.push_str(&format!(
                "  {k:<28} n={} mean={:.1} p50={} p95={} p99={}\n",
                h.count(),
                h.mean(),
                h.quantile(50.0),
                h.quantile(95.0),
                h.quantile(99.0),
            ));
        }
    }
    if out.is_empty() {
        out.push_str("(no metrics recorded)\n");
    }
    out
}
