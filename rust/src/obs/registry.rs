//! The metrics registry: named counters, gauges and histograms.
//!
//! Registration (`counter`/`gauge`/`hist`) takes a short mutex once and
//! hands back an `Arc` handle; the hot path — incrementing through the
//! handle — is a single relaxed atomic op. Call sites register once up
//! front (e.g. [`crate::serve::Server`] pre-registers its per-request
//! latency histograms) and record lock-free forever after.
//!
//! [`Metrics::snapshot`] freezes everything into a
//! [`MetricsSnapshot`]: plain `BTreeMap`s, mergeable across workers and
//! serializable through [`crate::util::json`] for the `metrics.jsonl`
//! exporter and the serve `Request::Stats` reply.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{obj, Json};

use super::hist::{Hist, HistSnapshot};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// An instantaneous signed value (queue depth, live worker count).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Adjust by a signed delta.
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
struct Tables {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    hists: BTreeMap<String, Arc<Hist>>,
}

/// The registry. Cheap to share (`Arc<Metrics>`); all instruments
/// registered through it appear in every snapshot under their name.
///
/// Names are dotted paths, `layer.instrument` — see ARCHITECTURE.md
/// §Observability for the catalog used across train/serve/data/dist.
#[derive(Default)]
pub struct Metrics {
    tables: Mutex<Tables>,
}

impl Metrics {
    /// A fresh, empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// A fresh registry behind an `Arc`, ready to share across threads.
    pub fn shared() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    /// Get or register the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut t = self.tables.lock().unwrap();
        t.counters.entry(name.to_string()).or_default().clone()
    }

    /// Get or register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut t = self.tables.lock().unwrap();
        t.gauges.entry(name.to_string()).or_default().clone()
    }

    /// Get or register the histogram `name`.
    pub fn hist(&self, name: &str) -> Arc<Hist> {
        let mut t = self.tables.lock().unwrap();
        t.hists
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Hist::new()))
            .clone()
    }

    /// Freeze every registered instrument into a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let t = self.tables.lock().unwrap();
        MetricsSnapshot {
            counters: t
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: t.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            hists: t
                .hists
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

impl std::fmt::Debug for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t = self.tables.lock().unwrap();
        f.debug_struct("Metrics")
            .field("counters", &t.counters.len())
            .field("gauges", &t.gauges.len())
            .field("hists", &t.hists.len())
            .finish()
    }
}

/// A frozen view of a [`Metrics`] registry: plain maps, mergeable and
/// JSON-serializable. This is what crosses the serve protocol in
/// `Response::Stats` and what the exporter writes per line.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, i64>,
    /// Histogram snapshots by name.
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl MetricsSnapshot {
    /// True when nothing has been registered or recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    /// Fold another snapshot in: counters and histogram buckets add,
    /// gauges take the other side's value (last write wins).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.hists {
            self.hists
                .entry(k.clone())
                .or_insert_with(HistSnapshot::empty)
                .merge(v);
        }
    }

    /// JSON object with `counters` / `gauges` / `hists` sub-objects.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        );
        obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("hists", hists),
        ])
    }

    /// Decode the [`MetricsSnapshot::to_json`] form (lossless inverse —
    /// what the serve wire protocol's `stats` reply is parsed with).
    pub fn from_json(v: &Json) -> Result<MetricsSnapshot, String> {
        let section = |key: &str| -> Result<&BTreeMap<String, Json>, String> {
            match v.get(key) {
                Some(Json::Obj(m)) => Ok(m),
                _ => Err(format!("metrics snapshot missing {key:?} object")),
            }
        };
        let mut snap = MetricsSnapshot::default();
        for (k, j) in section("counters")? {
            let n = j
                .as_usize()
                .ok_or_else(|| format!("counter {k:?} is not a non-negative integer"))?;
            snap.counters.insert(k.clone(), n as u64);
        }
        for (k, j) in section("gauges")? {
            let n = match j.as_f64() {
                Some(f) if f.fract() == 0.0 && f.abs() < 9e15 => f as i64,
                _ => return Err(format!("gauge {k:?} is not an integer")),
            };
            snap.gauges.insert(k.clone(), n);
        }
        for (k, j) in section("hists")? {
            let h = HistSnapshot::from_json(j).map_err(|e| format!("hist {k:?}: {e}"))?;
            snap.hists.insert(k.clone(), h);
        }
        Ok(snap)
    }
}

/// A scoped timer: records the elapsed wall time into a histogram (in
/// nanoseconds) when dropped. Create one at the top of the region to
/// measure — the `span!` macro is sugar for exactly this.
#[derive(Debug)]
pub struct SpanTimer {
    hist: Arc<Hist>,
    start: Instant,
}

impl SpanTimer {
    /// Start timing into `hist`.
    pub fn new(hist: Arc<Hist>) -> SpanTimer {
        SpanTimer {
            hist,
            start: Instant::now(),
        }
    }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        self.hist.record_duration(self.start.elapsed());
    }
}

/// Time the rest of the enclosing scope into a histogram.
///
/// ```no_run
/// use fasttucker::obs::Metrics;
/// let m = Metrics::new();
/// {
///     let _t = fasttucker::span!(m.hist("serve.latency.predict"));
///     // ... work measured until end of scope ...
/// }
/// ```
#[macro_export]
macro_rules! span {
    ($hist:expr) => {
        $crate::obs::SpanTimer::new($hist)
    };
}
