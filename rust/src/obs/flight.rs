//! The distributed flight recorder: a bounded ring buffer of every
//! protocol message the coordinator saw or issued, stamped with the
//! coordinator tick, dumpable as JSONL after a clean finish or a
//! watchdog abort. This is the post-hoc story for fault-injection runs:
//! when a worker is evicted, the `Evict` directive and the heartbeat
//! silence leading up to it are all on tape.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::util::json::{num, obj, s, Json};

/// One recorded protocol message.
#[derive(Clone, Debug)]
pub struct FlightEntry {
    /// Global sequence number (monotone, never reused — gaps after
    /// `dropped > 0` show exactly how much tape was lost).
    pub seq: u64,
    /// Coordinator tick count when the entry was recorded.
    pub tick: u64,
    /// `"event"` (worker → coordinator) or `"directive"` (coordinator →
    /// workers).
    pub role: &'static str,
    /// The message body, as the protocol type's own `to_json` form.
    pub body: Json,
}

impl FlightEntry {
    /// The JSONL line form: `{"kind":"flight","seq":..,"tick":..,...}`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", s("flight")),
            ("seq", num(self.seq as f64)),
            ("tick", num(self.tick as f64)),
            ("role", s(self.role)),
            ("body", self.body.clone()),
        ])
    }
}

struct Tape {
    next_seq: u64,
    dropped: u64,
    ring: VecDeque<FlightEntry>,
}

/// A bounded ring buffer of [`FlightEntry`]s. When full, the oldest
/// entry is dropped (and counted), so memory stays constant no matter
/// how long the run is while the most recent window — the part that
/// explains an abort — is always retained.
pub struct FlightRecorder {
    cap: usize,
    tape: Mutex<Tape>,
}

/// Default ring capacity — generous for an epoch-scale window at
/// dist protocol rates (a handful of messages per worker per round).
pub const DEFAULT_FLIGHT_CAP: usize = 4096;

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new(DEFAULT_FLIGHT_CAP)
    }
}

impl FlightRecorder {
    /// A recorder retaining at most `cap` entries (min 1).
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            cap: cap.max(1),
            tape: Mutex::new(Tape {
                next_seq: 0,
                dropped: 0,
                ring: VecDeque::new(),
            }),
        }
    }

    /// Append one message to the tape.
    pub fn record(&self, tick: u64, role: &'static str, body: Json) {
        let mut t = self.tape.lock().unwrap();
        let seq = t.next_seq;
        t.next_seq += 1;
        if t.ring.len() == self.cap {
            t.ring.pop_front();
            t.dropped += 1;
        }
        t.ring.push_back(FlightEntry {
            seq,
            tick,
            role,
            body,
        });
    }

    /// Number of entries currently on tape.
    pub fn len(&self) -> usize {
        self.tape.lock().unwrap().ring.len()
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many old entries the ring has evicted to stay bounded.
    pub fn dropped(&self) -> u64 {
        self.tape.lock().unwrap().dropped
    }

    /// Clone out the retained entries, oldest first.
    pub fn entries(&self) -> Vec<FlightEntry> {
        self.tape.lock().unwrap().ring.iter().cloned().collect()
    }

    /// Dump the tape as JSONL: one `{"kind":"flight",...}` line per
    /// entry, preceded by a `{"kind":"flight_head",...}` header line
    /// carrying the drop count so truncation is self-describing.
    pub fn to_jsonl(&self) -> String {
        let t = self.tape.lock().unwrap();
        let mut out = String::new();
        let head = obj(vec![
            ("kind", s("flight_head")),
            ("retained", num(t.ring.len() as f64)),
            ("dropped", num(t.dropped as f64)),
        ]);
        out.push_str(&head.dump());
        out.push('\n');
        for e in &t.ring {
            out.push_str(&e.to_json().dump());
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t = self.tape.lock().unwrap();
        f.debug_struct("FlightRecorder")
            .field("cap", &self.cap)
            .field("retained", &t.ring.len())
            .field("dropped", &t.dropped)
            .finish()
    }
}
