//! Seeded deterministic shard assignment: which member trains which
//! sections each round.
//!
//! Properties (pinned by `tests/dist.rs`):
//!
//! * **disjoint + covering** — every section id in `0..n_sections`
//!   appears in exactly one member's list;
//! * **reproducible** — the assignment is a pure function of
//!   `(seed, round, n_sections, membership set)`;
//! * **join-order invariant** — members are sorted by id before dealing,
//!   so the order they joined (or the order a caller lists them) never
//!   changes who gets what;
//! * **balanced** — member shard sizes differ by at most one section.
//!
//! The permutation depends only on `(seed, round)`, so consecutive rounds
//! re-deal the sections (every member eventually sees every region of the
//! tensor — the distributed analog of the serial trainer's per-epoch
//! reshuffle), while a membership change mid-run only moves the chunk
//! boundaries.

use crate::dist::event::{MemberId, ShardAssignment};
use crate::util::rng::Pcg32;

/// Pcg32 stream tag for assignment shuffles (mixed with the round so each
/// round permutes differently, mirroring the sampler's `0x0731 ^ epoch`
/// convention).
const ASSIGN_STREAM: u64 = 0xD157_0000;

/// Deal `0..n_sections` to `members` for `round`.  Duplicate member ids
/// are collapsed; an empty member list yields an empty assignment (the
/// coordinator never asks for one — it finishes the run instead).
pub fn assign(seed: u64, round: u64, n_sections: u32, members: &[MemberId]) -> ShardAssignment {
    let mut ids: Vec<MemberId> = members.to_vec();
    ids.sort_unstable();
    ids.dedup();

    let mut sections: Vec<u32> = (0..n_sections).collect();
    let mut rng = Pcg32::new(seed, ASSIGN_STREAM ^ round);
    rng.shuffle(&mut sections);

    let mut shards: Vec<(MemberId, Vec<u32>)> = Vec::with_capacity(ids.len());
    if ids.is_empty() {
        return ShardAssignment {
            round,
            n_sections,
            shards,
        };
    }
    // contiguous chunks of the permuted list; the first `extra` members
    // take one section more so sizes differ by at most one
    let base = sections.len() / ids.len();
    let extra = sections.len() % ids.len();
    let mut at = 0usize;
    for (k, &member) in ids.iter().enumerate() {
        let take = base + usize::from(k < extra);
        let mut own: Vec<u32> = sections[at..at + take].to_vec();
        at += take;
        // sorted section ids keep each member's entry ranges ascending,
        // which ShardView requires and which makes assignments canonical
        own.sort_unstable();
        shards.push((member, own));
    }
    ShardAssignment {
        round,
        n_sections,
        shards,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_disjoint_balanced() {
        let a = assign(7, 0, 13, &[10, 20, 30]);
        let mut seen: Vec<u32> = a.shards.iter().flat_map(|(_, s)| s.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..13).collect::<Vec<u32>>());
        for (_, s) in &a.shards {
            assert!(s.len() == 4 || s.len() == 5);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted sections");
        }
    }

    #[test]
    fn join_order_and_duplicates_do_not_matter() {
        let a = assign(7, 3, 20, &[3, 1, 2]);
        let b = assign(7, 3, 20, &[2, 3, 1, 1, 2]);
        assert_eq!(a, b);
    }

    #[test]
    fn rounds_redeal() {
        let a = assign(7, 0, 64, &[1, 2]);
        let b = assign(7, 1, 64, &[1, 2]);
        assert_ne!(a.shards, b.shards, "round should reshuffle the deal");
    }

    #[test]
    fn degenerate_shapes() {
        // more members than sections: someone gets nothing
        let a = assign(1, 0, 2, &[1, 2, 3]);
        assert_eq!(a.shards.iter().filter(|(_, s)| s.is_empty()).count(), 1);
        // no members
        assert!(assign(1, 0, 4, &[]).shards.is_empty());
        // single member takes everything
        let a = assign(9, 5, 6, &[42]);
        assert_eq!(a.shards.len(), 1);
        assert_eq!(a.shards[0].1, (0..6).collect::<Vec<u32>>());
    }
}
