//! The TCP backend: the same distributed protocol as [`crate::dist::local`]
//! with sockets in place of channels.
//!
//! One **coordinator process** (`train --coordinator LISTEN --workers N`)
//! binds a listener, drives the pure [`Coordinator`] state machine from
//! the same wall-clock→tick mapping as the channel backend
//! ([`crate::dist::local::TICK_MS`] ms per tick, stall credit clamped),
//! and runs the shared
//! barrier driver ([`crate::dist::driver`]).  N **worker processes**
//! (`train --join ADDR [--store data.ftb2]`) connect, train their dealt
//! sections each round, and ship models back.
//!
//! ## Wire grammar
//!
//! The control stream is newline-delimited JSON frames of the *existing*
//! protocol vocabulary — [`Event`] lines worker→coordinator,
//! [`Directive`] lines coordinator→worker — with the
//! [`crate::serve::net::frame`] framing discipline (single writer per
//! socket, length-sane line reader).  Three wire-level extensions:
//!
//! * **Handshake**: the worker's first frame is `join` with `member: 0`
//!   ("assign me") and a `proto` field; the coordinator assigns the next
//!   member id (1-based, accept order) and answers a `welcome` frame
//!   carrying the id, the section geometry, and the full
//!   [`RunSpec`] JSON — one source of truth for training config.
//! * **Model payloads**: a `begin_round` directive line is immediately
//!   followed by a binary payload frame (`u64` length, `u64` FNV-1a
//!   checksum, then FTM1 model bytes — exactly the checkpoint encoding);
//!   a `step_complete` event line is likewise followed by the worker's
//!   updated model.  FTM1 bytes preserve every f32 bit pattern, so the
//!   1-worker TCP run stays byte-identical to the serial trainer.
//! * **Extension fields**: `begin_round` lines carry `hyper` (the
//!   current learning rates, so decay reaches every process) and
//!   `step_complete` lines carry `stats` (the phase timings the barrier
//!   aggregates).  [`Event::from_json`]/[`Directive::from_json`] ignore
//!   unknown fields, so the vocabulary types are unchanged.
//!
//! ## Liveness
//!
//! Heartbeat eviction is unchanged: workers heartbeat every
//! [`HEARTBEAT_MS`] and the coordinator evicts after 60 ticks
//! (~300 ms) of silence, exactly as in the channel backend.  On top of
//! that both ends bound their socket reads: a worker uses the serving
//! client's timeout mechanism ([`DEFAULT_TIMEOUT`], `--timeout-ms`) so a
//! dead coordinator can't wedge it, and the coordinator drops any
//! connection silent for [`READ_IDLE`] (a live worker is never silent —
//! heartbeats flow constantly).  An evicted worker's socket is shut
//! down; the worker sees EOF and exits loudly.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::thread::Scope;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::{EpochStats, PhaseStats, Trainer};
use crate::cpu_ref::Hyper;
use crate::data::{ShardView, TensorView};
use crate::dist::coordinator::Coordinator;
use crate::dist::driver::{resolve_dist_data, RoundDriver};
use crate::dist::event::{Directive, DistConfig, Event, MemberId};
use crate::dist::local::{DistRun, DistTelemetry, PASS_CREDIT_MAX, TICK, WATCHDOG_S};
use crate::dist::worker::{Fault, RoundResult, HEARTBEAT_MS};
use crate::model::TuckerModel;
use crate::serve::net::client::DEFAULT_TIMEOUT;
use crate::serve::net::frame::{read_line_bounded, read_payload, FrameWriter};
use crate::session::{DataSource, Observer, RunSpec};
use crate::util::json::{self, Json};

/// Control frames (including the spec-bearing welcome and the full
/// shard assignment) larger than this are a protocol violation.
const MAX_CONTROL_FRAME: usize = 1 << 20;

/// Model payload bound — a hostile length prefix is rejected before any
/// allocation happens.
const MAX_MODEL_BYTES: usize = 1 << 30;

/// Wire protocol version spoken by this build.
const PROTO: u64 = 1;

/// Coordinator-side idle bound per connection: a live worker heartbeats
/// every [`HEARTBEAT_MS`], so a socket with no frame for this long is
/// dead (its member was evicted ~300 ms into the silence) and gets
/// dropped.
const READ_IDLE: Duration = Duration::from_secs(10);

// ======================================================================
// Wire helpers (extension fields on the Event/Directive lines)
// ======================================================================

/// Append one extension field to an encoded frame object.
fn with_field(mut frame: Json, key: &str, value: Json) -> Json {
    if let Json::Obj(m) = &mut frame {
        m.insert(key.to_string(), value);
    }
    frame
}

fn hyper_json(h: &Hyper) -> Json {
    // f32 → f64 widening is exact, and the emitter prints the shortest
    // round-tripping decimal, so learning rates cross bit-identically
    json::obj(vec![
        ("lr_a", json::num(h.lr_a as f64)),
        ("lr_b", json::num(h.lr_b as f64)),
        ("lam_a", json::num(h.lam_a as f64)),
        ("lam_b", json::num(h.lam_b as f64)),
    ])
}

fn f32_field(v: &Json, key: &str) -> Result<f32> {
    v.get(key)
        .and_then(Json::as_f64)
        .map(|x| x as f32)
        .ok_or_else(|| anyhow!("missing numeric field {key:?}"))
}

fn hyper_from_json(v: &Json) -> Result<Hyper> {
    Ok(Hyper {
        lr_a: f32_field(v, "lr_a")?,
        lr_b: f32_field(v, "lr_b")?,
        lam_a: f32_field(v, "lam_a")?,
        lam_b: f32_field(v, "lam_b")?,
    })
}

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Json::as_usize)
        .map(|x| x as u64)
        .ok_or_else(|| anyhow!("missing integer field {key:?}"))
}

fn phase_json(p: &PhaseStats) -> Json {
    json::obj(vec![
        ("sample_ns", json::num(p.sample.as_nanos() as f64)),
        ("gather_ns", json::num(p.gather.as_nanos() as f64)),
        ("exec_ns", json::num(p.exec.as_nanos() as f64)),
        ("scatter_ns", json::num(p.scatter.as_nanos() as f64)),
        ("precompute_ns", json::num(p.precompute.as_nanos() as f64)),
        ("blocks", json::num(p.blocks as f64)),
        ("samples", json::num(p.samples as f64)),
        ("padded_slots", json::num(p.padded_slots as f64)),
        ("inv_hits", json::num(p.inv_hits as f64)),
        ("inv_misses", json::num(p.inv_misses as f64)),
    ])
}

fn phase_from_json(v: &Json) -> Result<PhaseStats> {
    let ns = |key| u64_field(v, key).map(Duration::from_nanos);
    Ok(PhaseStats {
        sample: ns("sample_ns")?,
        gather: ns("gather_ns")?,
        exec: ns("exec_ns")?,
        scatter: ns("scatter_ns")?,
        precompute: ns("precompute_ns")?,
        blocks: u64_field(v, "blocks")? as usize,
        samples: u64_field(v, "samples")? as usize,
        padded_slots: u64_field(v, "padded_slots")? as usize,
        inv_hits: u64_field(v, "inv_hits")?,
        inv_misses: u64_field(v, "inv_misses")?,
    })
}

fn stats_json(s: &EpochStats) -> Json {
    json::obj(vec![
        ("factor", phase_json(&s.factor)),
        ("core", phase_json(&s.core)),
    ])
}

fn stats_from_json(v: &Json) -> Result<EpochStats> {
    Ok(EpochStats {
        factor: phase_from_json(v.get("factor").ok_or_else(|| anyhow!("missing factor stats"))?)?,
        core: phase_from_json(v.get("core").ok_or_else(|| anyhow!("missing core stats"))?)?,
    })
}

fn welcome_frame(member: MemberId, section_entries: usize, spec: &RunSpec) -> String {
    json::obj(vec![
        ("kind", json::s("welcome")),
        ("proto", json::num(PROTO as f64)),
        ("member", json::num(member as f64)),
        ("section_entries", json::num(section_entries as f64)),
        ("spec", spec.to_json()),
    ])
    .dump()
}

// ======================================================================
// Coordinator process
// ======================================================================

/// Bind `listen` (e.g. `127.0.0.1:7270`) and run the coordinator until
/// the run completes.  `spec.train.workers` is the quorum: that many
/// workers must join before the first round deals.
pub fn run_coordinator(
    spec: &RunSpec,
    listen: &str,
    observer: &mut dyn Observer,
) -> Result<DistRun> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("binding coordinator on {listen}"))?;
    run_coordinator_on(spec, listener, observer)
}

/// [`run_coordinator`] on an already-bound listener (tests bind port 0
/// and read the real port back before handing the listener in).
pub fn run_coordinator_on(
    spec: &RunSpec,
    listener: TcpListener,
    observer: &mut dyn Observer,
) -> Result<DistRun> {
    spec.validate()
        .map_err(|e| anyhow!(e))
        .context("invalid run spec")?;
    let workers = spec.train.workers;
    ensure!(
        workers > 0,
        "run_coordinator needs train.workers >= 1 (the quorum to wait for)"
    );
    let cfg = &spec.train;
    let sched = &spec.schedule;

    // resolve data exactly like the channel backend (and the serial
    // session): same split, same section geometry, same init
    let (data, test, n_sections, section_entries) =
        resolve_dist_data(&spec.data, sched.test_frac, cfg.seed, workers)?;
    let view: &dyn TensorView = data.view();
    ensure!(
        view.nnz() < u32::MAX as usize,
        "tensor has {} entries; the block samplers address at most 2^32 - 2",
        view.nnz()
    );
    let global0 = TuckerModel::init_with_mean(
        &view.dims().to_vec(),
        cfg.j,
        cfg.r,
        cfg.seed,
        view.mean_value(),
    );
    let dist_cfg = DistConfig {
        min_members: workers,
        warmup_ticks: 2,
        heartbeat_timeout_ticks: 60,
        rounds: sched.epochs as u64,
        sync_every: 1,
        seed: cfg.seed,
        n_sections,
    };

    let mut tel = match &spec.metrics {
        Some(path) => Some(DistTelemetry::create(path)?),
        None => None,
    };

    listener
        .set_nonblocking(true)
        .context("making the listener non-blocking")?;

    let stop = AtomicBool::new(false);
    let next_member = AtomicU64::new(1);
    let writers: Mutex<BTreeMap<MemberId, FrameWriter>> = Mutex::new(BTreeMap::new());
    let (event_tx, event_rx) = mpsc::channel::<Event>();
    let (done_tx, done_rx) = mpsc::channel::<RoundResult>();

    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<DistRun> {
        // accept thread: handshakes happen on per-connection reader
        // threads so a slow joiner can't stall later accepts
        {
            let stop = &stop;
            let next_member = &next_member;
            let writers = &writers;
            let listener = &listener;
            let event_tx = event_tx.clone();
            let done_tx = done_tx.clone();
            scope.spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let event_tx = event_tx.clone();
                            let done_tx = done_tx.clone();
                            scope.spawn(move || {
                                // a connection failing is a per-worker
                                // event (heartbeat eviction handles the
                                // fallout), never run-fatal
                                let _ = serve_connection(
                                    stream,
                                    section_entries,
                                    spec,
                                    next_member,
                                    writers,
                                    &event_tx,
                                    &done_tx,
                                );
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            });
        }
        drop(event_tx);
        drop(done_tx);

        let mut coord = Coordinator::new(dist_cfg);
        let mut driver = RoundDriver::new(cfg, sched, &test, global0, observer);
        let mut pending: Vec<RoundResult> = Vec::new();

        let mut tick_debt = Duration::ZERO;
        let mut last_pass = Instant::now();
        let mut round_started: Option<Instant> = None;
        let run = 'drive: loop {
            // 1. drain worker events (same cadence as the channel
            // backend: rejected events are dropped by design)
            match event_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if let Some(t) = &tel {
                        t.on_event(coord.ticks(), &ev);
                    }
                    let _ = coord.apply(&ev);
                    while let Ok(ev) = event_rx.try_recv() {
                        if let Some(t) = &tel {
                            t.on_event(coord.ticks(), &ev);
                        }
                        let _ = coord.apply(&ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }

            // 2. wall time → ticks, with the stall-forgetting credit
            // clamp (see dist::local::PASS_CREDIT_MAX)
            let now = Instant::now();
            tick_debt += now.duration_since(last_pass).min(PASS_CREDIT_MAX);
            last_pass = now;
            let mut directives = Vec::new();
            while tick_debt >= TICK {
                tick_debt -= TICK;
                while let Ok(ev) = event_rx.try_recv() {
                    if let Some(t) = &tel {
                        t.on_event(coord.ticks(), &ev);
                    }
                    let _ = coord.apply(&ev);
                }
                if let Some(t) = &tel {
                    t.ticks.inc();
                }
                directives.extend(coord.tick());
            }

            // 3. obey the directives
            for d in directives {
                if let Some(t) = &tel {
                    t.on_directive(coord.ticks(), &d);
                }
                match d {
                    Directive::EnterWarmup => {
                        observer.on_round(&coord.state());
                        // the quorum is set: connections that joined too
                        // late (or never completed a handshake) are not
                        // members — close them out
                        let members = coord.state().members;
                        let mut map = writers.lock().unwrap();
                        map.retain(|m, w| {
                            let keep = members.contains(m);
                            if !keep {
                                w.shutdown();
                            }
                            keep
                        });
                    }
                    Directive::Evict { member } => {
                        driver.drop_member(member);
                        if let Some(w) = writers.lock().unwrap().remove(&member) {
                            w.shutdown();
                        }
                        observer.on_round(&coord.state());
                    }
                    Directive::BeginRound { round, assignment } => {
                        observer.on_round(&coord.state());
                        round_started = Some(Instant::now());
                        let line = with_field(
                            Directive::BeginRound {
                                round,
                                assignment: assignment.clone(),
                            }
                            .to_json(),
                            "hyper",
                            hyper_json(&driver.hyper),
                        )
                        .dump();
                        let map = writers.lock().unwrap();
                        for (member, _sections) in &assignment.shards {
                            if let Some(w) = map.get(member) {
                                // a dead worker's send errors; the
                                // coordinator will evict it by timeout
                                let _ = w.send_line_with_payload(
                                    &line,
                                    &driver.model_for(*member).to_bytes(),
                                );
                            }
                        }
                    }
                    Directive::RunSync {
                        round,
                        members,
                        average,
                    } => {
                        observer.on_round(&coord.state());
                        let barrier_t0 = Instant::now();
                        if let Some(t) = &tel {
                            if let Some(started) = round_started.take() {
                                t.round_ns.record_duration(started.elapsed());
                            }
                        }
                        while let Ok(r) = done_rx.try_recv() {
                            pending.push(r);
                        }
                        pending.retain(|(_, r, _, _)| *r >= round);
                        // members are sorted by id, so `picked` is too —
                        // the averaging order is deterministic
                        let mut picked: Vec<(MemberId, TuckerModel, EpochStats)> = Vec::new();
                        for &m in &members {
                            if let Some(pos) = pending
                                .iter()
                                .position(|(pm, pr, _, _)| *pm == m && *pr == round)
                            {
                                let (_, _, model, stats) = pending.remove(pos);
                                picked.push((m, model, stats));
                            }
                        }
                        // errors break out of the drive loop instead of
                        // `?`-ing straight out of the closure: the
                        // teardown below must run so the accept thread
                        // (which only checks the stop flag) exits
                        let done = match driver.run_barrier(round, average, picked, observer) {
                            Ok(done) => done,
                            Err(e) => break 'drive Err(e),
                        };
                        if let Some(t) = &tel {
                            t.on_event(coord.ticks(), &done);
                        }
                        if let Err(e) = coord.apply(&done) {
                            break 'drive Err(anyhow!(
                                "coordinator rejected {}: {e}",
                                done.kind()
                            ));
                        }
                        if let Some(t) = &tel {
                            t.barrier_ns.record_duration(barrier_t0.elapsed());
                        }
                    }
                    Directive::Finish => {
                        observer.on_round(&coord.state());
                        let line = Directive::Finish.to_json().dump();
                        for w in writers.lock().unwrap().values() {
                            let _ = w.send_line(&line);
                        }
                        break 'drive Ok(());
                    }
                }
            }

            if t0.elapsed().as_secs() > WATCHDOG_S {
                if let Some(t) = tel.as_mut() {
                    let _ = t.finish();
                }
                break 'drive Err(anyhow!(
                    "distributed run exceeded the {WATCHDOG_S}s watchdog in phase {} \
                     (round {}, {} members)",
                    coord.phase().name(),
                    coord.round(),
                    coord.members().len()
                ));
            }
        };

        // teardown: stop accepting, close every socket (unblocking the
        // reader threads), then let the scope join them
        stop.store(true, Ordering::SeqCst);
        for w in writers.lock().unwrap().values() {
            w.shutdown();
        }
        run?;

        if let Some(t) = tel.as_mut() {
            t.finish().context("writing dist metrics file")?;
        }
        let (report, model) = driver.finish(t0.elapsed().as_secs_f64(), observer)?;
        Ok(DistRun {
            report,
            model,
            final_state: coord.state(),
        })
    })
}

/// One connection's coordinator-side life: handshake (assign a member
/// id, answer `welcome`), then forward every event — pairing each
/// `step_complete` with its model payload into the done queue *before*
/// the event, the ordering the barrier relies on.
#[allow(clippy::too_many_arguments)] // one call site, in the accept loop
fn serve_connection(
    stream: TcpStream,
    section_entries: usize,
    spec: &RunSpec,
    next_member: &AtomicU64,
    writers: &Mutex<BTreeMap<MemberId, FrameWriter>>,
    event_tx: &Sender<Event>,
    done_tx: &Sender<RoundResult>,
) -> Result<()> {
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(READ_IDLE))
        .context("setting the connection read timeout")?;
    stream
        .set_write_timeout(Some(DEFAULT_TIMEOUT))
        .context("setting the connection write timeout")?;
    let writer = FrameWriter::new(stream.try_clone().context("cloning the socket")?);
    let mut reader = BufReader::new(stream);

    // handshake: first frame must be a join asking for an id
    let line = read_line_bounded(&mut reader, MAX_CONTROL_FRAME)?
        .ok_or_else(|| anyhow!("peer closed before the handshake"))?;
    let v = Json::parse(&line).map_err(|e| anyhow!("bad handshake frame: {e}"))?;
    match Event::from_json(&v) {
        Ok(Event::Join { member: 0 }) => {}
        _ => bail!("expected a join handshake, got {line:?}"),
    }
    if let Some(p) = v.get("proto").and_then(Json::as_usize) {
        ensure!(
            p as u64 == PROTO,
            "protocol version mismatch: peer speaks {p}, this coordinator speaks {PROTO}"
        );
    }
    let member = next_member.fetch_add(1, Ordering::SeqCst);
    writer.send_line(&welcome_frame(member, section_entries, spec))?;
    writers.lock().unwrap().insert(member, writer);
    let _ = event_tx.send(Event::Join { member });

    // event stream
    loop {
        let line = match read_line_bounded(&mut reader, MAX_CONTROL_FRAME)? {
            None => return Ok(()), // clean EOF: worker exited
            Some(l) => l,
        };
        let v = Json::parse(&line).map_err(|e| anyhow!("bad frame from member {member}: {e}"))?;
        let ev = Event::from_json(&v)
            .map_err(|e| anyhow!("bad event from member {member}: {e}"))?;
        // a member may only speak for itself — anything else is a
        // protocol violation and drops the connection
        match &ev {
            Event::Join { member: m }
            | Event::Heartbeat { member: m }
            | Event::StepComplete { member: m, .. } => {
                ensure!(
                    *m == member,
                    "member {member} sent a frame claiming member {m}"
                );
            }
            Event::SyncComplete { .. } | Event::Shutdown => {
                bail!("member {member} sent a coordinator-only event {}", ev.kind())
            }
        }
        if let Event::StepComplete { round, .. } = ev {
            let stats = match v.get("stats") {
                Some(s) => stats_from_json(s)?,
                None => EpochStats::default(),
            };
            let bytes = read_payload(&mut reader, MAX_MODEL_BYTES)?;
            let model = TuckerModel::from_bytes(&bytes)
                .with_context(|| format!("decoding member {member}'s round {round} model"))?;
            // result before event: when the coordinator has seen the
            // StepComplete, the model is already in the done queue
            let _ = done_tx.send((member, round, model, stats));
        }
        if event_tx.send(ev).is_err() {
            return Ok(()); // drive loop exited; nothing left to do
        }
    }
}

// ======================================================================
// Worker process
// ======================================================================

/// How a worker process joins a run.
#[derive(Clone, Debug, Default)]
pub struct JoinOpts {
    /// Use this local FTB2 store instead of the data source in the
    /// coordinator's spec (the multi-machine path: every worker opens
    /// its own copy of the store).
    pub store: Option<PathBuf>,
    /// Socket read/write timeout (`None` → the serving client's
    /// [`DEFAULT_TIMEOUT`]); `--timeout-ms` on the CLI.
    pub timeout: Option<Duration>,
    /// Die silently partway through the given round (tests only — the
    /// socket is shut down exactly as a `kill -9` would).
    pub fault: Option<Fault>,
}

/// What a finished worker reports.
#[derive(Clone, Copy, Debug)]
pub struct WorkerSummary {
    /// The member id the coordinator assigned.
    pub member: MemberId,
    /// Rounds this worker trained.
    pub rounds: u64,
}

/// Connect to a coordinator at `addr` and work until the run finishes.
pub fn run_worker(addr: &str, opts: &JoinOpts) -> Result<WorkerSummary> {
    let timeout = opts.timeout.unwrap_or(DEFAULT_TIMEOUT);
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting coordinator {addr}"))?;
    let _ = stream.set_nodelay(true);
    // the same bounded-read mechanism as the serving NetClient: a dead
    // coordinator surfaces as a loud timeout, never a wedged worker
    stream
        .set_read_timeout(Some(timeout))
        .context("setting the read timeout")?;
    stream
        .set_write_timeout(Some(timeout))
        .context("setting the write timeout")?;
    let writer = FrameWriter::new(stream.try_clone().context("cloning the socket")?);
    let mut reader = BufReader::new(stream);

    // handshake
    writer.send_line(
        &with_field(
            Event::Join { member: 0 }.to_json(),
            "proto",
            json::num(PROTO as f64),
        )
        .dump(),
    )?;
    let line = read_line_bounded(&mut reader, MAX_CONTROL_FRAME)?
        .ok_or_else(|| anyhow!("coordinator closed the connection during the handshake"))?;
    let v = Json::parse(&line).map_err(|e| anyhow!("bad welcome frame: {e}"))?;
    ensure!(
        v.get("kind").and_then(Json::as_str) == Some("welcome"),
        "expected a welcome frame, got {line:?}"
    );
    let proto = u64_field(&v, "proto")?;
    ensure!(
        proto == PROTO,
        "protocol version mismatch: coordinator speaks {proto}, this worker speaks {PROTO}"
    );
    let member = u64_field(&v, "member")?;
    let wire_section_entries = u64_field(&v, "section_entries")? as usize;
    let spec = RunSpec::from_json(v.get("spec").ok_or_else(|| anyhow!("welcome has no spec"))?)
        .map_err(|e| anyhow!("bad spec in welcome: {e}"))?;

    // heartbeats start *before* data resolution: the coordinator's
    // liveness window opens at the join, and loading/splitting a big
    // tensor must not read as silence
    let alive = AtomicBool::new(true);
    std::thread::scope(|scope| -> Result<WorkerSummary> {
        spawn_heartbeats(scope, &alive, &writer, member);
        let result = (|| -> Result<WorkerSummary> {
            // resolve data through the same shared path as the
            // coordinator, then cross-check the section geometry — a
            // worker pointed at different data would otherwise train
            // garbage silently
            let source = match &opts.store {
                Some(path) => DataSource::Store(path.clone()),
                None => spec.data.clone(),
            };
            let (data, _test, _n_sections, section_entries) = resolve_dist_data(
                &source,
                spec.schedule.test_frac,
                spec.train.seed,
                spec.train.workers.max(1),
            )?;
            ensure!(
                section_entries == wire_section_entries,
                "section geometry mismatch: this worker's data yields {section_entries} \
                 entries/section, the coordinator dealt {wire_section_entries} — \
                 different data?"
            );
            let view: &dyn TensorView = data.view();
            ensure!(
                view.nnz() < u32::MAX as usize,
                "tensor has {} entries; the block samplers address at most 2^32 - 2",
                view.nnz()
            );
            worker_rounds(
                member,
                view,
                &spec,
                section_entries,
                &mut reader,
                &writer,
                opts.fault,
            )
        })();
        alive.store(false, Ordering::Relaxed);
        if opts.fault.is_some() && result.is_ok() {
            // simulated crash: drop the socket like the process died
            writer.shutdown();
        }
        result
    })
}

/// Heartbeat side thread: every [`HEARTBEAT_MS`], one `heartbeat` line
/// through the shared frame writer (2 ms slices so teardown never waits
/// a full period).
fn spawn_heartbeats<'scope>(
    scope: &'scope Scope<'scope, '_>,
    alive: &'scope AtomicBool,
    writer: &'scope FrameWriter,
    member: MemberId,
) {
    let frame = Event::Heartbeat { member }.to_json().dump();
    scope.spawn(move || {
        let slices = HEARTBEAT_MS.div_ceil(2).max(1);
        while alive.load(Ordering::Relaxed) {
            for _ in 0..slices {
                if !alive.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            if writer.send_line(&frame).is_err() {
                return; // connection gone; the round loop will notice
            }
        }
    });
}

/// The worker's round loop: obey `begin_round` directives until
/// `finish` (or a simulated fault).  The training sequence per round is
/// exactly [`crate::dist::worker`]'s — `epoch_no = round` keeps the
/// sampler streams on the serial schedule.
fn worker_rounds(
    member: MemberId,
    view: &dyn TensorView,
    spec: &RunSpec,
    section_entries: usize,
    reader: &mut BufReader<TcpStream>,
    writer: &FrameWriter,
    fault: Option<Fault>,
) -> Result<WorkerSummary> {
    let mut rounds = 0u64;
    loop {
        let line = read_line_bounded(reader, MAX_CONTROL_FRAME)?
            .ok_or_else(|| anyhow!("coordinator closed the connection (evicted?)"))?;
        let v = Json::parse(&line).map_err(|e| anyhow!("bad directive frame: {e}"))?;
        let d = Directive::from_json(&v).map_err(|e| anyhow!("bad directive: {e}"))?;
        match d {
            Directive::BeginRound { round, assignment } => {
                let hyper = hyper_from_json(
                    v.get("hyper")
                        .ok_or_else(|| anyhow!("begin_round without hyper"))?,
                )?;
                let bytes = read_payload(reader, MAX_MODEL_BYTES)?;
                let model = TuckerModel::from_bytes(&bytes)
                    .with_context(|| format!("decoding the round {round} model"))?;
                let sections = assignment.sections_for(member).to_vec();
                let shard = ShardView::new(view, &sections, section_entries);
                let (model, stats) = if shard.nnz() == 0 {
                    // nothing to train: echo the model back untouched
                    (model, EpochStats::default())
                } else {
                    let mut run_cfg = spec.train.clone();
                    run_cfg.hyper = hyper;
                    let mut trainer = Trainer::with_model(&shard, run_cfg, model)?;
                    trainer.epoch_no = round;
                    let factor = trainer.factor_phase(&shard)?;
                    if fault.is_some_and(|f| f.round == round) {
                        // simulated mid-epoch crash: no StepComplete;
                        // the caller shuts the socket down
                        return Ok(WorkerSummary { member, rounds });
                    }
                    let core = trainer.core_phase(&shard)?;
                    (trainer.model, EpochStats { factor, core })
                };
                rounds += 1;
                let line = with_field(
                    Event::StepComplete { member, round }.to_json(),
                    "stats",
                    stats_json(&stats),
                )
                .dump();
                writer.send_line_with_payload(&line, &model.to_bytes())?;
            }
            Directive::Finish => return Ok(WorkerSummary { member, rounds }),
            // not addressed to workers; tolerated for forward compat
            Directive::EnterWarmup | Directive::RunSync { .. } | Directive::Evict { .. } => {}
        }
    }
}
