//! The distributed layer: sharded data-parallel training of
//! FastTuckerPlus, in the style of cu_FastTucker's multi-GPU extension
//! mapped onto worker threads (and, later, worker processes).
//!
//! Split cleanly into policy and plumbing:
//!
//! * [`event`] — the protocol vocabulary: [`Event`]s workers send,
//!   [`Directive`]s the coordinator issues, [`CoordinatorState`]
//!   snapshots observers read.  Every type round-trips through
//!   [`crate::util::json`], so a TCP backend can serialize the exact
//!   same values onto a wire.
//! * [`shard`] — seeded deterministic shard assignment: disjoint,
//!   covering, balanced, reproducible, join-order invariant.
//! * [`coordinator`] — the pure, tick-driven [`Coordinator`] state
//!   machine (`WaitingForMembers → Warmup → Train ⇄ Sync → Done`).  No
//!   wall clock, no threads, no I/O: events + ticks in, directives out.
//! * [`worker`] — the worker loop: wrap the assigned sections in a
//!   [`crate::data::ShardView`], run one epoch through the ordinary
//!   [`crate::coordinator::Trainer`] / `StepBackend` dispatch, ship the
//!   model back.
//! * [`local`] — the in-process backend: N workers on threads, `mpsc`
//!   channels as the wire, wall time mapped to ticks.  Drives a
//!   [`crate::session::RunSpec`] end to end (`train --workers N`).
//! * [`net`] — the TCP backend: the same protocol over sockets, one
//!   coordinator process (`train --coordinator ADDR --workers N`) and N
//!   worker processes (`train --join ADDR`).  Newline-delimited JSON
//!   frames of the [`event`] vocabulary, FTM1 model payloads at
//!   barriers, framing shared with the serving tier
//!   ([`crate::serve::net::frame`]).
//! * `driver` (crate-private) — the barrier/eval/checkpoint driver both
//!   backends share, so the 1-worker byte-identity guarantee holds over
//!   TCP because it is literally the same code path.
//!
//! Semantics in one paragraph: each round, the coordinator deals the
//! tensor's sections to the live members ([`shard::assign`]); every
//! member trains one epoch over only its sections, starting from the
//! last averaged global model; at the barrier the driver averages the
//! members' models element-wise (f64, ascending member id) and the next
//! round starts from the average.  Liveness is heartbeat-based: a member
//! silent for longer than [`DistConfig::heartbeat_timeout_ticks`] is
//! evicted and its sections return to the pool at the next deal.  With
//! one worker every mechanism degenerates to the serial trainer —
//! byte-identically, which is what makes the whole layer testable.

pub mod coordinator;
pub(crate) mod driver;
pub mod event;
pub mod local;
pub mod net;
pub mod shard;
pub mod worker;

pub use coordinator::{Coordinator, EventError};
pub use event::{
    CoordinatorState, Directive, DistConfig, DistPhase, Event, MemberId, ShardAssignment,
};
pub use local::{run_local, run_local_with, DistRun, FaultSpec, LocalOpts};
pub use net::{run_coordinator, run_coordinator_on, run_worker, JoinOpts, WorkerSummary};
pub use worker::{worker_loop, Fault, WorkerCmd};
