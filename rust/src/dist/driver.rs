//! Backend-independent driver state shared by the channel and TCP
//! backends: data resolution, the per-round barrier (model collection,
//! averaging, eval/checkpoint/early-stop bookkeeping), and the final
//! report.
//!
//! [`crate::dist::local`] and [`crate::dist::net`] differ only in how
//! events and models travel (mpsc channels vs. sockets); everything that
//! decides *what the run computes* lives here so the two backends cannot
//! drift — the 1-worker byte-identity guarantee holds over TCP because
//! it is literally the same code path.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{EpochStats, TrainConfig};
use crate::cpu_ref;
use crate::data::{PagedTensor, TensorView};
use crate::dist::event::{Event, MemberId};
use crate::model::TuckerModel;
use crate::serve::ModelSnapshot;
use crate::session::{DataSource, EpochEvent, Observer, RunReport, Schedule};
use crate::tensor::{split::train_test_split, SparseTensor};

/// Target sections per worker for in-RAM tensors (more sections than
/// workers so a re-deal after an eviction stays balanced; the actual
/// count is trimmed so no section is empty).  FTB2 stores use their
/// real on-disk sections instead.
const RAM_SECTIONS_PER_WORKER: usize = 8;

/// The training data, RAM or paged (the distributed twin of the
/// session's internal enum — both feed workers through [`TensorView`]).
pub(crate) enum DistData {
    /// An in-RAM tensor (already split; this is the train part).
    Ram(SparseTensor),
    /// A paged FTB2 store (sections are its on-disk pages).
    Paged(PagedTensor),
}

impl DistData {
    pub(crate) fn view(&self) -> &dyn TensorView {
        match self {
            DistData::Ram(t) => t,
            DistData::Paged(p) => p,
        }
    }
}

/// Resolve a data source exactly like a serial session would (same
/// split, same seed), plus the section geometry the shard assignment
/// deals over.  Returns `(train data, test tensor, n_sections,
/// section_entries)`.
///
/// Every distributed party — the local driver, the TCP coordinator, and
/// each TCP worker — resolves through this one function, so section
/// geometry is a pure function of `(source, test_frac, seed, workers)`
/// and never has to cross the wire on trust alone (the TCP worker
/// cross-checks its computed `section_entries` against the welcome
/// frame).
pub(crate) fn resolve_dist_data(
    source: &DataSource,
    test_frac: f64,
    seed: u64,
    workers: usize,
) -> Result<(DistData, SparseTensor, u32, usize)> {
    match source {
        DataSource::Store(path) => {
            let paged = PagedTensor::open(path).with_context(|| format!("opening {path:?}"))?;
            let meta = paged.meta().clone();
            let empty = SparseTensor::new(meta.dims.clone());
            let n_sections = u32::try_from(meta.num_pages().max(1))
                .map_err(|_| anyhow!("store has more than u32::MAX sections"))?;
            Ok((
                DistData::Paged(paged),
                empty,
                n_sections,
                meta.page_entries,
            ))
        }
        _ => {
            let tensor = source.resolve()?;
            let (train, test) = if test_frac > 0.0 {
                train_test_split(&tensor, test_frac, seed)
            } else {
                let empty = SparseTensor::new(tensor.dims.clone());
                (tensor, empty)
            };
            let nnz = train.values.len();
            // aim for ~RAM_SECTIONS_PER_WORKER sections per worker, then
            // shrink the count to the non-empty fixed-stride ranges:
            // `n_sections = ceil(nnz / section_entries)` puts every
            // section's start offset below nnz, so no member is dealt
            // only empty sections (such a worker would echo its model
            // back untouched and the averaging barrier would dilute that
            // round's gradient updates by 1/N)
            let target = (workers * RAM_SECTIONS_PER_WORKER).min(nnz.max(1));
            let section_entries = nnz.div_ceil(target).max(1);
            let n_sections = nnz.div_ceil(section_entries).max(1);
            Ok((
                DistData::Ram(train),
                test,
                n_sections as u32,
                section_entries,
            ))
        }
    }
}

/// Everything a distributed backend's drive loop delegates at the round
/// barrier: the global/per-member model books, averaging, evaluation,
/// checkpointing, early stopping, learning-rate decay, and the epoch
/// history.  The backend stays a pure transport: it collects
/// `(member, model, stats)` triples however its wire works and hands
/// them here.
pub(crate) struct RoundDriver<'a> {
    cfg: &'a TrainConfig,
    sched: &'a Schedule,
    test: &'a SparseTensor,
    /// Current hyper-parameters (carries learning-rate decay forward).
    pub(crate) hyper: cpu_ref::Hyper,
    /// The last averaged global model.
    pub(crate) global: TuckerModel,
    /// Each member's model between averaging barriers (`sync_every > 1`).
    last_model: BTreeMap<MemberId, TuckerModel>,
    can_eval: bool,
    history: Vec<EpochEvent>,
    best_rmse: Option<f64>,
    final_eval: Option<(f64, f64)>,
    strikes: usize,
    stopped_early: bool,
    last_epoch_checkpointed: bool,
    epochs_run: usize,
}

impl<'a> RoundDriver<'a> {
    /// Set up the books and run the epoch-0 evaluation (when the
    /// schedule evaluates at all).
    pub(crate) fn new(
        cfg: &'a TrainConfig,
        sched: &'a Schedule,
        test: &'a SparseTensor,
        global0: TuckerModel,
        observer: &mut dyn Observer,
    ) -> RoundDriver<'a> {
        let can_eval = sched.eval_every > 0 && test.nnz() > 0;
        let mut driver = RoundDriver {
            cfg,
            sched,
            test,
            hyper: cfg.hyper,
            global: global0,
            last_model: BTreeMap::new(),
            can_eval,
            history: Vec::new(),
            best_rmse: None,
            final_eval: None,
            strikes: 0,
            stopped_early: false,
            last_epoch_checkpointed: false,
            epochs_run: 0,
        };
        if can_eval {
            let (rmse, mae) = cpu_ref::evaluate(&driver.global, test);
            driver.best_rmse = Some(rmse);
            driver.final_eval = Some((rmse, mae));
            let ev = EpochEvent {
                epoch: 0,
                stats: None,
                rmse: Some(rmse),
                mae: Some(mae),
                lr_a: driver.hyper.lr_a,
                checkpoint: None,
                published: false,
                cache: None,
            };
            observer.on_epoch(&ev);
            driver.history.push(ev);
        }
        driver
    }

    /// The model `member` starts its next round from: its own model
    /// between averaging barriers, the global model otherwise.
    pub(crate) fn model_for(&self, member: MemberId) -> TuckerModel {
        self.last_model.get(&member).unwrap_or(&self.global).clone()
    }

    /// Forget an evicted member's per-member model.
    pub(crate) fn drop_member(&mut self, member: MemberId) {
        self.last_model.remove(&member);
    }

    /// Execute one round barrier over the collected results (already in
    /// ascending member-id order — the averaging order is deterministic)
    /// and return the event to apply to the coordinator:
    /// `SyncComplete` normally, `Shutdown` when early stopping fires.
    pub(crate) fn run_barrier(
        &mut self,
        round: u64,
        average: bool,
        picked: Vec<(MemberId, TuckerModel, EpochStats)>,
        observer: &mut dyn Observer,
    ) -> Result<Event> {
        let mut agg = EpochStats::default();
        for (_, _, stats) in &picked {
            agg.factor.merge(&stats.factor);
            agg.core.merge(&stats.core);
        }
        if average {
            let models: Vec<&TuckerModel> = picked.iter().map(|(_, m, _)| m).collect();
            if !models.is_empty() {
                self.global = average_models(&models);
            }
            for (m, _, _) in &picked {
                self.last_model.insert(*m, self.global.clone());
            }
        } else {
            for (m, model, _) in picked {
                self.last_model.insert(m, model);
            }
        }

        let epoch = (round + 1) as usize;
        self.epochs_run = epoch;
        let lr_a = self.hyper.lr_a;
        let eval = if self.can_eval && epoch % self.sched.eval_every == 0 {
            let (rmse, mae) = cpu_ref::evaluate(&self.global, self.test);
            self.final_eval = Some((rmse, mae));
            Some((rmse, mae))
        } else {
            None
        };
        let checkpoint = match &self.sched.checkpoint {
            Some(path)
                if self.sched.checkpoint_every > 0
                    && epoch % self.sched.checkpoint_every == 0 =>
            {
                ModelSnapshot::from_model(&self.global, self.cfg.algo, round + 1).save(path)?;
                Some(path.clone())
            }
            _ => None,
        };
        self.last_epoch_checkpointed = checkpoint.is_some();

        if let (Some(es), Some((rmse, _))) = (&self.sched.early_stop, eval) {
            let improved = match self.best_rmse {
                Some(best) => rmse < best - es.min_delta,
                None => true,
            };
            if improved {
                self.strikes = 0;
            } else {
                self.strikes += 1;
                if self.strikes >= es.patience {
                    self.stopped_early = true;
                }
            }
        }
        if let Some((rmse, _)) = eval {
            self.best_rmse = Some(self.best_rmse.map_or(rmse, |b| b.min(rmse)));
        }

        let ev = EpochEvent {
            epoch,
            stats: Some(agg),
            rmse: eval.map(|e| e.0),
            mae: eval.map(|e| e.1),
            lr_a,
            checkpoint,
            published: false,
            cache: None,
        };
        observer.on_epoch(&ev);
        self.history.push(ev);

        if self.stopped_early {
            Ok(Event::Shutdown)
        } else {
            if let Some(decay) = self.sched.lr_decay {
                self.hyper.lr_a *= decay;
                self.hyper.lr_b *= decay;
            }
            Ok(Event::SyncComplete { round })
        }
    }

    /// Close the books: write the final checkpoint if the cadence didn't
    /// already cover the last epoch, build the report, and notify the
    /// observer.  Returns `(report, final model)`.
    pub(crate) fn finish(
        self,
        wall_s: f64,
        observer: &mut dyn Observer,
    ) -> Result<(RunReport, TuckerModel)> {
        if let Some(path) = &self.sched.checkpoint {
            if !self.last_epoch_checkpointed {
                ModelSnapshot::from_model(&self.global, self.cfg.algo, self.epochs_run as u64)
                    .save(path)?;
            }
        }
        let report = RunReport {
            epochs_run: self.epochs_run,
            stopped_early: self.stopped_early,
            final_rmse: self.final_eval.map(|e| e.0),
            final_mae: self.final_eval.map(|e| e.1),
            best_rmse: self.best_rmse,
            wall_s,
            history: self.history,
        };
        observer.on_finish(&report);
        Ok((report, self.global))
    }
}

/// Element-wise mean of the members' models, accumulated in `f64`.
/// Callers pass models in ascending member-id order, so the sum order —
/// and therefore the result, bit for bit — is deterministic.  Averaging
/// a single model is the identity (`(f64::from(x) / 1.0) as f32 == x`).
pub(crate) fn average_models(models: &[&TuckerModel]) -> TuckerModel {
    let mut out = models[0].clone();
    let k = models.len() as f64;
    for n in 0..out.factors.len() {
        for (i, slot) in out.factors[n].iter_mut().enumerate() {
            let sum: f64 = models.iter().map(|m| f64::from(m.factors[n][i])).sum();
            *slot = (sum / k) as f32;
        }
        for (i, slot) in out.cores[n].iter_mut().enumerate() {
            let sum: f64 = models.iter().map(|m| f64::from(m.cores[n][i])).sum();
            *slot = (sum / k) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(seed: u64) -> TuckerModel {
        TuckerModel::init_with_mean(&[4, 5, 6], 16, 16, seed, 1.0)
    }

    #[test]
    fn averaging_one_model_is_the_identity() {
        let m = model(3);
        let avg = average_models(&[&m]);
        for n in 0..m.factors.len() {
            assert_eq!(m.factors[n], avg.factors[n]);
            assert_eq!(m.cores[n], avg.cores[n]);
        }
    }

    #[test]
    fn averaging_is_the_elementwise_mean() {
        let a = model(1);
        let b = model(2);
        let avg = average_models(&[&a, &b]);
        let expect = (f64::from(a.factors[0][0]) + f64::from(b.factors[0][0])) / 2.0;
        assert_eq!(avg.factors[0][0], expect as f32);
    }
}
