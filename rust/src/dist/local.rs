//! The in-process channel backend: N workers on threads, one
//! coordinator, `mpsc` channels as the wire.
//!
//! This is the reference implementation of the distributed protocol —
//! the TCP backend ([`crate::dist::net`]) replaces the channels with
//! sockets and keeps the same tick-from-wall-clock mapping, and nothing
//! else: the [`Coordinator`] itself never sees a clock, and the barrier
//! semantics are shared code ([`crate::dist::driver`]).  The mapping is
//! [`TICK_MS`] milliseconds of wall time per tick, so the default
//! heartbeat timeout of 60 ticks is ~300 ms against workers that
//! heartbeat every ~20 ms ([`crate::dist::worker::HEARTBEAT_MS`]).
//! Each drive-loop pass converts at most `PASS_CREDIT_MAX` of elapsed
//! wall time into ticks and drains events before every tick, so time
//! the driver spent stalled on barrier work is forgotten rather than
//! replayed — never judged as worker heartbeat silence.
//!
//! One round = one epoch on every worker over its assigned sections,
//! then a barrier: the driver collects the workers' models, averages
//! them (f64 accumulation over members in ascending id order, so the
//! result is independent of arrival order), evaluates/checkpoints per
//! the schedule, and deals the next round.  With one worker the barrier
//! averages a single model — a bit-exact identity — so `--workers 1`
//! reproduces the serial trainer byte for byte (pinned by
//! `tests/dist.rs` and the CI `dist-smoke` job).

use std::collections::BTreeMap;
use std::io;
use std::path::Path;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::EpochStats;
use crate::data::TensorView;
use crate::dist::coordinator::Coordinator;
use crate::dist::driver::{resolve_dist_data, RoundDriver};
use crate::dist::event::{CoordinatorState, Directive, DistConfig, Event, MemberId};
use crate::dist::worker::{worker_loop, Fault, RoundResult, WorkerCmd};
use crate::model::TuckerModel;
use crate::obs::{Counter, FlightRecorder, Hist, Metrics, MetricsFile};
use crate::session::{Observer, RunReport, RunSpec};

/// Wall-clock milliseconds per coordinator tick in this backend.
pub const TICK_MS: u64 = 5;

/// One coordinator tick's worth of wall time.
pub(crate) const TICK: Duration = Duration::from_millis(TICK_MS);

/// The longest stretch of wall time one drive-loop pass may convert into
/// coordinator ticks.  Directive handling can stall the driver for
/// hundreds of milliseconds (the initial eval, a barrier eval on a
/// sizable test set, a checkpoint save) while the workers' heartbeats
/// pile up unread in the event queue; converting that whole stretch into
/// ticks at once would fast-forward the coordinator past the heartbeat
/// timeout against a backlog it never drained, evict every healthy
/// member and silently truncate the run.  Clamping each pass's credit
/// *forgets* driver-side stalls instead of replaying them: while the
/// driver is responsive the coordinator clock tracks wall time (so a
/// genuinely dead worker is still evicted after ~heartbeat timeout ×
/// [`TICK_MS`] of real silence), and a stalled pass contributes at most
/// two ticks.  The tick counter may therefore lag wall time — nothing
/// requires it to be wall-accurate, only monotonic.
pub(crate) const PASS_CREDIT_MAX: Duration = Duration::from_millis(2 * TICK_MS);

/// Hard wall-clock ceiling on a local distributed run — a liveness bug
/// should fail a test, not hang it (and CI) forever.
pub(crate) const WATCHDOG_S: u64 = 600;

/// Injected failure for the fault tests: worker number `member_index`
/// (0-based spawn index) dies mid-epoch in `round`.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// Which worker dies, as its 0-based spawn index.
    pub member_index: usize,
    /// The round it dies in.
    pub round: u64,
}

/// Knobs for [`run_local_with`] beyond the spec itself.
#[derive(Clone, Copy, Debug, Default)]
pub struct LocalOpts {
    /// Kill one worker mid-epoch (tests only).
    pub fault: Option<FaultSpec>,
}

/// What a finished distributed run hands back.
pub struct DistRun {
    /// The same report a serial [`crate::session::Session`] produces.
    pub report: RunReport,
    /// The final (averaged) global model.
    pub model: TuckerModel,
    /// The coordinator's terminal state (phase is `Done`; the member
    /// list shows who survived to the end).
    pub final_state: CoordinatorState,
}

/// Train `spec` with `spec.train.workers` in-process workers.
pub fn run_local(spec: &RunSpec, observer: &mut dyn Observer) -> Result<DistRun> {
    run_local_with(spec, &LocalOpts::default(), observer)
}

/// Telemetry for one distributed run: registry handles the drive loop
/// bumps, the flight-recorder tape of every protocol message, and the
/// JSONL sink both are dumped to on completion or watchdog abort.
/// Created only when [`RunSpec::metrics`] is set — with it absent every
/// recording site takes the `None` branch and the run's outputs are
/// bit-identical (pinned by `tests/dist.rs`).
pub(crate) struct DistTelemetry {
    registry: Metrics,
    flight: FlightRecorder,
    file: MetricsFile,
    pub(crate) ticks: Arc<Counter>,
    heartbeats: Arc<Counter>,
    evictions: Arc<Counter>,
    rounds: Arc<Counter>,
    pub(crate) round_ns: Arc<Hist>,
    pub(crate) barrier_ns: Arc<Hist>,
}

impl DistTelemetry {
    pub(crate) fn create(path: &Path) -> Result<DistTelemetry> {
        let registry = Metrics::new();
        let file = MetricsFile::create(path)
            .with_context(|| format!("creating metrics file {path:?}"))?;
        Ok(DistTelemetry {
            ticks: registry.counter("dist.ticks"),
            heartbeats: registry.counter("dist.heartbeats"),
            evictions: registry.counter("dist.evictions"),
            rounds: registry.counter("dist.rounds"),
            round_ns: registry.hist("dist.round_ns"),
            barrier_ns: registry.hist("dist.barrier_ns"),
            flight: FlightRecorder::default(),
            registry,
            file,
        })
    }

    /// Tape a worker → coordinator event before it is applied, so even
    /// events the coordinator rejects are on record.
    pub(crate) fn on_event(&self, tick: u64, ev: &Event) {
        if matches!(ev, Event::Heartbeat { .. }) {
            self.heartbeats.inc();
        }
        self.flight.record(tick, "event", ev.to_json());
    }

    /// Tape a coordinator → worker directive as it is issued.
    pub(crate) fn on_directive(&self, tick: u64, d: &Directive) {
        match d {
            Directive::Evict { .. } => self.evictions.inc(),
            Directive::BeginRound { .. } => self.rounds.inc(),
            _ => {}
        }
        self.flight.record(tick, "directive", d.to_json());
    }

    /// Dump the final registry snapshot plus the flight tape.  The
    /// watchdog-abort path ignores the result — a sink error must never
    /// mask the liveness failure being reported.
    pub(crate) fn finish(&mut self) -> io::Result<()> {
        self.file.write_snapshot("dist", &self.registry.snapshot())?;
        self.file.write_flight(&self.flight)
    }
}

/// [`run_local`] with fault injection.  Validates the spec, resolves the
/// data exactly like a serial session (same split, same seed), then runs
/// coordinator + workers to completion and returns the averaged model.
pub fn run_local_with(
    spec: &RunSpec,
    opts: &LocalOpts,
    observer: &mut dyn Observer,
) -> Result<DistRun> {
    spec.validate()
        .map_err(|e| anyhow!(e))
        .context("invalid run spec")?;
    let workers = spec.train.workers;
    ensure!(
        workers > 0,
        "run_local needs train.workers >= 1 (serial runs go through Session)"
    );
    let cfg = &spec.train;
    let sched = &spec.schedule;

    // --- data: mirror Session::from_spec so the 1-worker run sees the
    // exact same train/test split as the serial trainer ------------------
    let (data, test, n_sections, section_entries) =
        resolve_dist_data(&spec.data, sched.test_frac, cfg.seed, workers)?;
    let view: &dyn TensorView = data.view();
    ensure!(
        view.nnz() < u32::MAX as usize,
        "tensor has {} entries; the block samplers address at most 2^32 - 2",
        view.nnz()
    );

    // same init as Trainer::new — with one worker the first round starts
    // from bit-identical factors
    let global0 = TuckerModel::init_with_mean(
        &view.dims().to_vec(),
        cfg.j,
        cfg.r,
        cfg.seed,
        view.mean_value(),
    );

    let dist_cfg = DistConfig {
        min_members: workers,
        warmup_ticks: 2,
        heartbeat_timeout_ticks: 60,
        rounds: sched.epochs as u64,
        sync_every: 1,
        seed: cfg.seed,
        n_sections,
    };

    let mut tel = match &spec.metrics {
        Some(path) => Some(DistTelemetry::create(path)?),
        None => None,
    };

    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<DistRun> {
        let (event_tx, event_rx) = mpsc::channel::<Event>();
        let (done_tx, done_rx) = mpsc::channel::<RoundResult>();
        let mut cmds: BTreeMap<MemberId, mpsc::Sender<WorkerCmd>> = BTreeMap::new();
        let mut handles = Vec::with_capacity(workers);
        for idx in 0..workers {
            let member = (idx + 1) as MemberId;
            let (cmd_tx, cmd_rx) = mpsc::channel::<WorkerCmd>();
            cmds.insert(member, cmd_tx);
            let events = event_tx.clone();
            let done = done_tx.clone();
            let fault = opts
                .fault
                .filter(|f| f.member_index == idx)
                .map(|f| Fault { round: f.round });
            handles.push(scope.spawn(move || {
                worker_loop(member, view, cfg, section_entries, cmd_rx, events, done, fault)
            }));
        }
        // the driver holds only receivers: when every worker has exited,
        // recv reports Disconnected instead of blocking forever
        drop(event_tx);
        drop(done_tx);

        let mut coord = Coordinator::new(dist_cfg);
        let mut driver = RoundDriver::new(cfg, sched, &test, global0, observer);
        let mut pending: Vec<RoundResult> = Vec::new();

        let mut tick_debt = Duration::ZERO;
        let mut last_pass = Instant::now();
        // wall-clock anchor of the round in flight, for the telemetry
        // round-duration histogram (BeginRound issued → RunSync reached)
        let mut round_started: Option<Instant> = None;
        'drive: loop {
            // 1. drain worker events into the coordinator.  Rejected
            // events (a late heartbeat from an evicted worker, a
            // duplicate step-complete) are dropped by design.
            match event_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if let Some(t) = &tel {
                        t.on_event(coord.ticks(), &ev);
                    }
                    let _ = coord.apply(&ev);
                    while let Ok(ev) = event_rx.try_recv() {
                        if let Some(t) = &tel {
                            t.on_event(coord.ticks(), &ev);
                        }
                        let _ = coord.apply(&ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    // every worker is gone; ticks below will evict them
                    // all and finish the run
                    std::thread::sleep(Duration::from_millis(1));
                }
            }

            // 2. convert wall time since the last pass into coordinator
            // ticks — crediting at most PASS_CREDIT_MAX per pass so a
            // driver-side stall is forgotten rather than replayed, and
            // draining freshly arrived events before every tick so
            // liveness is never judged against an unread backlog
            let now = Instant::now();
            tick_debt += now.duration_since(last_pass).min(PASS_CREDIT_MAX);
            last_pass = now;
            let mut directives = Vec::new();
            while tick_debt >= TICK {
                tick_debt -= TICK;
                while let Ok(ev) = event_rx.try_recv() {
                    if let Some(t) = &tel {
                        t.on_event(coord.ticks(), &ev);
                    }
                    let _ = coord.apply(&ev);
                }
                if let Some(t) = &tel {
                    t.ticks.inc();
                }
                directives.extend(coord.tick());
            }

            // 3. obey the directives
            for d in directives {
                if let Some(t) = &tel {
                    t.on_directive(coord.ticks(), &d);
                }
                match d {
                    Directive::EnterWarmup | Directive::Evict { .. } => {
                        if let Directive::Evict { member } = d {
                            driver.drop_member(member);
                        }
                        observer.on_round(&coord.state());
                    }
                    Directive::BeginRound { round, assignment } => {
                        observer.on_round(&coord.state());
                        round_started = Some(Instant::now());
                        for (member, sections) in assignment.shards {
                            let model = driver.model_for(member);
                            if let Some(tx) = cmds.get(&member) {
                                // a dead worker's channel errors; the
                                // coordinator will evict it by timeout
                                let _ = tx.send(WorkerCmd::Round {
                                    round,
                                    sections,
                                    model,
                                    hyper: driver.hyper,
                                });
                            }
                        }
                    }
                    Directive::RunSync {
                        round,
                        members,
                        average,
                    } => {
                        observer.on_round(&coord.state());
                        let barrier_t0 = Instant::now();
                        if let Some(t) = &tel {
                            if let Some(started) = round_started.take() {
                                t.round_ns.record_duration(started.elapsed());
                            }
                        }
                        while let Ok(r) = done_rx.try_recv() {
                            pending.push(r);
                        }
                        pending.retain(|(_, r, _, _)| *r >= round);
                        // members are sorted by id, so `picked` is too —
                        // the averaging order is deterministic
                        let mut picked: Vec<(MemberId, TuckerModel, EpochStats)> = Vec::new();
                        for &m in &members {
                            if let Some(pos) = pending
                                .iter()
                                .position(|(pm, pr, _, _)| *pm == m && *pr == round)
                            {
                                let (_, _, model, stats) = pending.remove(pos);
                                picked.push((m, model, stats));
                            }
                        }
                        let done = driver.run_barrier(round, average, picked, observer)?;
                        if let Some(t) = &tel {
                            t.on_event(coord.ticks(), &done);
                        }
                        coord.apply(&done).map_err(|e| {
                            anyhow!("coordinator rejected {}: {e}", done.kind())
                        })?;
                        if let Some(t) = &tel {
                            t.barrier_ns.record_duration(barrier_t0.elapsed());
                        }
                    }
                    Directive::Finish => {
                        observer.on_round(&coord.state());
                        break 'drive;
                    }
                }
            }

            if t0.elapsed().as_secs() > WATCHDOG_S {
                // dump the tape first: the flight recorder exists for
                // exactly this moment, and a sink error must not mask
                // the liveness failure
                if let Some(t) = tel.as_mut() {
                    let _ = t.finish();
                }
                bail!(
                    "distributed run exceeded the {WATCHDOG_S}s watchdog in phase {} \
                     (round {}, {} members)",
                    coord.phase().name(),
                    coord.round(),
                    coord.members().len()
                );
            }
        }

        // orderly shutdown: Stop every worker, then surface any worker
        // error or panic (dropping `cmds` unblocks workers even if a
        // Stop send raced a worker exit)
        for tx in cmds.values() {
            let _ = tx.send(WorkerCmd::Stop);
        }
        drop(cmds);
        for h in handles {
            match h.join() {
                Ok(r) => r?,
                Err(p) => std::panic::resume_unwind(p),
            }
        }

        if let Some(t) = tel.as_mut() {
            t.finish().context("writing dist metrics file")?;
        }

        let (report, model) = driver.finish(t0.elapsed().as_secs_f64(), observer)?;
        Ok(DistRun {
            report,
            model,
            final_state: coord.state(),
        })
    })
}
