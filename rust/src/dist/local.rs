//! The in-process channel backend: N workers on threads, one
//! coordinator, `mpsc` channels as the wire.
//!
//! This is the reference implementation of the distributed protocol —
//! the TCP backend (a later PR) replaces the channels and the
//! tick-from-wall-clock mapping here, and nothing else: the
//! [`Coordinator`] itself never sees a clock.  The mapping is
//! [`TICK_MS`] milliseconds of wall time per tick, so the default
//! heartbeat timeout of 60 ticks is ~300 ms against workers that
//! heartbeat every ~20 ms ([`crate::dist::worker::HEARTBEAT_MS`]).
//! Each drive-loop pass converts at most `PASS_CREDIT_MAX` of elapsed
//! wall time into ticks and drains events before every tick, so time
//! the driver spent stalled on barrier work is forgotten rather than
//! replayed — never judged as worker heartbeat silence.
//!
//! One round = one epoch on every worker over its assigned sections,
//! then a barrier: the driver collects the workers' models, averages
//! them (f64 accumulation over members in ascending id order, so the
//! result is independent of arrival order), evaluates/checkpoints per
//! the schedule, and deals the next round.  With one worker the barrier
//! averages a single model — a bit-exact identity — so `--workers 1`
//! reproduces the serial trainer byte for byte (pinned by
//! `tests/dist.rs` and the CI `dist-smoke` job).

use std::collections::BTreeMap;
use std::io;
use std::path::Path;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::EpochStats;
use crate::cpu_ref;
use crate::data::{PagedTensor, TensorView};
use crate::dist::coordinator::Coordinator;
use crate::dist::event::{CoordinatorState, Directive, DistConfig, Event, MemberId};
use crate::dist::worker::{worker_loop, Fault, RoundResult, WorkerCmd};
use crate::model::TuckerModel;
use crate::obs::{Counter, FlightRecorder, Hist, Metrics, MetricsFile};
use crate::serve::ModelSnapshot;
use crate::session::{DataSource, EpochEvent, Observer, RunReport, RunSpec};
use crate::tensor::{split::train_test_split, SparseTensor};

/// Wall-clock milliseconds per coordinator tick in this backend.
pub const TICK_MS: u64 = 5;

/// One coordinator tick's worth of wall time.
const TICK: Duration = Duration::from_millis(TICK_MS);

/// The longest stretch of wall time one drive-loop pass may convert into
/// coordinator ticks.  Directive handling can stall the driver for
/// hundreds of milliseconds (the initial eval, a barrier eval on a
/// sizable test set, a checkpoint save) while the workers' heartbeats
/// pile up unread in the event queue; converting that whole stretch into
/// ticks at once would fast-forward the coordinator past the heartbeat
/// timeout against a backlog it never drained, evict every healthy
/// member and silently truncate the run.  Clamping each pass's credit
/// *forgets* driver-side stalls instead of replaying them: while the
/// driver is responsive the coordinator clock tracks wall time (so a
/// genuinely dead worker is still evicted after ~heartbeat timeout ×
/// [`TICK_MS`] of real silence), and a stalled pass contributes at most
/// two ticks.  The tick counter may therefore lag wall time — nothing
/// requires it to be wall-accurate, only monotonic.
const PASS_CREDIT_MAX: Duration = Duration::from_millis(2 * TICK_MS);

/// Hard wall-clock ceiling on a local distributed run — a liveness bug
/// should fail a test, not hang it (and CI) forever.
const WATCHDOG_S: u64 = 600;

/// Target sections per worker for in-RAM tensors (more sections than
/// workers so a re-deal after an eviction stays balanced; the actual
/// count is trimmed so no section is empty).  FTB2 stores use their
/// real on-disk sections instead.
const RAM_SECTIONS_PER_WORKER: usize = 8;

/// Injected failure for the fault tests: worker number `member_index`
/// (0-based spawn index) dies mid-epoch in `round`.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// Which worker dies, as its 0-based spawn index.
    pub member_index: usize,
    /// The round it dies in.
    pub round: u64,
}

/// Knobs for [`run_local_with`] beyond the spec itself.
#[derive(Clone, Copy, Debug, Default)]
pub struct LocalOpts {
    /// Kill one worker mid-epoch (tests only).
    pub fault: Option<FaultSpec>,
}

/// What a finished distributed run hands back.
pub struct DistRun {
    /// The same report a serial [`crate::session::Session`] produces.
    pub report: RunReport,
    /// The final (averaged) global model.
    pub model: TuckerModel,
    /// The coordinator's terminal state (phase is `Done`; the member
    /// list shows who survived to the end).
    pub final_state: CoordinatorState,
}

/// Train `spec` with `spec.train.workers` in-process workers.
pub fn run_local(spec: &RunSpec, observer: &mut dyn Observer) -> Result<DistRun> {
    run_local_with(spec, &LocalOpts::default(), observer)
}

/// The training data, RAM or paged (the distributed twin of the
/// session's internal enum — both feed workers through [`TensorView`]).
enum DistData {
    Ram(SparseTensor),
    Paged(PagedTensor),
}

impl DistData {
    fn view(&self) -> &dyn TensorView {
        match self {
            DistData::Ram(t) => t,
            DistData::Paged(p) => p,
        }
    }
}

/// Telemetry for one distributed run: registry handles the drive loop
/// bumps, the flight-recorder tape of every protocol message, and the
/// JSONL sink both are dumped to on completion or watchdog abort.
/// Created only when [`RunSpec::metrics`] is set — with it absent every
/// recording site takes the `None` branch and the run's outputs are
/// bit-identical (pinned by `tests/dist.rs`).
struct DistTelemetry {
    registry: Metrics,
    flight: FlightRecorder,
    file: MetricsFile,
    ticks: Arc<Counter>,
    heartbeats: Arc<Counter>,
    evictions: Arc<Counter>,
    rounds: Arc<Counter>,
    round_ns: Arc<Hist>,
    barrier_ns: Arc<Hist>,
}

impl DistTelemetry {
    fn create(path: &Path) -> Result<DistTelemetry> {
        let registry = Metrics::new();
        let file = MetricsFile::create(path)
            .with_context(|| format!("creating metrics file {path:?}"))?;
        Ok(DistTelemetry {
            ticks: registry.counter("dist.ticks"),
            heartbeats: registry.counter("dist.heartbeats"),
            evictions: registry.counter("dist.evictions"),
            rounds: registry.counter("dist.rounds"),
            round_ns: registry.hist("dist.round_ns"),
            barrier_ns: registry.hist("dist.barrier_ns"),
            flight: FlightRecorder::default(),
            registry,
            file,
        })
    }

    /// Tape a worker → coordinator event before it is applied, so even
    /// events the coordinator rejects are on record.
    fn on_event(&self, tick: u64, ev: &Event) {
        if matches!(ev, Event::Heartbeat { .. }) {
            self.heartbeats.inc();
        }
        self.flight.record(tick, "event", ev.to_json());
    }

    /// Tape a coordinator → worker directive as it is issued.
    fn on_directive(&self, tick: u64, d: &Directive) {
        match d {
            Directive::Evict { .. } => self.evictions.inc(),
            Directive::BeginRound { .. } => self.rounds.inc(),
            _ => {}
        }
        self.flight.record(tick, "directive", d.to_json());
    }

    /// Dump the final registry snapshot plus the flight tape.  The
    /// watchdog-abort path ignores the result — a sink error must never
    /// mask the liveness failure being reported.
    fn finish(&mut self) -> io::Result<()> {
        self.file.write_snapshot("dist", &self.registry.snapshot())?;
        self.file.write_flight(&self.flight)
    }
}

/// [`run_local`] with fault injection.  Validates the spec, resolves the
/// data exactly like a serial session (same split, same seed), then runs
/// coordinator + workers to completion and returns the averaged model.
pub fn run_local_with(
    spec: &RunSpec,
    opts: &LocalOpts,
    observer: &mut dyn Observer,
) -> Result<DistRun> {
    spec.validate()
        .map_err(|e| anyhow!(e))
        .context("invalid run spec")?;
    let workers = spec.train.workers;
    ensure!(
        workers > 0,
        "run_local needs train.workers >= 1 (serial runs go through Session)"
    );
    let cfg = &spec.train;
    let sched = &spec.schedule;

    // --- data: mirror Session::from_spec so the 1-worker run sees the
    // exact same train/test split as the serial trainer ------------------
    let (data, test, n_sections, section_entries) = match &spec.data {
        DataSource::Store(path) => {
            let paged = PagedTensor::open(path).with_context(|| format!("opening {path:?}"))?;
            let meta = paged.meta().clone();
            let empty = SparseTensor::new(meta.dims.clone());
            let n_sections = u32::try_from(meta.num_pages().max(1))
                .map_err(|_| anyhow!("store has more than u32::MAX sections"))?;
            (
                DistData::Paged(paged),
                empty,
                n_sections,
                meta.page_entries,
            )
        }
        _ => {
            let tensor = spec.data.resolve()?;
            let (train, test) = if sched.test_frac > 0.0 {
                train_test_split(&tensor, sched.test_frac, cfg.seed)
            } else {
                let empty = SparseTensor::new(tensor.dims.clone());
                (tensor, empty)
            };
            let nnz = train.values.len();
            // aim for ~RAM_SECTIONS_PER_WORKER sections per worker, then
            // shrink the count to the non-empty fixed-stride ranges:
            // `n_sections = ceil(nnz / section_entries)` puts every
            // section's start offset below nnz, so no member is dealt
            // only empty sections (such a worker would echo its model
            // back untouched and the averaging barrier would dilute that
            // round's gradient updates by 1/N)
            let target = (workers * RAM_SECTIONS_PER_WORKER).min(nnz.max(1));
            let section_entries = nnz.div_ceil(target).max(1);
            let n_sections = nnz.div_ceil(section_entries).max(1);
            (
                DistData::Ram(train),
                test,
                n_sections as u32,
                section_entries,
            )
        }
    };
    let view: &dyn TensorView = data.view();
    ensure!(
        view.nnz() < u32::MAX as usize,
        "tensor has {} entries; the block samplers address at most 2^32 - 2",
        view.nnz()
    );

    // same init as Trainer::new — with one worker the first round starts
    // from bit-identical factors
    let global0 = TuckerModel::init_with_mean(
        &view.dims().to_vec(),
        cfg.j,
        cfg.r,
        cfg.seed,
        view.mean_value(),
    );

    let dist_cfg = DistConfig {
        min_members: workers,
        warmup_ticks: 2,
        heartbeat_timeout_ticks: 60,
        rounds: sched.epochs as u64,
        sync_every: 1,
        seed: cfg.seed,
        n_sections,
    };

    let mut tel = match &spec.metrics {
        Some(path) => Some(DistTelemetry::create(path)?),
        None => None,
    };

    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<DistRun> {
        let (event_tx, event_rx) = mpsc::channel::<Event>();
        let (done_tx, done_rx) = mpsc::channel::<RoundResult>();
        let mut cmds: BTreeMap<MemberId, mpsc::Sender<WorkerCmd>> = BTreeMap::new();
        let mut handles = Vec::with_capacity(workers);
        for idx in 0..workers {
            let member = (idx + 1) as MemberId;
            let (cmd_tx, cmd_rx) = mpsc::channel::<WorkerCmd>();
            cmds.insert(member, cmd_tx);
            let events = event_tx.clone();
            let done = done_tx.clone();
            let fault = opts
                .fault
                .filter(|f| f.member_index == idx)
                .map(|f| Fault { round: f.round });
            handles.push(scope.spawn(move || {
                worker_loop(member, view, cfg, section_entries, cmd_rx, events, done, fault)
            }));
        }
        // the driver holds only receivers: when every worker has exited,
        // recv reports Disconnected instead of blocking forever
        drop(event_tx);
        drop(done_tx);

        let mut coord = Coordinator::new(dist_cfg);
        let mut hyper = cfg.hyper;
        let mut global = global0;
        let mut last_model: BTreeMap<MemberId, TuckerModel> = BTreeMap::new();
        let mut pending: Vec<RoundResult> = Vec::new();

        let can_eval = sched.eval_every > 0 && test.nnz() > 0;
        let mut history: Vec<EpochEvent> = Vec::new();
        let mut best_rmse: Option<f64> = None;
        let mut final_eval: Option<(f64, f64)> = None;
        let mut strikes = 0usize;
        let mut stopped_early = false;
        let mut last_epoch_checkpointed = false;
        let mut epochs_run = 0usize;

        if can_eval {
            let (rmse, mae) = cpu_ref::evaluate(&global, &test);
            best_rmse = Some(rmse);
            final_eval = Some((rmse, mae));
            let ev = EpochEvent {
                epoch: 0,
                stats: None,
                rmse: Some(rmse),
                mae: Some(mae),
                lr_a: hyper.lr_a,
                checkpoint: None,
                published: false,
                cache: None,
            };
            observer.on_epoch(&ev);
            history.push(ev);
        }

        let mut tick_debt = Duration::ZERO;
        let mut last_pass = Instant::now();
        // wall-clock anchor of the round in flight, for the telemetry
        // round-duration histogram (BeginRound issued → RunSync reached)
        let mut round_started: Option<Instant> = None;
        'drive: loop {
            // 1. drain worker events into the coordinator.  Rejected
            // events (a late heartbeat from an evicted worker, a
            // duplicate step-complete) are dropped by design.
            match event_rx.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if let Some(t) = &tel {
                        t.on_event(coord.ticks(), &ev);
                    }
                    let _ = coord.apply(&ev);
                    while let Ok(ev) = event_rx.try_recv() {
                        if let Some(t) = &tel {
                            t.on_event(coord.ticks(), &ev);
                        }
                        let _ = coord.apply(&ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    // every worker is gone; ticks below will evict them
                    // all and finish the run
                    std::thread::sleep(Duration::from_millis(1));
                }
            }

            // 2. convert wall time since the last pass into coordinator
            // ticks — crediting at most PASS_CREDIT_MAX per pass so a
            // driver-side stall is forgotten rather than replayed, and
            // draining freshly arrived events before every tick so
            // liveness is never judged against an unread backlog
            let now = Instant::now();
            tick_debt += now.duration_since(last_pass).min(PASS_CREDIT_MAX);
            last_pass = now;
            let mut directives = Vec::new();
            while tick_debt >= TICK {
                tick_debt -= TICK;
                while let Ok(ev) = event_rx.try_recv() {
                    if let Some(t) = &tel {
                        t.on_event(coord.ticks(), &ev);
                    }
                    let _ = coord.apply(&ev);
                }
                if let Some(t) = &tel {
                    t.ticks.inc();
                }
                directives.extend(coord.tick());
            }

            // 3. obey the directives
            for d in directives {
                if let Some(t) = &tel {
                    t.on_directive(coord.ticks(), &d);
                }
                match d {
                    Directive::EnterWarmup | Directive::Evict { .. } => {
                        if let Directive::Evict { member } = d {
                            last_model.remove(&member);
                        }
                        observer.on_round(&coord.state());
                    }
                    Directive::BeginRound { round, assignment } => {
                        observer.on_round(&coord.state());
                        round_started = Some(Instant::now());
                        for (member, sections) in assignment.shards {
                            let model =
                                last_model.get(&member).unwrap_or(&global).clone();
                            if let Some(tx) = cmds.get(&member) {
                                // a dead worker's channel errors; the
                                // coordinator will evict it by timeout
                                let _ = tx.send(WorkerCmd::Round {
                                    round,
                                    sections,
                                    model,
                                    hyper,
                                });
                            }
                        }
                    }
                    Directive::RunSync {
                        round,
                        members,
                        average,
                    } => {
                        observer.on_round(&coord.state());
                        let barrier_t0 = Instant::now();
                        if let Some(t) = &tel {
                            if let Some(started) = round_started.take() {
                                t.round_ns.record_duration(started.elapsed());
                            }
                        }
                        while let Ok(r) = done_rx.try_recv() {
                            pending.push(r);
                        }
                        pending.retain(|(_, r, _, _)| *r >= round);
                        // members are sorted by id, so `picked` is too —
                        // the averaging order is deterministic
                        let mut picked: Vec<(MemberId, TuckerModel, EpochStats)> = Vec::new();
                        for &m in &members {
                            if let Some(pos) = pending
                                .iter()
                                .position(|(pm, pr, _, _)| *pm == m && *pr == round)
                            {
                                let (_, _, model, stats) = pending.remove(pos);
                                picked.push((m, model, stats));
                            }
                        }
                        let mut agg = EpochStats::default();
                        for (_, _, stats) in &picked {
                            agg.factor.merge(&stats.factor);
                            agg.core.merge(&stats.core);
                        }
                        if average {
                            let models: Vec<&TuckerModel> =
                                picked.iter().map(|(_, m, _)| m).collect();
                            if !models.is_empty() {
                                global = average_models(&models);
                            }
                            for (m, _, _) in &picked {
                                last_model.insert(*m, global.clone());
                            }
                        } else {
                            for (m, model, _) in picked {
                                last_model.insert(m, model);
                            }
                        }

                        let epoch = (round + 1) as usize;
                        epochs_run = epoch;
                        let lr_a = hyper.lr_a;
                        let eval = if can_eval && epoch % sched.eval_every == 0 {
                            let (rmse, mae) = cpu_ref::evaluate(&global, &test);
                            final_eval = Some((rmse, mae));
                            Some((rmse, mae))
                        } else {
                            None
                        };
                        let checkpoint = match &sched.checkpoint {
                            Some(path)
                                if sched.checkpoint_every > 0
                                    && epoch % sched.checkpoint_every == 0 =>
                            {
                                ModelSnapshot::from_model(&global, cfg.algo, round + 1)
                                    .save(path)?;
                                Some(path.clone())
                            }
                            _ => None,
                        };
                        last_epoch_checkpointed = checkpoint.is_some();

                        if let (Some(es), Some((rmse, _))) = (&sched.early_stop, eval) {
                            let improved = match best_rmse {
                                Some(best) => rmse < best - es.min_delta,
                                None => true,
                            };
                            if improved {
                                strikes = 0;
                            } else {
                                strikes += 1;
                                if strikes >= es.patience {
                                    stopped_early = true;
                                }
                            }
                        }
                        if let Some((rmse, _)) = eval {
                            best_rmse = Some(best_rmse.map_or(rmse, |b| b.min(rmse)));
                        }

                        let ev = EpochEvent {
                            epoch,
                            stats: Some(agg),
                            rmse: eval.map(|e| e.0),
                            mae: eval.map(|e| e.1),
                            lr_a,
                            checkpoint,
                            published: false,
                            cache: None,
                        };
                        observer.on_epoch(&ev);
                        history.push(ev);

                        if stopped_early {
                            let shutdown = Event::Shutdown;
                            if let Some(t) = &tel {
                                t.on_event(coord.ticks(), &shutdown);
                            }
                            coord
                                .apply(&shutdown)
                                .map_err(|e| anyhow!("coordinator rejected Shutdown: {e}"))?;
                        } else {
                            if let Some(decay) = sched.lr_decay {
                                hyper.lr_a *= decay;
                                hyper.lr_b *= decay;
                            }
                            let done = Event::SyncComplete { round };
                            if let Some(t) = &tel {
                                t.on_event(coord.ticks(), &done);
                            }
                            coord
                                .apply(&done)
                                .map_err(|e| anyhow!("coordinator rejected SyncComplete: {e}"))?;
                        }
                        if let Some(t) = &tel {
                            t.barrier_ns.record_duration(barrier_t0.elapsed());
                        }
                    }
                    Directive::Finish => {
                        observer.on_round(&coord.state());
                        break 'drive;
                    }
                }
            }

            if t0.elapsed().as_secs() > WATCHDOG_S {
                // dump the tape first: the flight recorder exists for
                // exactly this moment, and a sink error must not mask
                // the liveness failure
                if let Some(t) = tel.as_mut() {
                    let _ = t.finish();
                }
                bail!(
                    "distributed run exceeded the {WATCHDOG_S}s watchdog in phase {} \
                     (round {}, {} members)",
                    coord.phase().name(),
                    coord.round(),
                    coord.members().len()
                );
            }
        }

        // orderly shutdown: Stop every worker, then surface any worker
        // error or panic (dropping `cmds` unblocks workers even if a
        // Stop send raced a worker exit)
        for tx in cmds.values() {
            let _ = tx.send(WorkerCmd::Stop);
        }
        drop(cmds);
        for h in handles {
            match h.join() {
                Ok(r) => r?,
                Err(p) => std::panic::resume_unwind(p),
            }
        }

        if let Some(path) = &sched.checkpoint {
            if !last_epoch_checkpointed {
                ModelSnapshot::from_model(&global, cfg.algo, epochs_run as u64).save(path)?;
            }
        }

        if let Some(t) = tel.as_mut() {
            t.finish().context("writing dist metrics file")?;
        }

        let report = RunReport {
            epochs_run,
            stopped_early,
            final_rmse: final_eval.map(|e| e.0),
            final_mae: final_eval.map(|e| e.1),
            best_rmse,
            wall_s: t0.elapsed().as_secs_f64(),
            history,
        };
        observer.on_finish(&report);
        Ok(DistRun {
            report,
            model: global,
            final_state: coord.state(),
        })
    })
}

/// Element-wise mean of the members' models, accumulated in `f64`.
/// Callers pass models in ascending member-id order, so the sum order —
/// and therefore the result, bit for bit — is deterministic.  Averaging
/// a single model is the identity (`(f64::from(x) / 1.0) as f32 == x`).
fn average_models(models: &[&TuckerModel]) -> TuckerModel {
    let mut out = models[0].clone();
    let k = models.len() as f64;
    for n in 0..out.factors.len() {
        for (i, slot) in out.factors[n].iter_mut().enumerate() {
            let sum: f64 = models.iter().map(|m| f64::from(m.factors[n][i])).sum();
            *slot = (sum / k) as f32;
        }
        for (i, slot) in out.cores[n].iter_mut().enumerate() {
            let sum: f64 = models.iter().map(|m| f64::from(m.cores[n][i])).sum();
            *slot = (sum / k) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(seed: u64) -> TuckerModel {
        TuckerModel::init_with_mean(&[4, 5, 6], 16, 16, seed, 1.0)
    }

    #[test]
    fn averaging_one_model_is_the_identity() {
        let m = model(3);
        let avg = average_models(&[&m]);
        for n in 0..m.factors.len() {
            assert_eq!(m.factors[n], avg.factors[n]);
            assert_eq!(m.cores[n], avg.cores[n]);
        }
    }

    #[test]
    fn averaging_is_the_elementwise_mean() {
        let a = model(1);
        let b = model(2);
        let avg = average_models(&[&a, &b]);
        let expect = (f64::from(a.factors[0][0]) + f64::from(b.factors[0][0])) / 2.0;
        assert_eq!(avg.factors[0][0], expect as f32);
    }
}
