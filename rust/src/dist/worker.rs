//! The worker side of the distributed loop: train my shard, report back.
//!
//! A worker is deliberately dumb.  It joins, heartbeats from a side
//! thread, and then executes [`WorkerCmd`]s: for each round it wraps its
//! assigned sections in a [`ShardView`], builds a
//! [`Trainer`](crate::coordinator::Trainer) around the model the
//! coordinator handed it, runs exactly one epoch (factor phase + core
//! phase) through the ordinary [`StepBackend`](crate::coordinator::backend::StepBackend)
//! dispatch, and ships the updated model back.  All policy — membership,
//! barriers, averaging, eviction — lives in the coordinator; a worker
//! that dies mid-round simply stops heartbeating and the coordinator
//! routes around it.
//!
//! Determinism: the worker pins `trainer.epoch_no = round` before the
//! phases, so the per-epoch sampler streams (`0x0731 ^ epoch`) and core
//! seeds match what the serial trainer would use at the same epoch — the
//! 1-worker run replays the serial schedule exactly.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{EpochStats, TrainConfig, Trainer};
use crate::cpu_ref::Hyper;
use crate::data::{ShardView, TensorView};
use crate::dist::event::{Event, MemberId};
use crate::model::TuckerModel;

/// How often a live worker heartbeats, in milliseconds.  The local
/// backend's tick is 5 ms and the default timeout is 60 ticks, so a
/// healthy worker gets ~15 chances per timeout window.
pub const HEARTBEAT_MS: u64 = 20;

/// A command from the driver to one worker.
pub enum WorkerCmd {
    /// Train one epoch over `sections` starting from `model`.
    Round {
        /// The round this epoch belongs to (becomes the trainer's
        /// `epoch_no`, so sampling seeds match the serial schedule).
        round: u64,
        /// Section ids this member owns for the round.
        sections: Vec<u32>,
        /// The model to start from (the last averaged global model, or
        /// this member's own model between averaging barriers).
        model: TuckerModel,
        /// Hyper-parameters for the round (carries the driver's
        /// learning-rate decay to every worker).
        hyper: Hyper,
    },
    /// The run is over; exit the loop.
    Stop,
}

/// One finished round: `(member, round, updated model, stats)`.
pub type RoundResult = (MemberId, u64, TuckerModel, EpochStats);

/// Injected failure for the fault tests: die (silently — no
/// `StepComplete`, heartbeats stop) partway through the given round.
#[derive(Clone, Copy, Debug)]
pub struct Fault {
    /// The round to die in.
    pub round: u64,
}

/// Run one worker until `Stop` (or a fault).  Emits `Join` immediately,
/// heartbeats every [`HEARTBEAT_MS`] from a scoped side thread, and for
/// each `Round` sends the result on `done` *before* the `StepComplete`
/// event — so when the coordinator has seen every `StepComplete`, every
/// model is already in the `done` queue.
///
/// Channel sends ignore disconnects: if the driver is gone (e.g. it bailed
/// on an error), the worker just drains to its own exit.
#[allow(clippy::too_many_arguments)] // one call site, in dist::local
pub fn worker_loop(
    member: MemberId,
    base: &dyn TensorView,
    cfg: &TrainConfig,
    section_entries: usize,
    cmd: Receiver<WorkerCmd>,
    events: Sender<Event>,
    done: Sender<RoundResult>,
    fault: Option<Fault>,
) -> Result<()> {
    let _ = events.send(Event::Join { member });
    let alive = AtomicBool::new(true);
    std::thread::scope(|scope| -> Result<()> {
        let hb_events = events.clone();
        let hb_alive = &alive;
        scope.spawn(move || {
            // 2 ms slices so the thread notices `alive` dropping fast and
            // scope teardown never waits a full heartbeat period
            let slices = HEARTBEAT_MS.div_ceil(2).max(1);
            while hb_alive.load(Ordering::Relaxed) {
                for _ in 0..slices {
                    if !hb_alive.load(Ordering::Relaxed) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                if hb_events.send(Event::Heartbeat { member }).is_err() {
                    return;
                }
            }
        });
        let result = run_rounds(member, base, cfg, section_entries, &cmd, &events, &done, fault);
        alive.store(false, Ordering::Relaxed);
        result
    })
}

#[allow(clippy::too_many_arguments)] // private plumbing for worker_loop
fn run_rounds(
    member: MemberId,
    base: &dyn TensorView,
    cfg: &TrainConfig,
    section_entries: usize,
    cmd: &Receiver<WorkerCmd>,
    events: &Sender<Event>,
    done: &Sender<RoundResult>,
    fault: Option<Fault>,
) -> Result<()> {
    while let Ok(command) = cmd.recv() {
        let WorkerCmd::Round {
            round,
            sections,
            model,
            hyper,
        } = command
        else {
            break;
        };
        let shard = ShardView::new(base, &sections, section_entries);
        if shard.nnz() == 0 {
            // nothing to train: echo the model back untouched.  (Running
            // the phases anyway would still apply the regularization
            // decay with zero samples — a silent model change.)
            let _ = done.send((member, round, model, EpochStats::default()));
            let _ = events.send(Event::StepComplete { member, round });
            continue;
        }
        let mut run_cfg = cfg.clone();
        run_cfg.hyper = hyper;
        let mut trainer = Trainer::with_model(&shard, run_cfg, model)?;
        trainer.epoch_no = round;
        let factor = trainer.factor_phase(&shard)?;
        if fault.is_some_and(|f| f.round == round) {
            // simulated crash mid-epoch: no StepComplete, no more
            // heartbeats (worker_loop flips `alive` when we return)
            return Ok(());
        }
        let core = trainer.core_phase(&shard)?;
        let _ = done.send((member, round, trainer.model, EpochStats { factor, core }));
        let _ = events.send(Event::StepComplete { member, round });
    }
    Ok(())
}
