//! The pure, tick-driven coordinator state machine.
//!
//! The coordinator never touches a clock, a thread or a socket: it
//! consumes [`Event`]s ([`Coordinator::apply`]) and a monotonic tick
//! counter ([`Coordinator::tick`]), and emits [`Directive`]s telling the
//! backend what to do.  That makes every run a replayable function of its
//! inputs — the property the tick-table tests in `tests/dist.rs` pin —
//! and means a wire backend only has to move the (JSON-serializable)
//! events and directives to get the same semantics.
//!
//! Lifecycle:
//!
//! ```text
//! WaitingForMembers --quorum--> Warmup --warmup_ticks--> Train
//!       Train --all StepComplete--> Sync --SyncComplete--> Train (next round)
//!       Sync --last round--> Done
//! ```
//!
//! Liveness: members heartbeat; in `Warmup`/`Train` a member silent for
//! more than [`DistConfig::heartbeat_timeout_ticks`] ticks is evicted
//! ([`Directive::Evict`]) and its shards return to the pool at the next
//! `BeginRound` — the round in flight completes over the survivors'
//! shards only (the dropped shards' entries miss one round of updates,
//! which SGD tolerates; the fault-injection test bounds the effect).
//! Every member's liveness window resets when a phase the *backend*
//! spends time in ends (quorum → Warmup, and each `BeginRound`), so
//! ticks the backend burned on barrier work — averaging, evaluation,
//! checkpointing — are never judged as member silence.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::dist::event::{CoordinatorState, Directive, DistConfig, DistPhase, Event, MemberId};
use crate::dist::shard;

/// Why [`Coordinator::apply`] rejected an event — the tick-table tests
/// assert exactly which (phase, event) pairs are legal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EventError {
    /// A `Join` arrived after the membership window closed.
    JoinClosed {
        /// The member that tried to join.
        member: MemberId,
        /// The phase the coordinator was in.
        phase: DistPhase,
    },
    /// An event referenced a member the coordinator does not know (never
    /// joined, or already evicted).
    UnknownMember {
        /// The unknown member.
        member: MemberId,
    },
    /// The event is not legal in the current phase.
    WrongPhase {
        /// The event's kind tag.
        event: &'static str,
        /// The phase the coordinator was in.
        phase: DistPhase,
    },
    /// A `StepComplete`/`SyncComplete` for a round other than the current
    /// one (a late or duplicated message).
    WrongRound {
        /// The round the event claimed.
        got: u64,
        /// The coordinator's current round.
        want: u64,
    },
}

impl fmt::Display for EventError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EventError::JoinClosed { member, phase } => write!(
                f,
                "member {member} cannot join during {} (joins close at warmup)",
                phase.name()
            ),
            EventError::UnknownMember { member } => {
                write!(f, "unknown member {member} (never joined, or evicted)")
            }
            EventError::WrongPhase { event, phase } => {
                write!(f, "event {event:?} is not legal in phase {}", phase.name())
            }
            EventError::WrongRound { got, want } => {
                write!(f, "event for round {got}, but the current round is {want}")
            }
        }
    }
}

impl std::error::Error for EventError {}

/// The coordinator: owns the membership table, the round counter and the
/// phase, and nothing else.  See the module docs for the lifecycle.
#[derive(Clone, Debug)]
pub struct Coordinator {
    cfg: DistConfig,
    phase: DistPhase,
    tick: u64,
    round: u64,
    /// member → tick of its last sign of life (join, heartbeat, step).
    members: BTreeMap<MemberId, u64>,
    completed: BTreeSet<MemberId>,
    warmup_started: u64,
    sync_done: bool,
    finish_requested: bool,
}

impl Coordinator {
    /// A fresh coordinator in `WaitingForMembers`.
    pub fn new(cfg: DistConfig) -> Coordinator {
        Coordinator {
            cfg,
            phase: DistPhase::WaitingForMembers,
            tick: 0,
            round: 0,
            members: BTreeMap::new(),
            completed: BTreeSet::new(),
            warmup_started: 0,
            sync_done: false,
            finish_requested: false,
        }
    }

    /// Current phase.
    pub fn phase(&self) -> DistPhase {
        self.phase
    }

    /// Current round (0-based).
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Ticks elapsed so far.
    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// Live members, sorted by id.
    pub fn members(&self) -> Vec<MemberId> {
        self.members.keys().copied().collect()
    }

    /// The static configuration this coordinator runs.
    pub fn config(&self) -> &DistConfig {
        &self.cfg
    }

    /// Observable snapshot (for the [`crate::session::Observer`] stream
    /// and, later, a wire status endpoint).
    pub fn state(&self) -> CoordinatorState {
        CoordinatorState {
            phase: self.phase,
            tick: self.tick,
            round: self.round,
            members: self.members(),
            completed: self.completed.iter().copied().collect(),
            n_sections: self.cfg.n_sections,
        }
    }

    /// Feed one event in.  Legal (phase, event) pairs — the tick-table:
    ///
    /// | event          | Waiting | Warmup | Train | Sync | Done |
    /// |----------------|---------|--------|-------|------|------|
    /// | `Join`         | ok      | err    | err   | err  | err  |
    /// | `Heartbeat`    | ok*     | ok*    | ok*   | ok*  | ok*  |
    /// | `StepComplete` | err     | err    | ok*†  | err  | err  |
    /// | `SyncComplete` | err     | err    | err   | ok†  | err  |
    /// | `Shutdown`     | ok      | ok     | ok    | ok   | ok   |
    ///
    /// `*` known members only; `†` current round only.  Rejected events
    /// change nothing — the backend logs and drops them (a late heartbeat
    /// from an evicted worker is expected traffic, not a bug).
    pub fn apply(&mut self, event: &Event) -> Result<(), EventError> {
        match event {
            Event::Join { member } => {
                if self.phase != DistPhase::WaitingForMembers {
                    return Err(EventError::JoinClosed {
                        member: *member,
                        phase: self.phase,
                    });
                }
                self.members.insert(*member, self.tick);
                Ok(())
            }
            Event::Heartbeat { member } => match self.members.get_mut(member) {
                Some(last_seen) => {
                    *last_seen = self.tick;
                    Ok(())
                }
                None => Err(EventError::UnknownMember { member: *member }),
            },
            Event::StepComplete { member, round } => {
                if self.phase != DistPhase::Train {
                    return Err(EventError::WrongPhase {
                        event: event.kind(),
                        phase: self.phase,
                    });
                }
                if *round != self.round {
                    return Err(EventError::WrongRound {
                        got: *round,
                        want: self.round,
                    });
                }
                match self.members.get_mut(member) {
                    Some(last_seen) => {
                        *last_seen = self.tick; // a finished step is proof of life
                        self.completed.insert(*member);
                        Ok(())
                    }
                    None => Err(EventError::UnknownMember { member: *member }),
                }
            }
            Event::SyncComplete { round } => {
                if self.phase != DistPhase::Sync {
                    return Err(EventError::WrongPhase {
                        event: event.kind(),
                        phase: self.phase,
                    });
                }
                if *round != self.round {
                    return Err(EventError::WrongRound {
                        got: *round,
                        want: self.round,
                    });
                }
                self.sync_done = true;
                Ok(())
            }
            Event::Shutdown => {
                self.finish_requested = true;
                Ok(())
            }
        }
    }

    /// Advance time by one tick and return the directives that fall out.
    /// This is the only place phase transitions happen, so the backend's
    /// loop is: drain events → tick until caught up → obey directives.
    pub fn tick(&mut self) -> Vec<Directive> {
        self.tick += 1;
        let mut out = Vec::new();
        if self.finish_requested && self.phase != DistPhase::Done {
            self.finish(&mut out);
            return out;
        }
        match self.phase {
            DistPhase::WaitingForMembers => {
                if self.members.len() >= self.cfg.min_members.max(1) {
                    self.phase = DistPhase::Warmup;
                    self.warmup_started = self.tick;
                    // everyone gets a fresh liveness window: time spent
                    // waiting for the quorum is not heartbeat silence
                    for last_seen in self.members.values_mut() {
                        *last_seen = self.tick;
                    }
                    out.push(Directive::EnterWarmup);
                }
            }
            DistPhase::Warmup => {
                self.evict_stale(&mut out);
                if self.members.is_empty() {
                    self.finish(&mut out);
                } else if self.tick - self.warmup_started >= self.cfg.warmup_ticks {
                    self.begin_round(&mut out);
                }
            }
            DistPhase::Train => {
                self.evict_stale(&mut out);
                if self.members.is_empty() {
                    self.finish(&mut out);
                } else if self.members.keys().all(|m| self.completed.contains(m)) {
                    self.phase = DistPhase::Sync;
                    self.sync_done = false;
                    let last_round = self.round + 1 >= self.cfg.rounds;
                    out.push(Directive::RunSync {
                        round: self.round,
                        members: self.members(),
                        // the averaging cadence, with the final barrier
                        // always averaging so the run ends on one model
                        average: last_round
                            || (self.round + 1) % self.cfg.sync_every.max(1) == 0,
                    });
                }
            }
            DistPhase::Sync => {
                // no evictions here: the barrier is backend work, and the
                // members are idle-but-heartbeating while it runs
                if self.sync_done {
                    if self.round + 1 >= self.cfg.rounds {
                        self.finish(&mut out);
                    } else {
                        self.round += 1;
                        self.begin_round(&mut out);
                    }
                }
            }
            DistPhase::Done => {}
        }
        out
    }

    fn begin_round(&mut self, out: &mut Vec<Directive>) {
        self.phase = DistPhase::Train;
        self.completed.clear();
        // a fresh liveness window for everyone, exactly like the Warmup
        // entry: the ticks just spent were barrier work on the *backend's*
        // side (model collection, averaging, eval, checkpointing), so they
        // must not count as heartbeat silence against the members
        for last_seen in self.members.values_mut() {
            *last_seen = self.tick;
        }
        let members = self.members();
        out.push(Directive::BeginRound {
            round: self.round,
            assignment: shard::assign(self.cfg.seed, self.round, self.cfg.n_sections, &members),
        });
    }

    fn finish(&mut self, out: &mut Vec<Directive>) {
        self.phase = DistPhase::Done;
        out.push(Directive::Finish);
    }

    fn evict_stale(&mut self, out: &mut Vec<Directive>) {
        let timeout = self.cfg.heartbeat_timeout_ticks;
        let now = self.tick;
        let dead: Vec<MemberId> = self
            .members
            .iter()
            .filter(|(_, &last_seen)| now.saturating_sub(last_seen) > timeout)
            .map(|(&m, _)| m)
            .collect();
        for m in dead {
            self.members.remove(&m);
            self.completed.remove(&m);
            out.push(Directive::Evict { member: m });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(min_members: usize, rounds: u64) -> DistConfig {
        DistConfig {
            min_members,
            warmup_ticks: 2,
            heartbeat_timeout_ticks: 5,
            rounds,
            sync_every: 1,
            seed: 7,
            n_sections: 8,
        }
    }

    /// Tick until a directive appears (bounded, so a logic bug fails the
    /// test instead of hanging it).
    fn tick_until(c: &mut Coordinator, max: u64) -> Vec<Directive> {
        for _ in 0..max {
            let d = c.tick();
            if !d.is_empty() {
                return d;
            }
        }
        Vec::new()
    }

    #[test]
    fn happy_path_two_members_two_rounds() {
        let mut c = Coordinator::new(cfg(2, 2));
        assert_eq!(c.phase(), DistPhase::WaitingForMembers);
        c.apply(&Event::Join { member: 2 }).unwrap();
        assert!(c.tick().is_empty(), "below quorum: nothing happens");
        c.apply(&Event::Join { member: 1 }).unwrap();
        assert_eq!(tick_until(&mut c, 4), vec![Directive::EnterWarmup]);
        assert_eq!(c.phase(), DistPhase::Warmup);

        let d = tick_until(&mut c, 4);
        let Directive::BeginRound { round: 0, assignment } = &d[0] else {
            panic!("expected BeginRound, got {d:?}");
        };
        assert_eq!(assignment.shards.len(), 2);
        assert_eq!(c.phase(), DistPhase::Train);

        // keep both alive, finish the round
        c.apply(&Event::Heartbeat { member: 1 }).unwrap();
        c.apply(&Event::StepComplete { member: 1, round: 0 }).unwrap();
        assert!(c.tick().is_empty(), "one member still training");
        c.apply(&Event::StepComplete { member: 2, round: 0 }).unwrap();
        let d = tick_until(&mut c, 2);
        assert_eq!(
            d,
            vec![Directive::RunSync {
                round: 0,
                members: vec![1, 2],
                average: true,
            }]
        );
        assert_eq!(c.phase(), DistPhase::Sync);

        c.apply(&Event::SyncComplete { round: 0 }).unwrap();
        let d = tick_until(&mut c, 2);
        assert!(matches!(d[0], Directive::BeginRound { round: 1, .. }));

        c.apply(&Event::StepComplete { member: 1, round: 1 }).unwrap();
        c.apply(&Event::StepComplete { member: 2, round: 1 }).unwrap();
        tick_until(&mut c, 2);
        c.apply(&Event::SyncComplete { round: 1 }).unwrap();
        assert_eq!(tick_until(&mut c, 2), vec![Directive::Finish]);
        assert_eq!(c.phase(), DistPhase::Done);
    }

    #[test]
    fn heartbeat_timeout_evicts_and_barrier_proceeds() {
        let mut c = Coordinator::new(cfg(2, 1));
        c.apply(&Event::Join { member: 1 }).unwrap();
        c.apply(&Event::Join { member: 2 }).unwrap();
        tick_until(&mut c, 4); // warmup
        tick_until(&mut c, 4); // round 0
        c.apply(&Event::StepComplete { member: 1, round: 0 }).unwrap();
        // member 2 goes silent; member 1 keeps heartbeating
        let mut saw_evict = false;
        for _ in 0..20 {
            c.apply(&Event::Heartbeat { member: 1 }).unwrap();
            let d = c.tick();
            if d.contains(&Directive::Evict { member: 2 }) {
                saw_evict = true;
                // the survivor already completed, so the same tick (or the
                // next) must reach the barrier over the survivors only
                let sync = if d.iter().any(|x| matches!(x, Directive::RunSync { .. })) {
                    d
                } else {
                    c.tick()
                };
                assert!(
                    sync.iter().any(|x| matches!(
                        x,
                        Directive::RunSync { members, .. } if members == &vec![1]
                    )),
                    "barrier should run over the survivors, got {sync:?}"
                );
                break;
            }
        }
        assert!(saw_evict, "silent member was never evicted");
        // the evicted member is gone for good
        assert_eq!(
            c.apply(&Event::Heartbeat { member: 2 }),
            Err(EventError::UnknownMember { member: 2 })
        );
    }

    #[test]
    fn barrier_stall_is_not_heartbeat_silence() {
        // Regression: members heartbeat last just before the barrier,
        // then the backend stalls in Sync (a big eval, a checkpoint
        // save) for far longer than the heartbeat timeout.  The next
        // round must open a fresh liveness window instead of evicting
        // everyone for silence that was really driver-side work.
        let mut c = Coordinator::new(cfg(2, 2));
        c.apply(&Event::Join { member: 1 }).unwrap();
        c.apply(&Event::Join { member: 2 }).unwrap();
        tick_until(&mut c, 4); // warmup
        tick_until(&mut c, 4); // round 0 deal
        c.apply(&Event::StepComplete { member: 1, round: 0 }).unwrap();
        c.apply(&Event::StepComplete { member: 2, round: 0 }).unwrap();
        tick_until(&mut c, 2);
        assert_eq!(c.phase(), DistPhase::Sync);
        for _ in 0..50 {
            assert!(c.tick().is_empty(), "Sync must neither evict nor act");
        }
        c.apply(&Event::SyncComplete { round: 0 }).unwrap();
        let d = tick_until(&mut c, 2);
        assert!(
            matches!(d[0], Directive::BeginRound { round: 1, .. }),
            "expected the next round to begin, got {d:?}"
        );
        // only *new* silence counts: a full timeout must elapse before
        // anyone is evicted
        let timeout = c.config().heartbeat_timeout_ticks;
        for _ in 0..timeout {
            assert!(
                c.tick().is_empty(),
                "barrier-stall ticks were counted as heartbeat silence"
            );
        }
        assert_eq!(c.members(), vec![1, 2]);
    }

    #[test]
    fn all_members_lost_finishes_the_run() {
        let mut c = Coordinator::new(cfg(1, 3));
        c.apply(&Event::Join { member: 9 }).unwrap();
        tick_until(&mut c, 4);
        tick_until(&mut c, 4);
        assert_eq!(c.phase(), DistPhase::Train);
        // silence: ticks pass, nobody heartbeats
        let mut out = Vec::new();
        for _ in 0..20 {
            out.extend(c.tick());
            if c.phase() == DistPhase::Done {
                break;
            }
        }
        assert!(out.contains(&Directive::Evict { member: 9 }));
        assert!(out.contains(&Directive::Finish));
    }

    #[test]
    fn shutdown_finishes_from_any_phase() {
        let mut c = Coordinator::new(cfg(1, 5));
        c.apply(&Event::Join { member: 1 }).unwrap();
        tick_until(&mut c, 4);
        tick_until(&mut c, 4);
        assert_eq!(c.phase(), DistPhase::Train);
        c.apply(&Event::Shutdown).unwrap();
        assert_eq!(c.tick(), vec![Directive::Finish]);
        assert_eq!(c.phase(), DistPhase::Done);
        assert!(c.tick().is_empty(), "Finish is emitted exactly once");
    }

    #[test]
    fn sync_every_cadence_controls_average_flag() {
        let mut c = Coordinator::new(DistConfig {
            sync_every: 2,
            rounds: 3,
            ..cfg(1, 3)
        });
        c.apply(&Event::Join { member: 1 }).unwrap();
        tick_until(&mut c, 4);
        tick_until(&mut c, 4);
        let mut averages = Vec::new();
        for round in 0..3 {
            c.apply(&Event::StepComplete { member: 1, round }).unwrap();
            let d = tick_until(&mut c, 4);
            let Directive::RunSync { average, .. } = d[0] else {
                panic!("expected RunSync, got {d:?}");
            };
            averages.push(average);
            c.apply(&Event::SyncComplete { round }).unwrap();
            tick_until(&mut c, 4);
        }
        // rounds are 0-based: barrier after round 1 hits the cadence, and
        // the final barrier always averages
        assert_eq!(averages, vec![false, true, true]);
    }
}
