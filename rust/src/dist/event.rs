//! The distributed layer's wire surface: every event a member can send,
//! every directive the coordinator can emit, and the coordinator's
//! observable state — all round-tripping losslessly through the in-tree
//! [`crate::util::json`] so a TCP wire layer is a drop-in later (the
//! in-process backend in [`crate::dist::local`] passes these same types
//! over channels today).
//!
//! Nothing here touches the clock: time is a monotonic *tick* counter the
//! backend advances ([`crate::dist::Coordinator::tick`]), so the state
//! machine is a pure function of (events, ticks) and every run replays.

use std::fmt;

use crate::util::json::{self, Json};

/// Stable identity of one worker in a run.  The in-process backend hands
/// out small consecutive ids; a wire backend can derive them from
/// connection handshakes — the coordinator only ever orders and compares
/// them (deterministic shard assignment sorts by id).
pub type MemberId = u64;

// ======================================================================
// JSON field helpers (shared by every type in this module)
// ======================================================================

/// u64 → JSON, lossless: exactly-representable values as numbers, larger
/// ones as decimal strings (the in-tree parser stores numbers as f64).
fn num_u64(v: u64) -> Json {
    if v <= (1u64 << 53) {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

/// One JSON value as a u64 — the single parser behind scalars and list
/// elements, so both enforce the same bound: numbers must be
/// non-negative integers at or below 2^53 (exactly representable in the
/// parser's f64), anything larger must arrive as a decimal string.
fn parse_u64(v: &Json, key: &str) -> Result<u64, String> {
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= (1u64 << 53) as f64 => {
            Ok(*n as u64)
        }
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| format!("field {key:?}: bad u64 string {s:?}")),
        other => Err(format!("field {key:?}: expected a u64, got {other:?}")),
    }
}

fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    parse_u64(v.get(key).ok_or_else(|| format!("missing field {key:?}"))?, key)
}

fn get_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    v.get(key)
        .ok_or_else(|| format!("missing field {key:?}"))?
        .as_str()
        .ok_or_else(|| format!("field {key:?}: expected a string"))
}

fn get_u64_list(v: &Json, key: &str) -> Result<Vec<u64>, String> {
    let arr = v
        .get(key)
        .ok_or_else(|| format!("missing field {key:?}"))?
        .as_arr()
        .ok_or_else(|| format!("field {key:?}: expected an array"))?;
    arr.iter().map(|e| parse_u64(e, key)).collect()
}

fn u64_list(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| num_u64(x)).collect())
}

// ======================================================================
// Events (member → coordinator)
// ======================================================================

/// One input to the coordinator state machine.  Events carry everything
/// the coordinator learns about the outside world; combined with the tick
/// counter they fully determine its behavior.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// A member announces itself (legal only while the coordinator is
    /// waiting for the quorum — late joins are rejected in this PR).
    Join {
        /// The joining member.
        member: MemberId,
    },
    /// Proof of life.  A member that misses
    /// [`DistConfig::heartbeat_timeout_ticks`] consecutive ticks of
    /// heartbeats is evicted at the next tick and its shards are
    /// reassigned at the next round barrier.
    Heartbeat {
        /// The member reporting in.
        member: MemberId,
    },
    /// A member finished its assigned shards for `round` (one full
    /// factor+core epoch over its ranges).
    StepComplete {
        /// The member that finished.
        member: MemberId,
        /// The round it finished (must match the coordinator's).
        round: u64,
    },
    /// The backend finished the barrier work (model collection, factor
    /// averaging, redistribution) for `round`.
    SyncComplete {
        /// The synced round.
        round: u64,
    },
    /// Orderly teardown request from the backend (early stopping, operator
    /// abort).  Legal in every phase; the next tick finishes the run.
    Shutdown,
}

impl Event {
    /// The variant tag used in the JSON encoding (and error messages).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Join { .. } => "join",
            Event::Heartbeat { .. } => "heartbeat",
            Event::StepComplete { .. } => "step_complete",
            Event::SyncComplete { .. } => "sync_complete",
            Event::Shutdown => "shutdown",
        }
    }

    /// Serialize (the future wire encoding).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("kind", json::s(self.kind()))];
        match self {
            Event::Join { member } | Event::Heartbeat { member } => {
                fields.push(("member", num_u64(*member)));
            }
            Event::StepComplete { member, round } => {
                fields.push(("member", num_u64(*member)));
                fields.push(("round", num_u64(*round)));
            }
            Event::SyncComplete { round } => fields.push(("round", num_u64(*round))),
            Event::Shutdown => {}
        }
        json::obj(fields)
    }

    /// Parse (inverse of [`Event::to_json`]).
    pub fn from_json(v: &Json) -> Result<Event, String> {
        Ok(match get_str(v, "kind")? {
            "join" => Event::Join {
                member: get_u64(v, "member")?,
            },
            "heartbeat" => Event::Heartbeat {
                member: get_u64(v, "member")?,
            },
            "step_complete" => Event::StepComplete {
                member: get_u64(v, "member")?,
                round: get_u64(v, "round")?,
            },
            "sync_complete" => Event::SyncComplete {
                round: get_u64(v, "round")?,
            },
            "shutdown" => Event::Shutdown,
            other => return Err(format!("unknown event kind {other:?}")),
        })
    }
}

// ======================================================================
// Phases
// ======================================================================

/// The coordinator's lifecycle, a one-way street:
/// `WaitingForMembers → Warmup → (Train ⇄ Sync)* → Done`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistPhase {
    /// Accepting joins until the quorum ([`DistConfig::min_members`]).
    WaitingForMembers,
    /// Quorum reached; members settle for
    /// [`DistConfig::warmup_ticks`] ticks before the first round.
    Warmup,
    /// A round is in flight: members train their assigned shards.
    Train,
    /// Round barrier reached: the backend averages/redistributes factors.
    Sync,
    /// The run is over (all rounds done, shutdown, or no members left).
    Done,
}

impl DistPhase {
    /// Canonical name (`parse(name()) == Some(self)`).
    pub fn name(self) -> &'static str {
        match self {
            DistPhase::WaitingForMembers => "waiting_for_members",
            DistPhase::Warmup => "warmup",
            DistPhase::Train => "train",
            DistPhase::Sync => "sync",
            DistPhase::Done => "done",
        }
    }

    /// Parse a serialized phase name.
    pub fn parse(s: &str) -> Option<DistPhase> {
        match s {
            "waiting_for_members" => Some(DistPhase::WaitingForMembers),
            "warmup" => Some(DistPhase::Warmup),
            "train" => Some(DistPhase::Train),
            "sync" => Some(DistPhase::Sync),
            "done" => Some(DistPhase::Done),
            _ => None,
        }
    }

    /// Every phase, in lifecycle order (tick-table tests iterate this).
    pub const ALL: [DistPhase; 5] = [
        DistPhase::WaitingForMembers,
        DistPhase::Warmup,
        DistPhase::Train,
        DistPhase::Sync,
        DistPhase::Done,
    ];
}

// ======================================================================
// Shard assignment
// ======================================================================

/// One round's seeded deterministic mapping of section ids to members.
///
/// Sections are the shard unit: FTB2 store pages for out-of-core runs,
/// fixed-size entry-id ranges for in-RAM tensors (see
/// [`crate::data::ShardView`]).  The assignment is a pure function of
/// `(seed, round, n_sections, membership set)` — reproducible from the
/// seed alone and invariant to join order, pinned by `tests/dist.rs`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardAssignment {
    /// The round this assignment is for.
    pub round: u64,
    /// Total sections dealt (every id in `0..n_sections` appears exactly
    /// once across all members).
    pub n_sections: u32,
    /// `(member, its sorted section ids)`, sorted by member id.
    pub shards: Vec<(MemberId, Vec<u32>)>,
}

impl ShardAssignment {
    /// The sections assigned to `member` (empty when unknown).
    pub fn sections_for(&self, member: MemberId) -> &[u32] {
        self.shards
            .iter()
            .find(|(m, _)| *m == member)
            .map(|(_, s)| s.as_slice())
            .unwrap_or(&[])
    }

    /// Serialize.
    pub fn to_json(&self) -> Json {
        let shards = self
            .shards
            .iter()
            .map(|(m, sections)| {
                json::obj(vec![
                    ("member", num_u64(*m)),
                    (
                        "sections",
                        Json::Arr(sections.iter().map(|&s| json::num(s as f64)).collect()),
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("round", num_u64(self.round)),
            ("n_sections", json::num(self.n_sections as f64)),
            ("shards", Json::Arr(shards)),
        ])
    }

    /// Parse (inverse of [`ShardAssignment::to_json`]).
    pub fn from_json(v: &Json) -> Result<ShardAssignment, String> {
        let arr = v
            .get("shards")
            .ok_or("missing field \"shards\"")?
            .as_arr()
            .ok_or("field \"shards\": expected an array")?;
        let mut shards = Vec::with_capacity(arr.len());
        for entry in arr {
            let member = get_u64(entry, "member")?;
            let sections = entry
                .get("sections")
                .ok_or("missing field \"sections\"")?
                .as_arr()
                .ok_or("field \"sections\": expected an array")?
                .iter()
                .map(|s| {
                    s.as_usize()
                        .map(|x| x as u32)
                        .ok_or_else(|| "field \"sections\": expected integers".to_string())
                })
                .collect::<Result<Vec<u32>, String>>()?;
            shards.push((member, sections));
        }
        Ok(ShardAssignment {
            round: get_u64(v, "round")?,
            n_sections: get_u64(v, "n_sections")? as u32,
            shards,
        })
    }
}

// ======================================================================
// Directives (coordinator → backend)
// ======================================================================

/// One instruction [`crate::dist::Coordinator::tick`] hands the backend.
/// The coordinator never performs work itself — it tells the backend what
/// to do and learns the outcome through events, so the core stays pure.
#[derive(Clone, Debug, PartialEq)]
pub enum Directive {
    /// Quorum reached; the run is warming up.
    EnterWarmup,
    /// Start round `round`: deliver each member its shards (and the
    /// current global model).
    BeginRound {
        /// The round beginning now.
        round: u64,
        /// Who trains which sections this round.
        assignment: ShardAssignment,
    },
    /// All live members finished `round`: run the barrier.  `average`
    /// says whether this barrier exchanges factors
    /// ([`DistConfig::sync_every`] cadence — the final round always
    /// averages so the run ends on one agreed model).
    RunSync {
        /// The round being synced.
        round: u64,
        /// The live membership at the barrier, sorted by id — the models
        /// to collect and average.
        members: Vec<MemberId>,
        /// Whether this barrier averages + redistributes factors.
        average: bool,
    },
    /// `member` missed its heartbeat window and is out of the run; its
    /// shards return to the pool at the next `BeginRound`.
    Evict {
        /// The evicted member.
        member: MemberId,
    },
    /// The run is over; tear the workers down.
    Finish,
}

impl Directive {
    /// The variant tag used in the JSON encoding.
    pub fn kind(&self) -> &'static str {
        match self {
            Directive::EnterWarmup => "enter_warmup",
            Directive::BeginRound { .. } => "begin_round",
            Directive::RunSync { .. } => "run_sync",
            Directive::Evict { .. } => "evict",
            Directive::Finish => "finish",
        }
    }

    /// Serialize.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("kind", json::s(self.kind()))];
        match self {
            Directive::EnterWarmup | Directive::Finish => {}
            Directive::BeginRound { round, assignment } => {
                fields.push(("round", num_u64(*round)));
                fields.push(("assignment", assignment.to_json()));
            }
            Directive::RunSync {
                round,
                members,
                average,
            } => {
                fields.push(("round", num_u64(*round)));
                fields.push(("members", u64_list(members)));
                fields.push(("average", Json::Bool(*average)));
            }
            Directive::Evict { member } => fields.push(("member", num_u64(*member))),
        }
        json::obj(fields)
    }

    /// Parse (inverse of [`Directive::to_json`]).
    pub fn from_json(v: &Json) -> Result<Directive, String> {
        Ok(match get_str(v, "kind")? {
            "enter_warmup" => Directive::EnterWarmup,
            "begin_round" => Directive::BeginRound {
                round: get_u64(v, "round")?,
                assignment: ShardAssignment::from_json(
                    v.get("assignment").ok_or("missing field \"assignment\"")?,
                )?,
            },
            "run_sync" => Directive::RunSync {
                round: get_u64(v, "round")?,
                members: get_u64_list(v, "members")?,
                average: v
                    .get("average")
                    .and_then(|b| b.as_bool())
                    .ok_or("field \"average\": expected a bool")?,
            },
            "evict" => Directive::Evict {
                member: get_u64(v, "member")?,
            },
            "finish" => Directive::Finish,
            other => return Err(format!("unknown directive kind {other:?}")),
        })
    }
}

// ======================================================================
// Config + observable state
// ======================================================================

/// Static parameters of one distributed run.  Everything is in *ticks*
/// and *rounds* — the backend decides how long a tick is (the in-process
/// backend maps 1 tick ≈ 5 ms of wall time; a test harness can tick a
/// coordinator by hand).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DistConfig {
    /// Members required before the run leaves `WaitingForMembers`.
    pub min_members: usize,
    /// Ticks spent in `Warmup` once the quorum is reached.
    pub warmup_ticks: u64,
    /// Ticks of heartbeat silence tolerated before eviction.
    pub heartbeat_timeout_ticks: u64,
    /// Rounds to run (each round = one full collective pass over the
    /// training entries, i.e. one epoch of the serial trainer).
    pub rounds: u64,
    /// Factor averaging cadence: barriers exchange factors every this
    /// many rounds (1 = every barrier; the final barrier always does).
    pub sync_every: u64,
    /// Seed for the deterministic shard assignment.
    pub seed: u64,
    /// Sections being dealt (store pages, or computed entry ranges).
    pub n_sections: u32,
}

impl Default for DistConfig {
    fn default() -> Self {
        Self {
            min_members: 1,
            warmup_ticks: 2,
            heartbeat_timeout_ticks: 60,
            rounds: 1,
            sync_every: 1,
            seed: 42,
            n_sections: 1,
        }
    }
}

impl DistConfig {
    /// Serialize.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("min_members", json::num(self.min_members as f64)),
            ("warmup_ticks", num_u64(self.warmup_ticks)),
            (
                "heartbeat_timeout_ticks",
                num_u64(self.heartbeat_timeout_ticks),
            ),
            ("rounds", num_u64(self.rounds)),
            ("sync_every", num_u64(self.sync_every)),
            ("seed", num_u64(self.seed)),
            ("n_sections", json::num(self.n_sections as f64)),
        ])
    }

    /// Parse (inverse of [`DistConfig::to_json`]).
    pub fn from_json(v: &Json) -> Result<DistConfig, String> {
        Ok(DistConfig {
            min_members: get_u64(v, "min_members")? as usize,
            warmup_ticks: get_u64(v, "warmup_ticks")?,
            heartbeat_timeout_ticks: get_u64(v, "heartbeat_timeout_ticks")?,
            rounds: get_u64(v, "rounds")?,
            sync_every: get_u64(v, "sync_every")?,
            seed: get_u64(v, "seed")?,
            n_sections: get_u64(v, "n_sections")? as u32,
        })
    }
}

/// A snapshot of the coordinator for observers and logs (surfaced through
/// [`crate::session::Observer::on_round`] and serializable for a wire
/// status endpoint later).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CoordinatorState {
    /// Current lifecycle phase.
    pub phase: DistPhase,
    /// Ticks elapsed since construction.
    pub tick: u64,
    /// Current round (0-based; meaningful from the first `Train` on).
    pub round: u64,
    /// Live members, sorted by id.
    pub members: Vec<MemberId>,
    /// Members that completed the current round so far, sorted by id.
    pub completed: Vec<MemberId>,
    /// Sections being dealt each round.
    pub n_sections: u32,
}

impl fmt::Display for CoordinatorState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "phase {} round {} ({}/{} members done, tick {})",
            self.phase.name(),
            self.round,
            self.completed.len(),
            self.members.len(),
            self.tick
        )
    }
}

impl CoordinatorState {
    /// Serialize.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("phase", json::s(self.phase.name())),
            ("tick", num_u64(self.tick)),
            ("round", num_u64(self.round)),
            ("members", u64_list(&self.members)),
            ("completed", u64_list(&self.completed)),
            ("n_sections", json::num(self.n_sections as f64)),
        ])
    }

    /// Parse (inverse of [`CoordinatorState::to_json`]).
    pub fn from_json(v: &Json) -> Result<CoordinatorState, String> {
        let phase_name = get_str(v, "phase")?;
        Ok(CoordinatorState {
            phase: DistPhase::parse(phase_name)
                .ok_or_else(|| format!("unknown phase {phase_name:?}"))?,
            tick: get_u64(v, "tick")?,
            round: get_u64(v, "round")?,
            members: get_u64_list(v, "members")?,
            completed: get_u64_list(v, "completed")?,
            n_sections: get_u64(v, "n_sections")? as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_roundtrip() {
        for ev in [
            Event::Join { member: 3 },
            Event::Heartbeat { member: u64::MAX },
            Event::StepComplete {
                member: 1,
                round: 7,
            },
            Event::SyncComplete { round: 2 },
            Event::Shutdown,
        ] {
            let text = ev.to_json().dump();
            let back = Event::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, ev, "through {text}");
        }
    }

    #[test]
    fn phase_names_roundtrip() {
        for p in DistPhase::ALL {
            assert_eq!(DistPhase::parse(p.name()), Some(p));
        }
        assert_eq!(DistPhase::parse("nope"), None);
    }

    #[test]
    fn config_and_state_roundtrip() {
        let cfg = DistConfig {
            min_members: 4,
            warmup_ticks: 3,
            heartbeat_timeout_ticks: 99,
            rounds: 12,
            sync_every: 2,
            seed: u64::MAX - 1, // exercises the string fallback
            n_sections: 37,
        };
        let back = DistConfig::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, cfg);

        let st = CoordinatorState {
            phase: DistPhase::Sync,
            tick: 1234,
            round: 5,
            members: vec![1, 2, 9],
            completed: vec![2],
            n_sections: 37,
        };
        let back =
            CoordinatorState::from_json(&Json::parse(&st.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, st);
        assert!(st.to_string().contains("sync"));
    }

    #[test]
    fn numbers_above_2_pow_53_are_rejected_as_scalars_and_list_elements() {
        // beyond 2^53 a JSON number is no longer exactly representable in
        // the parser's f64, so it must arrive as a decimal string — both
        // as a scalar and inside a list (a member id in `members` would
        // otherwise round-trip silently truncated)
        let big = ((1u64 << 53) + 2) as f64;
        let err = Event::from_json(&json::obj(vec![
            ("kind", json::s("join")),
            ("member", Json::Num(big)),
        ]))
        .unwrap_err();
        assert!(err.contains("member"), "{err}");

        let st = json::obj(vec![
            ("phase", json::s("train")),
            ("tick", Json::Num(1.0)),
            ("round", Json::Num(0.0)),
            ("members", Json::Arr(vec![Json::Num(big)])),
            ("completed", Json::Arr(Vec::new())),
            ("n_sections", Json::Num(4.0)),
        ]);
        let err = CoordinatorState::from_json(&st).unwrap_err();
        assert!(err.contains("members"), "{err}");
    }

    #[test]
    fn bad_json_is_rejected_with_field_names() {
        let err = Event::from_json(&json::obj(vec![("kind", json::s("join"))])).unwrap_err();
        assert!(err.contains("member"), "{err}");
        let err = Event::from_json(&json::obj(vec![("kind", json::s("warp"))])).unwrap_err();
        assert!(err.contains("warp"), "{err}");
    }
}
