//! AVX2 + FMA implementations of the SIMD primitives (x86_64 only).
//!
//! Every function carries `#[target_feature(enable = "avx2", enable =
//! "fma")]` and is `unsafe` to call: the dispatcher in [`super`] only
//! routes here after `is_x86_feature_detected!` confirmed both features
//! at runtime, which is the entire safety contract.  Bodies process
//! 8-lane `__m256` chunks with unaligned loads (`_mm256_loadu_ps`) and
//! fused multiply-add (`_mm256_fmadd_ps`); remainders run scalar.
//! Reductions fold the 8 lanes ascending, matching the portable
//! fallback's accumulator shape.

use std::arch::x86_64::*;

/// Fold the 8 lanes of `v` in ascending lane order.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let mut lanes = [0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), v);
    lanes.iter().sum()
}

/// Dot product with a fused 8-lane accumulator.
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let va = _mm256_loadu_ps(a.as_ptr().add(i));
        let vb = _mm256_loadu_ps(b.as_ptr().add(i));
        acc = _mm256_fmadd_ps(va, vb, acc);
        i += 8;
    }
    let mut sum = hsum(acc);
    while i < n {
        sum += a[i] * b[i];
        i += 1;
    }
    sum
}

/// Elementwise `acc[i] *= src[i]` — exact (one rounding per lane).
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn mul_in(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    let n = acc.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_mul_ps(
            _mm256_loadu_ps(acc.as_ptr().add(i)),
            _mm256_loadu_ps(src.as_ptr().add(i)),
        );
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), v);
        i += 8;
    }
    while i < n {
        acc[i] *= src[i];
        i += 1;
    }
}

/// Fused `out[i] += alpha * x[i]`.
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let n = out.len();
    let va = _mm256_set1_ps(alpha);
    let mut i = 0usize;
    while i + 8 <= n {
        let vo = _mm256_fmadd_ps(
            va,
            _mm256_loadu_ps(x.as_ptr().add(i)),
            _mm256_loadu_ps(out.as_ptr().add(i)),
        );
        _mm256_storeu_ps(out.as_mut_ptr().add(i), vo);
        i += 8;
    }
    while i < n {
        out[i] += alpha * x[i];
        i += 1;
    }
}

/// `out = row · core` — ascending-`j` fused axpy accumulation.
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn project_row(row: &[f32], core: &[f32], out: &mut [f32]) {
    debug_assert_eq!(core.len(), row.len() * out.len());
    out.fill(0.0);
    for (&a, brow) in row.iter().zip(core.chunks_exact(out.len())) {
        axpy(a, brow, out);
    }
}

/// `out[j] = core[j, :] · d` for every row of `core`.
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn matvec_rows(core: &[f32], d: &[f32], out: &mut [f32]) {
    debug_assert_eq!(core.len(), out.len() * d.len());
    for (o, brow) in out.iter_mut().zip(core.chunks_exact(d.len())) {
        *o = dot(brow, d);
    }
}

/// SGD row update `out = row + lr * (err * db - lam * row)` with fused
/// multiply-adds.
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn sgd_row(row: &[f32], db: &[f32], err: f32, lr: f32, lam: f32, out: &mut [f32]) {
    debug_assert_eq!(row.len(), db.len());
    debug_assert_eq!(row.len(), out.len());
    let n = out.len();
    let verr = _mm256_set1_ps(err);
    let vlr = _mm256_set1_ps(lr);
    let vlam = _mm256_set1_ps(lam);
    let mut i = 0usize;
    while i + 8 <= n {
        let vrow = _mm256_loadu_ps(row.as_ptr().add(i));
        let vdb = _mm256_loadu_ps(db.as_ptr().add(i));
        // t = err * db - lam * row, fused on the err * db side
        let t = _mm256_fmsub_ps(verr, vdb, _mm256_mul_ps(vlam, vrow));
        let vo = _mm256_fmadd_ps(vlr, t, vrow);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), vo);
        i += 8;
    }
    while i < n {
        out[i] = row[i] + lr * (err * db[i] - lam * row[i]);
        i += 1;
    }
}

/// Rank-1 accumulation `grad[j, :] += (err * row[j]) * d`.
///
/// # Safety
/// AVX2 and FMA must be available (checked by the dispatcher).
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn grad_accum(grad: &mut [f32], row: &[f32], d: &[f32], err: f32) {
    debug_assert_eq!(grad.len(), row.len() * d.len());
    for (&a, grow) in row.iter().zip(grad.chunks_exact_mut(d.len())) {
        axpy(err * a, d, grow);
    }
}
