//! NEON implementations of the SIMD primitives (aarch64 only).
//!
//! NEON is part of the aarch64 baseline, so these functions are safe
//! wrappers around `unsafe` intrinsic blocks — no `#[target_feature]`
//! attribute is needed (the dispatcher still confirms `neon` via
//! `is_aarch64_feature_detected!` before routing here).  Bodies process
//! 4-lane `float32x4_t` chunks with fused multiply-add (`vfmaq_f32`);
//! remainders run scalar.

use std::arch::aarch64::*;

/// Dot product with a fused 4-lane accumulator.
pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    unsafe {
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            acc = vfmaq_f32(acc, vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            i += 4;
        }
        let mut sum = vaddvq_f32(acc);
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }
}

/// Elementwise `acc[i] *= src[i]` — exact (one rounding per lane).
pub(super) fn mul_in(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    let n = acc.len();
    unsafe {
        let mut i = 0usize;
        while i + 4 <= n {
            let v = vmulq_f32(vld1q_f32(acc.as_ptr().add(i)), vld1q_f32(src.as_ptr().add(i)));
            vst1q_f32(acc.as_mut_ptr().add(i), v);
            i += 4;
        }
        while i < n {
            acc[i] *= src[i];
            i += 1;
        }
    }
}

/// Fused `out[i] += alpha * x[i]`.
pub(super) fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let n = out.len();
    unsafe {
        let va = vdupq_n_f32(alpha);
        let mut i = 0usize;
        while i + 4 <= n {
            let vo = vfmaq_f32(vld1q_f32(out.as_ptr().add(i)), va, vld1q_f32(x.as_ptr().add(i)));
            vst1q_f32(out.as_mut_ptr().add(i), vo);
            i += 4;
        }
        while i < n {
            out[i] += alpha * x[i];
            i += 1;
        }
    }
}

/// `out = row · core` — ascending-`j` fused axpy accumulation.
pub(super) fn project_row(row: &[f32], core: &[f32], out: &mut [f32]) {
    debug_assert_eq!(core.len(), row.len() * out.len());
    out.fill(0.0);
    for (&a, brow) in row.iter().zip(core.chunks_exact(out.len())) {
        axpy(a, brow, out);
    }
}

/// `out[j] = core[j, :] · d` for every row of `core`.
pub(super) fn matvec_rows(core: &[f32], d: &[f32], out: &mut [f32]) {
    debug_assert_eq!(core.len(), out.len() * d.len());
    for (o, brow) in out.iter_mut().zip(core.chunks_exact(d.len())) {
        *o = dot(brow, d);
    }
}

/// SGD row update `out = row + lr * (err * db - lam * row)` with fused
/// multiply-adds.
pub(super) fn sgd_row(row: &[f32], db: &[f32], err: f32, lr: f32, lam: f32, out: &mut [f32]) {
    debug_assert_eq!(row.len(), db.len());
    debug_assert_eq!(row.len(), out.len());
    let n = out.len();
    unsafe {
        let verr = vdupq_n_f32(err);
        let vlr = vdupq_n_f32(lr);
        let vlam = vdupq_n_f32(lam);
        let mut i = 0usize;
        while i + 4 <= n {
            let vrow = vld1q_f32(row.as_ptr().add(i));
            let vdb = vld1q_f32(db.as_ptr().add(i));
            // t = err * db - lam * row, fused on the err * db side
            let t = vfmaq_f32(vnegq_f32(vmulq_f32(vlam, vrow)), verr, vdb);
            let vo = vfmaq_f32(vrow, vlr, t);
            vst1q_f32(out.as_mut_ptr().add(i), vo);
            i += 4;
        }
        while i < n {
            out[i] = row[i] + lr * (err * db[i] - lam * row[i]);
            i += 1;
        }
    }
}

/// Rank-1 accumulation `grad[j, :] += (err * row[j]) * d`.
pub(super) fn grad_accum(grad: &mut [f32], row: &[f32], d: &[f32], err: f32) {
    debug_assert_eq!(grad.len(), row.len() * d.len());
    for (&a, grow) in row.iter().zip(grad.chunks_exact_mut(d.len())) {
        axpy(err * a, d, grow);
    }
}
