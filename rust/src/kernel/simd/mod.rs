//! Runtime-dispatched f32 SIMD primitives — the CPU's stand-in for the
//! paper's tensor-core fragments, behind [`KernelPolicy::Simd`].
//!
//! The first call probes the CPU once (`is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!`), caches the winner in an atomic, and
//! every primitive then routes to that backend:
//!
//! ```text
//! detect (once, cached)          select                 execute
//! ───────────────────────  ───────────────────  ───────────────────────
//! avx2 && fma present   →  SimdBackend::Avx2Fma → 8-lane __m256 + FMA
//! neon present (arm64)  →  SimdBackend::Neon    → 4-lane float32x4 + FMA
//! otherwise             →  SimdBackend::Portable→ 8-lane chunked scalar
//! ```
//!
//! Numerical contract: elementwise primitives ([`mul_in`], [`sgd_row`]
//! minus its FMA fusion) round once per lane exactly like scalar code,
//! but reductions ([`dot`], [`matvec_rows`], [`project_row`] tails) fold
//! lanes in a different order and FMA skips intermediate roundings — so
//! the `Simd` tier is **tolerance-bounded** against the scalar oracle,
//! never bit-identical.  The exact tiers (`Tiled`, `Scalar`) do not go
//! through this module and stay bit-for-bit reproducible.
//!
//! All primitives accept arbitrary (ragged, unaligned) slice lengths;
//! chunk remainders run scalar.
//!
//! [`KernelPolicy::Simd`]: crate::kernel::KernelPolicy::Simd

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
mod portable;

use std::sync::atomic::{AtomicU8, Ordering};

/// Which implementation backs the SIMD tier on this machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// 256-bit AVX2 lanes with FMA contraction (x86_64, runtime-detected).
    Avx2Fma,
    /// 128-bit NEON lanes with fused multiply-add (aarch64 baseline).
    Neon,
    /// Chunked scalar fallback (autovectorizable), selected when no
    /// supported instruction set is detected.
    Portable,
}

impl SimdBackend {
    /// Stable lowercase name for logs, platform strings, and benches.
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Avx2Fma => "avx2_fma",
            SimdBackend::Neon => "neon",
            SimdBackend::Portable => "portable",
        }
    }
}

const UNPROBED: u8 = 0;
const SEL_AVX2: u8 = 1;
const SEL_NEON: u8 = 2;
const SEL_PORTABLE: u8 = 3;

static SELECTED: AtomicU8 = AtomicU8::new(UNPROBED);

fn probe() -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return SEL_AVX2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SEL_NEON;
        }
    }
    SEL_PORTABLE
}

/// The backend the SIMD tier dispatches to on this machine.  Probes the
/// CPU on first call, then answers from a cached atomic (a benign race
/// at worst probes twice with the same result).
pub fn active() -> SimdBackend {
    let mut sel = SELECTED.load(Ordering::Relaxed);
    if sel == UNPROBED {
        sel = probe();
        SELECTED.store(sel, Ordering::Relaxed);
    }
    match sel {
        SEL_AVX2 => SimdBackend::Avx2Fma,
        SEL_NEON => SimdBackend::Neon,
        _ => SimdBackend::Portable,
    }
}

/// Dot product `Σ a[i] * b[i]` (lane-chunked reduction; tolerance-bounded
/// vs scalar).  Lengths must match.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::dot(a, b),
        _ => portable::dot(a, b),
    }
}

/// Elementwise `acc[i] *= src[i]` — bit-identical to scalar on every
/// backend (one rounding per lane, no reassociation).
pub fn mul_in(acc: &mut [f32], src: &[f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::mul_in(acc, src) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::mul_in(acc, src),
        _ => portable::mul_in(acc, src),
    }
}

/// `out[i] += alpha * x[i]` (FMA-fused where available).
pub fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::axpy(alpha, x, out) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::axpy(alpha, x, out),
        _ => portable::axpy(alpha, x, out),
    }
}

/// Row projection `out = row · core` where `core` is `j x r` row-major,
/// `j = row.len()`, `r = out.len()` — the SIMD twin of
/// `kernel::micro::project`.
pub fn project_row(row: &[f32], core: &[f32], out: &mut [f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::project_row(row, core, out) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::project_row(row, core, out),
        _ => portable::project_row(row, core, out),
    }
}

/// Per-row dot `out[j] = core[j, :] · d` where `core` is `j x r`
/// row-major, `r = d.len()` — the SIMD twin of `kernel::micro::db_rows`.
pub fn matvec_rows(core: &[f32], d: &[f32], out: &mut [f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::matvec_rows(core, d, out) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::matvec_rows(core, d, out),
        _ => portable::matvec_rows(core, d, out),
    }
}

/// SGD row update `out = row + lr * (err * db - lam * row)` — the SIMD
/// twin of `kernel::micro::sgd_row` (FMA-fused, tolerance-bounded).
pub fn sgd_row(row: &[f32], db: &[f32], err: f32, lr: f32, lam: f32, out: &mut [f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::sgd_row(row, db, err, lr, lam, out) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::sgd_row(row, db, err, lr, lam, out),
        _ => portable::sgd_row(row, db, err, lr, lam, out),
    }
}

/// Rank-1 gradient accumulation `grad[j, :] += (err * row[j]) * d` — the
/// SIMD twin of `kernel::micro::grad_accum`.
pub fn grad_accum(grad: &mut [f32], row: &[f32], d: &[f32], err: f32) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2Fma => unsafe { avx2::grad_accum(grad, row, d, err) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon => neon::grad_accum(grad, row, d, err),
        _ => portable::grad_accum(grad, row, d, err),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill in [-0.5, 0.5).
    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..n)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x % 10_000) as f32 * 1e-4 - 0.5
            })
            .collect()
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs()))
    }

    fn assert_all_close(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(close(x, y), "{what}[{i}]: simd {x} vs scalar {y}");
        }
    }

    /// Ragged lengths straddling every chunk boundary of both lane widths.
    const LENS: [usize; 16] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 65];

    #[test]
    fn probe_is_stable() {
        let first = active();
        for _ in 0..4 {
            assert_eq!(active(), first);
        }
        assert!(!first.name().is_empty());
    }

    #[test]
    fn dot_matches_scalar_ragged_and_offset() {
        let pool = data(256, 1);
        for len in LENS {
            for off in [0usize, 1, 3] {
                let a = &pool[off..off + len];
                let b = &pool[off + len..off + 2 * len];
                let want: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                assert!(close(dot(a, b), want), "dot len {len} off {off}");
                assert!(close(portable::dot(a, b), want), "portable dot len {len}");
            }
        }
    }

    #[test]
    fn mul_in_is_bit_exact() {
        let pool = data(256, 2);
        for len in LENS {
            for off in [0usize, 1, 3] {
                let src = &pool[off + len..off + 2 * len];
                let mut got = pool[off..off + len].to_vec();
                let mut want = got.clone();
                mul_in(&mut got, src);
                for (w, &s) in want.iter_mut().zip(src) {
                    *w *= s;
                }
                assert_eq!(got, want, "mul_in len {len} off {off}");
            }
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        let pool = data(256, 3);
        for len in LENS {
            let x = &pool[len..2 * len];
            let mut got = pool[..len].to_vec();
            let mut want = got.clone();
            axpy(0.37, x, &mut got);
            for (w, &v) in want.iter_mut().zip(x) {
                *w += 0.37 * v;
            }
            assert_all_close(&got, &want, "axpy");
        }
    }

    #[test]
    fn project_and_matvec_match_scalar() {
        for (j, r) in [(1, 1), (5, 9), (16, 16), (16, 32), (3, 17), (48, 48)] {
            let factor = data(j, (j * r) as u64);
            let core = data(j * r, (j + r) as u64);
            let d = data(r, r as u64);

            let mut got = vec![0f32; r];
            project_row(&factor, &core, &mut got);
            let mut want = vec![0f32; r];
            for (jj, &a) in factor.iter().enumerate() {
                for (w, &b) in want.iter_mut().zip(&core[jj * r..(jj + 1) * r]) {
                    *w += a * b;
                }
            }
            assert_all_close(&got, &want, "project_row");

            let mut got = vec![0f32; j];
            matvec_rows(&core, &d, &mut got);
            let want: Vec<f32> = core
                .chunks_exact(r)
                .map(|brow| brow.iter().zip(&d).map(|(x, y)| x * y).sum())
                .collect();
            assert_all_close(&got, &want, "matvec_rows");
        }
    }

    #[test]
    fn sgd_and_grad_match_scalar() {
        let (err, lr, lam) = (0.21f32, 0.015f32, 0.03f32);
        for (j, r) in [(7, 5), (16, 16), (33, 9)] {
            let row = data(j, 11);
            let db = data(j, 12);
            let mut got = vec![0f32; j];
            sgd_row(&row, &db, err, lr, lam, &mut got);
            let want: Vec<f32> = row
                .iter()
                .zip(&db)
                .map(|(&a, &g)| a + lr * (err * g - lam * a))
                .collect();
            assert_all_close(&got, &want, "sgd_row");

            let d = data(r, 13);
            let mut got = data(j * r, 14);
            let mut want = got.clone();
            grad_accum(&mut got, &row, &d, err);
            for (jj, &a) in row.iter().enumerate() {
                for (w, &v) in want[jj * r..(jj + 1) * r].iter_mut().zip(&d) {
                    *w += (err * a) * v;
                }
            }
            assert_all_close(&got, &want, "grad_accum");
        }
    }
}
