//! Portable chunked fallback for the SIMD primitive layer.
//!
//! Selected when runtime detection finds no supported instruction set (and
//! on every architecture without an explicit backend).  The loops mirror
//! the lane structure of the real SIMD backends — reductions keep
//! `LANES` independent partial accumulators folded at the end — so the
//! numerical behavior of the `Simd` tier is chunked-reduction shaped on
//! every machine, and LLVM can autovectorize the bodies.  No `mul_add`:
//! without hardware FMA that lowers to a libm call.

/// Lane count the portable reductions mirror (the AVX2 f32 width).
pub(super) const LANES: usize = 8;

/// Chunked dot product: `LANES` partial accumulators, folded lane-ascending.
pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % LANES;
    let (ah, at) = a.split_at(split);
    let (bh, bt) = b.split_at(split);
    let mut acc = [0f32; LANES];
    for (ca, cb) in ah.chunks_exact(LANES).zip(bh.chunks_exact(LANES)) {
        for ((l, &x), &y) in acc.iter_mut().zip(ca).zip(cb) {
            *l += x * y;
        }
    }
    let mut tail = 0f32;
    for (&x, &y) in at.iter().zip(bt) {
        tail += x * y;
    }
    acc.iter().sum::<f32>() + tail
}

/// Elementwise `acc[i] *= src[i]` (exact: one rounding per lane, same as
/// scalar).
pub(super) fn mul_in(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, &s) in acc.iter_mut().zip(src) {
        *a *= s;
    }
}

/// Elementwise `out[i] += alpha * x[i]`.
pub(super) fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// `out = row · core` (`core` is `j x r` row-major, `j = row.len()`,
/// `r = out.len()`): ascending-`j` axpy accumulation.
pub(super) fn project_row(row: &[f32], core: &[f32], out: &mut [f32]) {
    debug_assert_eq!(core.len(), row.len() * out.len());
    out.fill(0.0);
    for (&a, brow) in row.iter().zip(core.chunks_exact(out.len())) {
        axpy(a, brow, out);
    }
}

/// `out[j] = core[j, :] · d` for every row of `core` (`j x r` row-major,
/// `r = d.len()`).
pub(super) fn matvec_rows(core: &[f32], d: &[f32], out: &mut [f32]) {
    debug_assert_eq!(core.len(), out.len() * d.len());
    for (o, brow) in out.iter_mut().zip(core.chunks_exact(d.len())) {
        *o = dot(brow, d);
    }
}

/// SGD row update `out = row + lr * (err * db - lam * row)`.
pub(super) fn sgd_row(row: &[f32], db: &[f32], err: f32, lr: f32, lam: f32, out: &mut [f32]) {
    debug_assert_eq!(row.len(), db.len());
    debug_assert_eq!(row.len(), out.len());
    for ((o, &a), &g) in out.iter_mut().zip(row).zip(db) {
        *o = a + lr * (err * g - lam * a);
    }
}

/// Rank-1 accumulation `grad[j, :] += (err * row[j]) * d` (`grad` is
/// `j x r` row-major).
pub(super) fn grad_accum(grad: &mut [f32], row: &[f32], d: &[f32], err: f32) {
    debug_assert_eq!(grad.len(), row.len() * d.len());
    for (&a, grow) in row.iter().zip(grad.chunks_exact_mut(d.len())) {
        axpy(err * a, d, grow);
    }
}
