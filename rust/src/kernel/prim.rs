//! Exact runtime-width primitives — the single accumulation-order contract
//! shared by the serving layer and the snapshot builder.
//!
//! The serve layer works with runtime `j`/`r` (read from a checkpoint),
//! not const generics, and its outputs are pinned **bit-identical** to the
//! trainer's oracle (`cpu_ref::compute_c_full`, `TuckerModel::predict_one`).
//! These wrappers give it one place to get that arithmetic: known widths
//! route to the monomorphized microkernels in [`super::micro`] (which the
//! `kernel_parity` suite proves equal to the oracle), and every other
//! width runs the same ascending-index scalar loops.  `engine::dot_r` and
//! `snapshot::project_rows` used to duplicate this logic privately; they
//! now both call here, so there is exactly one place to optimize and one
//! order to test.

use super::micro;

/// Exact dot product `Σ a[i] * b[i]` in ascending index order.  Known
/// Kruskal widths (16/32/48/64) use the monomorphized microkernel; the
/// result is bit-identical either way.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match a.len() {
        16 => micro::dot::<16>(a.try_into().unwrap(), b.try_into().unwrap()),
        32 => micro::dot::<32>(a.try_into().unwrap(), b.try_into().unwrap()),
        48 => micro::dot::<48>(a.try_into().unwrap(), b.try_into().unwrap()),
        64 => micro::dot::<64>(a.try_into().unwrap(), b.try_into().unwrap()),
        _ => {
            let mut acc = 0.0f32;
            for (&x, &y) in a.iter().zip(b) {
                acc += x * y;
            }
            acc
        }
    }
}

/// Exact elementwise `acc[i] *= src[i]` (one rounding per element).
pub fn mul_in(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, &s) in acc.iter_mut().zip(src) {
        *a *= s;
    }
}

/// Project every row of `factor` (`rows x j` row-major) through `core`
/// (`j x r` row-major) into `out` (`rows x r` row-major) — the exact
/// table build `C = A B`, bit-identical to `cpu_ref::compute_c_full`
/// (zero-init, ascending `j`, ascending `r`).
pub fn project_rows(factor: &[f32], core: &[f32], j: usize, r: usize, out: &mut [f32]) {
    debug_assert_eq!(core.len(), j * r);
    debug_assert_eq!(factor.len() / j * r, out.len());
    match (j, r) {
        (16, 16) => project_tile::<16, 16>(factor, core, out),
        (16, 32) => project_tile::<16, 32>(factor, core, out),
        (32, 16) => project_tile::<32, 16>(factor, core, out),
        (32, 32) => project_tile::<32, 32>(factor, core, out),
        (48, 48) => project_tile::<48, 48>(factor, core, out),
        (64, 64) => project_tile::<64, 64>(factor, core, out),
        _ => {
            for (row, dst) in factor.chunks_exact(j).zip(out.chunks_exact_mut(r)) {
                dst.fill(0.0);
                for (&a, brow) in row.iter().zip(core.chunks_exact(r)) {
                    for (d, &b) in dst.iter_mut().zip(brow) {
                        *d += a * b;
                    }
                }
            }
        }
    }
}

fn project_tile<const J: usize, const R: usize>(factor: &[f32], core: &[f32], out: &mut [f32]) {
    for (row, dst) in factor.chunks_exact(J).zip(out.chunks_exact_mut(R)) {
        let row: &[f32; J] = row.try_into().unwrap();
        let dst: &mut [f32; R] = dst.try_into().unwrap();
        micro::project::<J, R>(row, core, dst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| (i % 13) as f32 * scale - 0.4).collect()
    }

    #[test]
    fn dot_bit_identical_to_scalar_all_widths() {
        for len in [1usize, 7, 16, 17, 32, 48, 64, 65] {
            let a = seq(len, 0.11);
            let b = seq(len, 0.07);
            let mut want = 0.0f32;
            for (&x, &y) in a.iter().zip(&b) {
                want += x * y;
            }
            assert_eq!(dot(&a, &b), want, "width {len}");
        }
    }

    #[test]
    fn project_rows_bit_identical_to_scalar_order() {
        for (j, r) in [(16usize, 16usize), (16, 32), (5, 9), (48, 48)] {
            let rows = 3;
            let factor = seq(rows * j, 0.05);
            let core = seq(j * r, 0.03);
            let mut got = vec![0f32; rows * r];
            project_rows(&factor, &core, j, r, &mut got);
            let mut want = vec![0f32; rows * r];
            for i in 0..rows {
                for jj in 0..j {
                    let a = factor[i * j + jj];
                    for rr in 0..r {
                        want[i * r + rr] += a * core[jj * r + rr];
                    }
                }
            }
            assert_eq!(got, want, "shape ({j}, {r})");
        }
    }

    #[test]
    fn mul_in_is_elementwise() {
        let mut acc = seq(10, 0.3);
        let src = seq(10, 0.2);
        let want: Vec<f32> = acc.iter().zip(&src).map(|(&a, &s)| a * s).collect();
        mul_in(&mut acc, &src);
        assert_eq!(acc, want);
    }
}
