//! Fixed-width microkernels — the innermost `J`/`R` loops of every tiled
//! step, monomorphized per (J, R) shape.
//!
//! Each function is the lane-level analog of one L1 Pallas primitive: the
//! `[S, J] x [J, R]` projection, the `d B^T` matvec, the SGD row update and
//! the rank-1 core-gradient accumulation.  `J` and `R` are const generics,
//! so every inner trip count is a compile-time constant: LLVM fully unrolls
//! the loops, keeps the `[f32; R]` accumulators in vector registers, and
//! emits packed multiply/add lanes (the CPU analog of the MXU tile; with
//! FMA contraction enabled by the target the mul+add pairs fuse).
//!
//! Arithmetic-order contract: every loop performs the *same operations in
//! the same order* as the scalar oracle in [`crate::cpu_ref::step`], so the
//! tiled path is bit-identical to the oracle — the `kernel_parity`
//! integration test and the oracle-vs-block tests both rely on this.  Do
//! not reassociate reductions or fuse the mul/add pairs in source.

/// `out = row · core`, the `[1, J] x [J, R]` projection of one factor row
/// through one core matrix (`core` is `J x R` row-major).
#[inline(always)]
pub(crate) fn project<const J: usize, const R: usize>(
    row: &[f32; J],
    core: &[f32],
    out: &mut [f32; R],
) {
    debug_assert_eq!(core.len(), J * R);
    *out = [0.0; R];
    for (&a, brow) in row.iter().zip(core.chunks_exact(R)) {
        for rr in 0..R {
            out[rr] += a * brow[rr];
        }
    }
}

/// `out[j] = d · core[j, :]` for every `j` — the `B d^T` matvec feeding the
/// factor-row gradient (Eq. 8 / Eq. 12).
#[inline(always)]
pub(crate) fn db_rows<const J: usize, const R: usize>(
    core: &[f32],
    d: &[f32; R],
    out: &mut [f32; J],
) {
    debug_assert_eq!(core.len(), J * R);
    for (dst, brow) in out.iter_mut().zip(core.chunks_exact(R)) {
        let mut acc = 0.0f32;
        for rr in 0..R {
            acc += d[rr] * brow[rr];
        }
        *dst = acc;
    }
}

/// Fixed-width dot product over the Kruskal rank.
#[inline(always)]
pub(crate) fn dot<const R: usize>(a: &[f32; R], b: &[f32; R]) -> f32 {
    let mut acc = 0.0f32;
    for rr in 0..R {
        acc += a[rr] * b[rr];
    }
    acc
}

/// SGD row update: `out = row + lr * (err * db - lam * row)`.
#[inline(always)]
pub(crate) fn sgd_row<const J: usize>(
    row: &[f32; J],
    db: &[f32; J],
    err: f32,
    lr: f32,
    lam: f32,
    out: &mut [f32; J],
) {
    for jj in 0..J {
        out[jj] = row[jj] + lr * (err * db[jj] - lam * row[jj]);
    }
}

/// Rank-1 core-gradient accumulation: `grad[j, :] += (err * row[j]) * d`
/// (`grad` is `J x R` row-major).
#[inline(always)]
pub(crate) fn grad_accum<const J: usize, const R: usize>(
    grad: &mut [f32],
    row: &[f32; J],
    d: &[f32; R],
    err: f32,
) {
    debug_assert_eq!(grad.len(), J * R);
    for (&a, grow) in row.iter().zip(grad.chunks_exact_mut(R)) {
        let ea = err * a;
        for rr in 0..R {
            grow[rr] += ea * d[rr];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn project_matches_naive() {
        let row: [f32; 16] = std::array::from_fn(|i| i as f32 * 0.25 - 1.0);
        let core: Vec<f32> = (0..16 * 16).map(|i| (i % 7) as f32 * 0.1).collect();
        let mut out = [0f32; 16];
        project::<16, 16>(&row, &core, &mut out);
        for rr in 0..16 {
            let mut want = 0f32;
            for jj in 0..16 {
                want += row[jj] * core[jj * 16 + rr];
            }
            assert!((out[rr] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn db_rows_matches_naive() {
        let d: [f32; 16] = std::array::from_fn(|i| 0.5 - i as f32 * 0.05);
        let core: Vec<f32> = (0..16 * 16).map(|i| (i % 5) as f32 * 0.2).collect();
        let mut out = [0f32; 16];
        db_rows::<16, 16>(&core, &d, &mut out);
        for jj in 0..16 {
            let mut want = 0f32;
            for rr in 0..16 {
                want += d[rr] * core[jj * 16 + rr];
            }
            assert!((out[jj] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn sgd_and_grad_shapes() {
        let row = [1.0f32; 16];
        let db = [2.0f32; 16];
        let mut out = [0f32; 16];
        sgd_row::<16>(&row, &db, 0.5, 0.1, 0.0, &mut out);
        assert!(out.iter().all(|&v| (v - 1.1).abs() < 1e-6));

        let d = [1.0f32; 16];
        let mut grad = vec![0f32; 16 * 16];
        grad_accum::<16, 16>(&mut grad, &row, &d, 2.0);
        assert!(grad.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert!((dot::<16>(&d, &d) - 16.0).abs() < 1e-6);
    }
}
