//! Per-block invariant cache — the kernel-level "calculation instead of
//! storage" knob (§5.6 of the paper, the shared-invariant reuse of
//! cuFasterTucker).
//!
//! The storage-scheme kernels need the exclusion product
//! `d = Π_{m≠mode} C^(m)[i_m, :]` for every sample.  Consecutive samples in
//! a fiber-grouped block share all non-target coordinates, so their `d` is
//! identical.  [`InvariantCache`] either recomputes `d` per sample
//! ([`InvariantPolicy::Recompute`] — calculation, the default) or keeps the
//! last fiber's product and reuses it while the fiber key matches
//! ([`InvariantPolicy::CachePerFiber`] — storage).  Both policies produce
//! bit-identical results: a cache hit returns the exact f32 product a
//! recompute would (same inputs, same multiply order), so the knob trades
//! arithmetic against loads without touching the trajectory — the same
//! tradeoff the `table9_calc_vs_store` / `fig5_calc_store_sweep` benches
//! probe on the HLO path.

use crate::cpu_ref::step::BlockData;

use super::{simd, InvariantPolicy, KernelCounters};

/// Cached exclusion product for the storage-scheme kernels, scoped to one
/// block range (each worker shard owns its own cache).
pub struct InvariantCache<const R: usize> {
    policy: InvariantPolicy,
    /// Coordinates of the sample the cached `d` was computed for (the slot
    /// at `mode` is ignored by the fiber comparison).
    key: Vec<u32>,
    d: [f32; R],
    valid: bool,
    simd: bool,
    hits: u64,
    misses: u64,
}

impl<const R: usize> InvariantCache<R> {
    /// Empty cache for an order-`n` tensor.
    pub fn new(policy: InvariantPolicy, n: usize) -> InvariantCache<R> {
        InvariantCache {
            policy,
            key: vec![0; n],
            d: [1.0; R],
            valid: false,
            simd: false,
            hits: 0,
            misses: 0,
        }
    }

    /// Route the rebuild's elementwise row products through the SIMD
    /// primitive layer.  The products are elementwise (one rounding per
    /// lane, no reassociation), so the cache stays bit-identical to the
    /// scalar rebuild even on this path.
    pub fn with_simd(mut self, on: bool) -> InvariantCache<R> {
        self.simd = on;
        self
    }

    /// Exclusion product `d` for sample `e` of the block, excluding `mode`.
    ///
    /// Under [`InvariantPolicy::CachePerFiber`] the cached product is returned
    /// when sample `e` lies in the same fiber as the previously served
    /// sample (all coordinates equal except `mode`); otherwise — and always
    /// under [`InvariantPolicy::Recompute`] — it is rebuilt from the stored
    /// `C^(m)` rows in ascending mode order, exactly like the scalar oracle.
    pub fn exclusion(&mut self, data: &BlockData<'_>, e: usize, mode: usize) -> &[f32; R] {
        if self.valid
            && self.policy == InvariantPolicy::CachePerFiber
            && self.same_fiber(data, e, mode)
        {
            self.hits += 1;
            return &self.d;
        }
        self.misses += 1;
        self.d = [1.0; R];
        for m in 0..data.n {
            if m == mode {
                continue;
            }
            let row = data.coord(e, m) as usize;
            let crow = &data.c_store[m][row * R..row * R + R];
            if self.simd {
                simd::mul_in(&mut self.d, crow);
            } else {
                for rr in 0..R {
                    self.d[rr] *= crow[rr];
                }
            }
            self.key[m] = row as u32;
        }
        self.valid = true;
        &self.d
    }

    fn same_fiber(&self, data: &BlockData<'_>, e: usize, mode: usize) -> bool {
        (0..data.n).all(|m| m == mode || self.key[m] == data.coord(e, m))
    }

    /// Number of samples served from the cached fiber product.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Number of samples that recomputed the product.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hit/miss totals in the shape the kernel range functions report
    /// back to the backend.
    pub fn counters(&self) -> KernelCounters {
        KernelCounters {
            inv_hits: self.hits,
            inv_misses: self.misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu_ref::Hyper;

    fn block_data<'a>(
        c_store: &'a [Vec<f32>],
        coords: &'a [u32],
        values: &'a [f32],
    ) -> BlockData<'a> {
        BlockData {
            cores: &[],
            c_store,
            coords,
            lanes: &[],
            values,
            n: 3,
            j: 16,
            r: 16,
            hyper: Hyper::default(),
        }
    }

    #[test]
    fn cache_fiber_reuses_within_fiber_only() {
        // C^(m): 4 rows of R=16 each, distinct per row.
        let c_store: Vec<Vec<f32>> = (0..3)
            .map(|m| (0..4 * 16).map(|i| 1.0 + (m * 64 + i) as f32 * 1e-3).collect())
            .collect();
        // three samples: first two share the mode-0 fiber (coords 1/2 equal)
        let coords: Vec<u32> = vec![0, 1, 2, /**/ 1, 1, 2, /**/ 1, 3, 2];
        let values = vec![0f32; 3];
        let data = block_data(&c_store, &coords, &values);

        let mut cached = InvariantCache::<16>::new(InvariantPolicy::CachePerFiber, 3);
        let mut recomputed = InvariantCache::<16>::new(InvariantPolicy::Recompute, 3);
        let mut simd = InvariantCache::<16>::new(InvariantPolicy::CachePerFiber, 3).with_simd(true);
        for e in 0..3 {
            let a = *cached.exclusion(&data, e, 0);
            let b = *recomputed.exclusion(&data, e, 0);
            let c = *simd.exclusion(&data, e, 0);
            assert_eq!(a, b, "policies must agree bit-for-bit at sample {e}");
            assert_eq!(a, c, "simd rebuild must stay bit-identical at sample {e}");
        }
        assert_eq!(cached.hits(), 1);
        assert_eq!(cached.misses(), 2);
        assert_eq!(recomputed.hits(), 0);
        assert_eq!(recomputed.misses(), 3);
        let kc = cached.counters();
        assert_eq!((kc.inv_hits, kc.inv_misses), (1, 2));
    }
}
