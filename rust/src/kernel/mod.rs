//! Tiled CPU microkernels — the execution core of the CPU backends.
//!
//! The paper's speedup comes from re-shaping the per-sample math into
//! tensor-core-sized `[S, J] x [J, R]` tiles and from "computation instead
//! of storage": recomputing cheap invariants instead of round-tripping them
//! through memory.  This module ports both ideas to the CPU path:
//!
//! * `micro` — fixed-width `(J, R)` microkernels (const generics, fully
//!   unrolled inner loops over contiguous chunks) that LLVM autovectorizes;
//!   the lane-level mirror of the L1 Pallas tiles.
//! * [`simd`] — explicit runtime-dispatched SIMD primitives (AVX2+FMA on
//!   x86_64, NEON on aarch64, a chunked portable fallback) — the CPU's
//!   stand-in for the paper's tensor-core fragments.
//! * `tile` — per-(algorithm, phase) drivers that walk a block range
//!   through a [`tile::TileMath`] primitive set: `ExactMath` (bit-identical
//!   to the scalar oracle) or `SimdMath` (tolerance-bounded).
//! * [`invariant`] — [`InvariantCache`], the block-level calc-vs-store knob
//!   for the storage-scheme kernels (recompute the exclusion product per
//!   sample, or reuse it across a fiber).
//! * [`prim`] — exact runtime-width primitives shared with the serve layer
//!   (one accumulation-order contract for snapshots and scoring).
//!
//! The public entry points (`*_factor_range` / `*_core_range` and the
//! algorithm dispatchers [`run_factor_range`] / [`run_core_range`]) mirror
//! the scalar functions in [`crate::cpu_ref::step`], take a [`KernelCfg`],
//! and return [`KernelCounters`] (invariant-cache hit/miss totals):
//!
//! * [`KernelPolicy::Tiled`] (default) selects a monomorphized tiled driver
//!   when the run's `(J, R)` shape has one (J, R ∈ {16, 32}, plus the
//!   square 48/64 shapes) and falls back to the scalar path otherwise;
//! * [`KernelPolicy::Scalar`] forces the scalar oracle (`--cpu-kernel
//!   scalar` on the CLI) — the baseline the `parallel_scaling` bench and
//!   the `kernel_parity` test compare against;
//! * [`KernelPolicy::Simd`] routes the same monomorphized drivers through
//!   the explicit SIMD primitives ([`simd::active`] picks AVX2/NEON/
//!   portable once per process), with the same scalar fallback for shapes
//!   without an instantiation.
//!
//! Numerical contract: `Tiled` and `Scalar` perform the same operations in
//! the same order, so switching between them never changes a trajectory —
//! only the wall clock.  `Simd` reassociates reductions into lanes and
//! fuses multiply-adds, so it tracks the exact tiers to a small relative
//! tolerance (pinned by `kernel_parity`) rather than bit-for-bit.

pub mod invariant;
pub(crate) mod micro;
pub mod prim;
pub mod simd;
pub(crate) mod tile;

pub use invariant::InvariantCache;
pub use simd::SimdBackend;

use std::ops::Range;

use crate::coordinator::config::Algo;
use crate::cpu_ref::step::{self, BlockData};
use crate::model::SharedFactors;

/// Which CPU step implementation to run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelPolicy {
    /// Fixed-width tiled microkernels (scalar fallback for shapes without a
    /// monomorphized instantiation) — exact, bit-identical to `Scalar`.
    #[default]
    Tiled,
    /// The scalar reference path — the CpuRef oracle, kept behind this flag
    /// for parity tests and baseline measurements.
    Scalar,
    /// Explicit SIMD microkernels (AVX2+FMA / NEON, runtime-detected, with
    /// a portable chunked fallback) — tolerance-bounded, not bit-identical
    /// to the exact tiers.
    Simd,
}

impl KernelPolicy {
    /// Parse a CLI value (`tiled` / `scalar` / `simd`).
    pub fn parse(s: &str) -> Option<KernelPolicy> {
        match s {
            "tiled" => Some(KernelPolicy::Tiled),
            "scalar" => Some(KernelPolicy::Scalar),
            "simd" => Some(KernelPolicy::Simd),
            _ => None,
        }
    }

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            KernelPolicy::Tiled => "tiled",
            KernelPolicy::Scalar => "scalar",
            KernelPolicy::Simd => "simd",
        }
    }
}

/// How the storage-scheme kernels obtain the per-sample exclusion product
/// (the paper's calculation-vs-storage tradeoff at block level).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InvariantPolicy {
    /// Recompute the Kruskal exclusion product for every sample
    /// (calculation — the default).
    #[default]
    Recompute,
    /// Cache the product and reuse it while consecutive samples share a
    /// fiber (storage — wins when blocks are fiber-grouped).
    CachePerFiber,
}

/// Kernel configuration threaded from [`crate::coordinator::TrainConfig`]
/// into every CPU block execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct KernelCfg {
    /// Tiled microkernels vs the scalar oracle vs explicit SIMD.
    pub policy: KernelPolicy,
    /// Calc-vs-store handling of the storage-scheme invariants.
    pub invariant: InvariantPolicy,
}

/// Counters every kernel range execution reports back to the backend —
/// currently the invariant-cache hit/miss totals of the storage-scheme
/// kernels (zero for the other algorithms and for the scalar path, which
/// recomputes unconditionally).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelCounters {
    /// Samples whose exclusion product was served from the fiber cache.
    pub inv_hits: u64,
    /// Samples that recomputed the exclusion product.
    pub inv_misses: u64,
}

impl KernelCounters {
    /// Fold another range's counters into this one.
    pub fn merge(&mut self, other: KernelCounters) {
        self.inv_hits += other.inv_hits;
        self.inv_misses += other.inv_misses;
    }
}

/// Monomorphized `(J, R)` dispatch: route to a fixed-shape tile driver
/// instantiated with the given math, or to the scalar fallback when the
/// shape has no instantiation.
macro_rules! dispatch_jr {
    (($j:expr, $r:expr), $math:ty, $f:ident ( $($a:expr),* ), $fallback:expr) => {
        match ($j, $r) {
            (16, 16) => tile::$f::<$math, 16, 16>($($a),*),
            (16, 32) => tile::$f::<$math, 16, 32>($($a),*),
            (32, 16) => tile::$f::<$math, 32, 16>($($a),*),
            (32, 32) => tile::$f::<$math, 32, 32>($($a),*),
            (48, 48) => tile::$f::<$math, 48, 48>($($a),*),
            (64, 64) => tile::$f::<$math, 64, 64>($($a),*),
            _ => $fallback,
        }
    };
}

/// Policy dispatch on top of [`dispatch_jr!`]: scalar forces the oracle,
/// the tiled tiers pick their math, unsupported shapes fall back.
macro_rules! dispatch_policy {
    ($cfg:expr, ($j:expr, $r:expr), $f:ident ( $($a:expr),* ), $fallback:expr) => {
        match $cfg.policy {
            KernelPolicy::Scalar => $fallback,
            KernelPolicy::Tiled => {
                dispatch_jr!(($j, $r), tile::ExactMath, $f($($a),*), $fallback)
            }
            KernelPolicy::Simd => {
                dispatch_jr!(($j, $r), tile::SimdMath, $f($($a),*), $fallback)
            }
        }
    };
}

/// FastTuckerPlus factor step over `range` (all factor rows per sample).
pub fn plus_factor_range(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
    cfg: KernelCfg,
) -> KernelCounters {
    dispatch_policy!(cfg, (data.j, data.r), plus_factor(shared, data, range), {
        step::plus_factor_scalar(shared, data, range);
        KernelCounters::default()
    })
}

/// FastTuckerPlus core step over `range`, accumulating into `grad`
/// (`[N, J, R]`).
pub fn plus_core_range(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
    grad: &mut [f32],
    cfg: KernelCfg,
) -> KernelCounters {
    dispatch_policy!(cfg, (data.j, data.r), plus_core(shared, data, range, grad), {
        step::plus_core_scalar(shared, data, range, grad);
        KernelCounters::default()
    })
}

/// FastTucker factor step for `mode` over `range`.
pub fn mode_factor_range(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    cfg: KernelCfg,
) -> KernelCounters {
    dispatch_policy!(cfg, (data.j, data.r), mode_factor(shared, data, mode, range), {
        step::mode_factor_scalar(shared, data, mode, range);
        KernelCounters::default()
    })
}

/// FastTucker core step for `mode` over `range`, accumulating into `grad`
/// (`[J, R]`).
pub fn mode_core_range(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
    cfg: KernelCfg,
) -> KernelCounters {
    dispatch_policy!(
        cfg,
        (data.j, data.r),
        mode_core(shared, data, mode, range, grad),
        {
            step::mode_core_scalar(shared, data, mode, range, grad);
            KernelCounters::default()
        }
    )
}

/// FasterTucker (storage scheme) factor step for `mode` over `range`.
pub fn stored_factor_range(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    cfg: KernelCfg,
) -> KernelCounters {
    dispatch_policy!(
        cfg,
        (data.j, data.r),
        stored_factor(shared, data, mode, range, cfg.invariant),
        {
            step::stored_factor_scalar(shared, data, mode, range);
            KernelCounters::default()
        }
    )
}

/// FasterTucker (storage scheme) core step for `mode` over `range`,
/// accumulating into `grad` (`[J, R]`).
pub fn stored_core_range(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
    cfg: KernelCfg,
) -> KernelCounters {
    dispatch_policy!(
        cfg,
        (data.j, data.r),
        stored_core(shared, data, mode, range, grad, cfg.invariant),
        {
            step::stored_core_scalar(shared, data, mode, range, grad);
            KernelCounters::default()
        }
    )
}

/// Dispatch one factor-step range to the algorithm's kernel (the CPU
/// backends' single entry point for the factor phase).
pub fn run_factor_range(
    algo: Algo,
    mode: Option<usize>,
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
    cfg: KernelCfg,
) -> KernelCounters {
    match (algo, mode) {
        (Algo::Plus, None) => plus_factor_range(shared, data, range, cfg),
        (Algo::FastTucker, Some(m)) => mode_factor_range(shared, data, m, range, cfg),
        (Algo::FasterTucker | Algo::FasterTuckerCoo, Some(m)) => {
            stored_factor_range(shared, data, m, range, cfg)
        }
        _ => unreachable!("algo/pass schedule mismatch"),
    }
}

/// Dispatch one core-step range to the algorithm's kernel (the CPU
/// backends' single entry point for the core phase).
pub fn run_core_range(
    algo: Algo,
    mode: Option<usize>,
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
    grad: &mut [f32],
    cfg: KernelCfg,
) -> KernelCounters {
    match (algo, mode) {
        (Algo::Plus, None) => plus_core_range(shared, data, range, grad, cfg),
        (Algo::FastTucker, Some(m)) => mode_core_range(shared, data, m, range, grad, cfg),
        (Algo::FasterTucker | Algo::FasterTuckerCoo, Some(m)) => {
            stored_core_range(shared, data, m, range, grad, cfg)
        }
        _ => unreachable!("algo/pass schedule mismatch"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu_ref::Hyper;
    use crate::model::TuckerModel;

    #[test]
    fn policy_parse_roundtrip() {
        for p in [KernelPolicy::Tiled, KernelPolicy::Scalar, KernelPolicy::Simd] {
            assert_eq!(KernelPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(KernelPolicy::parse("nope"), None);
        assert_eq!(KernelPolicy::default(), KernelPolicy::Tiled);
        assert_eq!(InvariantPolicy::default(), InvariantPolicy::Recompute);
    }

    #[test]
    fn counters_merge_sums() {
        let mut a = KernelCounters {
            inv_hits: 3,
            inv_misses: 5,
        };
        a.merge(KernelCounters {
            inv_hits: 2,
            inv_misses: 1,
        });
        assert_eq!(a.inv_hits, 5);
        assert_eq!(a.inv_misses, 6);
    }

    /// A shape with no monomorphized tile must run through the scalar
    /// fallback and still produce the scalar trajectory — under the tiled
    /// *and* the SIMD tier (the fallback is the same exact oracle).
    #[test]
    fn unsupported_shape_falls_back_to_scalar() {
        let (j, r) = (48, 16); // not in the dispatch table
        let base = TuckerModel::init(&[8, 8, 8], j, r, 3);
        let coords: Vec<u32> = (0..12u32)
            .flat_map(|e| [e % 8, (e / 2) % 8, (e / 3) % 8])
            .collect();
        let values: Vec<f32> = (0..12).map(|e| 1.0 + e as f32 * 0.1).collect();
        let run = |model: &mut TuckerModel, cfg: KernelCfg| {
            let cores = model.cores.clone();
            let shared = SharedFactors::new(&mut model.factors, j);
            let data = BlockData {
                cores: &cores,
                c_store: &[],
                coords: &coords,
                lanes: &[],
                values: &values,
                n: 3,
                j,
                r,
                hyper: Hyper::default(),
            };
            plus_factor_range(&shared, &data, 0..12, cfg);
        };
        let mut scalar = base.clone();
        run(
            &mut scalar,
            KernelCfg {
                policy: KernelPolicy::Scalar,
                ..Default::default()
            },
        );
        for policy in [KernelPolicy::Tiled, KernelPolicy::Simd] {
            let mut m = base.clone();
            run(
                &mut m,
                KernelCfg {
                    policy,
                    ..Default::default()
                },
            );
            for mode in 0..3 {
                assert_eq!(
                    m.factors[mode], scalar.factors[mode],
                    "{policy:?} mode {mode} diverged"
                );
            }
        }
    }
}
