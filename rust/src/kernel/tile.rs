//! Tiled step drivers — one per (algorithm, phase), generic over the
//! compile-time `(J, R)` shape *and* the math implementation.
//!
//! Each driver walks its slot range sample-by-sample (factor phases must:
//! a later sample may touch a row an earlier sample just updated, and the
//! serial backend is defined as exactly the sequential trajectory) but
//! performs *all* per-sample arithmetic through a [`TileMath`] — the
//! per-shape primitive vtable the dispatch macro monomorphizes:
//!
//! * [`ExactMath`] routes to [`super::micro`], whose fully unrolled
//!   `J`/`R` loops are the CPU mirror of the L1 Pallas `[S, J] x [J, R]`
//!   tiles, bit-identical to the scalar oracle in
//!   [`crate::cpu_ref::step`] (the `kernel_parity` test pins it);
//! * [`SimdMath`] routes to the runtime-dispatched primitives in
//!   [`super::simd`] (AVX2+FMA / NEON / portable) — tolerance-bounded
//!   against the oracle, never bit-identical.
//!
//! The storage-scheme drivers thread an [`InvariantCache`] through the
//! range (the calc-vs-store knob at block level) and return its hit/miss
//! totals as [`KernelCounters`]; the other drivers return zeros.
//!
//! [`KernelCounters`]: super::KernelCounters

use std::ops::Range;

use crate::cpu_ref::step::BlockData;
use crate::model::SharedFactors;

use super::invariant::InvariantCache;
use super::{micro, simd, InvariantPolicy, KernelCounters};

/// The per-sample primitive set a tile driver runs on, monomorphized per
/// `(J, R)` shape.  Implementations must preserve the oracle's operand
/// order per primitive (only rounding/association may differ).
pub(crate) trait TileMath<const J: usize, const R: usize> {
    /// Whether the storage-scheme drivers should route the invariant
    /// cache's elementwise products through the SIMD layer too.
    const SIMD: bool;
    /// `out = row · core` (`core` is `J x R` row-major).
    fn project(row: &[f32; J], core: &[f32], out: &mut [f32; R]);
    /// `out[j] = d · core[j, :]` for every `j`.
    fn db_rows(core: &[f32], d: &[f32; R], out: &mut [f32; J]);
    /// Dot product over the Kruskal rank.
    fn dot(a: &[f32; R], b: &[f32; R]) -> f32;
    /// SGD row update `out = row + lr * (err * db - lam * row)`.
    fn sgd_row(row: &[f32; J], db: &[f32; J], err: f32, lr: f32, lam: f32, out: &mut [f32; J]);
    /// Rank-1 accumulation `grad[j, :] += (err * row[j]) * d`.
    fn grad_accum(grad: &mut [f32], row: &[f32; J], d: &[f32; R], err: f32);
}

/// Exact tier: the unrolled scalar-order microkernels (bit-identical to
/// the oracle).
pub(crate) struct ExactMath;

impl<const J: usize, const R: usize> TileMath<J, R> for ExactMath {
    const SIMD: bool = false;

    #[inline(always)]
    fn project(row: &[f32; J], core: &[f32], out: &mut [f32; R]) {
        micro::project::<J, R>(row, core, out);
    }

    #[inline(always)]
    fn db_rows(core: &[f32], d: &[f32; R], out: &mut [f32; J]) {
        micro::db_rows::<J, R>(core, d, out);
    }

    #[inline(always)]
    fn dot(a: &[f32; R], b: &[f32; R]) -> f32 {
        micro::dot::<R>(a, b)
    }

    #[inline(always)]
    fn sgd_row(row: &[f32; J], db: &[f32; J], err: f32, lr: f32, lam: f32, out: &mut [f32; J]) {
        micro::sgd_row::<J>(row, db, err, lr, lam, out);
    }

    #[inline(always)]
    fn grad_accum(grad: &mut [f32], row: &[f32; J], d: &[f32; R], err: f32) {
        micro::grad_accum::<J, R>(grad, row, d, err);
    }
}

/// SIMD tier: explicit AVX2/NEON/portable primitives (tolerance-bounded).
pub(crate) struct SimdMath;

impl<const J: usize, const R: usize> TileMath<J, R> for SimdMath {
    const SIMD: bool = true;

    #[inline(always)]
    fn project(row: &[f32; J], core: &[f32], out: &mut [f32; R]) {
        simd::project_row(row, core, out);
    }

    #[inline(always)]
    fn db_rows(core: &[f32], d: &[f32; R], out: &mut [f32; J]) {
        simd::matvec_rows(core, d, out);
    }

    #[inline(always)]
    fn dot(a: &[f32; R], b: &[f32; R]) -> f32 {
        simd::dot(a, b)
    }

    #[inline(always)]
    fn sgd_row(row: &[f32; J], db: &[f32; J], err: f32, lr: f32, lam: f32, out: &mut [f32; J]) {
        simd::sgd_row(row, db, err, lr, lam, out);
    }

    #[inline(always)]
    fn grad_accum(grad: &mut [f32], row: &[f32; J], d: &[f32; R], err: f32) {
        simd::grad_accum(grad, row, d, err);
    }
}

/// Per-range scratch: gathered rows and the forward chain, all fixed-width.
struct Scratch<const J: usize, const R: usize> {
    /// Gathered factor rows `a^(m)`, one per mode.
    rows: Vec<[f32; J]>,
    /// Projections `c^(m) = a^(m) B^(m)`.
    c: Vec<[f32; R]>,
    /// Exclusion products `d^(m)`.
    d: Vec<[f32; R]>,
    /// Prefix products of `c` (length `n + 1`).
    pre: Vec<[f32; R]>,
    /// Suffix products of `c` (length `n + 1`).
    suf: Vec<[f32; R]>,
    db: [f32; J],
    new_row: [f32; J],
}

impl<const J: usize, const R: usize> Scratch<J, R> {
    fn new(n: usize) -> Scratch<J, R> {
        Scratch {
            rows: vec![[0.0; J]; n],
            c: vec![[0.0; R]; n],
            d: vec![[0.0; R]; n],
            pre: vec![[0.0; R]; n + 1],
            suf: vec![[0.0; R]; n + 1],
            db: [0.0; J],
            new_row: [0.0; J],
        }
    }
}

/// Projections, exclusion products and the prediction for one sample from
/// pre-gathered rows — the tiled analog of the oracle's `forward_rows`,
/// same prefix/suffix multiply order (the product chains are elementwise,
/// so they stay exact under every math).
fn forward<M: TileMath<J, R>, const J: usize, const R: usize>(
    cores: &[Vec<f32>],
    s: &mut Scratch<J, R>,
) -> f32 {
    let n = s.rows.len();
    for m in 0..n {
        M::project(&s.rows[m], &cores[m], &mut s.c[m]);
    }
    s.pre[0] = [1.0; R];
    for m in 0..n {
        for rr in 0..R {
            s.pre[m + 1][rr] = s.pre[m][rr] * s.c[m][rr];
        }
    }
    s.suf[n] = [1.0; R];
    for m in (0..n).rev() {
        for rr in 0..R {
            s.suf[m][rr] = s.suf[m + 1][rr] * s.c[m][rr];
        }
    }
    for m in 0..n {
        for rr in 0..R {
            s.d[m][rr] = s.pre[m][rr] * s.suf[m + 1][rr];
        }
    }
    s.pre[n].iter().sum()
}

fn load_all_rows<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    coords: &[u32],
    s: &mut Scratch<J, R>,
) {
    for m in 0..data.n {
        shared.load_row(m, coords[m] as usize, &mut s.rows[m]);
    }
}

/// FastTuckerPlus factor step (Eq. 12): update all factor rows per sample.
pub(crate) fn plus_factor<M: TileMath<J, R>, const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
) -> KernelCounters {
    let hp = data.hyper;
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<M, J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        for m in 0..data.n {
            M::db_rows(&data.cores[m], &s.d[m], &mut s.db);
            M::sgd_row(&s.rows[m], &s.db, err, hp.lr_a, hp.lam_a, &mut s.new_row);
            shared.store_row(m, coords[m] as usize, &s.new_row);
        }
    }
    KernelCounters::default()
}

/// FastTuckerPlus core step: accumulate `∂B^(m)` for every mode into
/// `grad` (`[N, J, R]`).
pub(crate) fn plus_core<M: TileMath<J, R>, const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
    grad: &mut [f32],
) -> KernelCounters {
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<M, J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        for m in 0..data.n {
            M::grad_accum(&mut grad[m * J * R..(m + 1) * J * R], &s.rows[m], &s.d[m], err);
        }
    }
    KernelCounters::default()
}

/// FastTucker factor step for one mode (Eq. 8): full forward, update only
/// the target mode's row.
pub(crate) fn mode_factor<M: TileMath<J, R>, const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
) -> KernelCounters {
    let hp = data.hyper;
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<M, J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        M::db_rows(&data.cores[mode], &s.d[mode], &mut s.db);
        M::sgd_row(&s.rows[mode], &s.db, err, hp.lr_a, hp.lam_a, &mut s.new_row);
        shared.store_row(mode, coords[mode] as usize, &s.new_row);
    }
    KernelCounters::default()
}

/// FastTucker core step for one mode (Eq. 9): accumulate `∂B^(mode)` into
/// `grad` (`[J, R]`).
pub(crate) fn mode_core<M: TileMath<J, R>, const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
) -> KernelCounters {
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<M, J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        M::grad_accum(grad, &s.rows[mode], &s.d[mode], err);
    }
    KernelCounters::default()
}

/// FasterTucker factor step for one mode (storage scheme): `d` via the
/// [`InvariantCache`], own projection recomputed from the live row.
pub(crate) fn stored_factor<M: TileMath<J, R>, const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    policy: InvariantPolicy,
) -> KernelCounters {
    let hp = data.hyper;
    let core = &data.cores[mode];
    let mut cache = InvariantCache::<R>::new(policy, data.n).with_simd(M::SIMD);
    let mut row = [0f32; J];
    let mut new_row = [0f32; J];
    let mut db = [0f32; J];
    let mut c_own = [0f32; R];
    for e in range {
        let i = data.coord(e, mode) as usize;
        let d = cache.exclusion(data, e, mode);
        shared.load_row(mode, i, &mut row);
        M::project(&row, core, &mut c_own);
        let err = data.values[e] - M::dot(&c_own, d);
        M::db_rows(core, d, &mut db);
        M::sgd_row(&row, &db, err, hp.lr_a, hp.lam_a, &mut new_row);
        shared.store_row(mode, i, &new_row);
    }
    cache.counters()
}

/// FasterTucker core step for one mode (storage scheme): prediction from
/// stored `C` rows, gradient into `grad` (`[J, R]`).
pub(crate) fn stored_core<M: TileMath<J, R>, const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
    policy: InvariantPolicy,
) -> KernelCounters {
    let mut cache = InvariantCache::<R>::new(policy, data.n).with_simd(M::SIMD);
    let mut row = [0f32; J];
    for e in range {
        let i = data.coord(e, mode) as usize;
        let d = cache.exclusion(data, e, mode);
        let crow: &[f32; R] = (&data.c_store[mode][i * R..i * R + R])
            .try_into()
            .expect("stored C row width");
        let err = data.values[e] - M::dot(crow, d);
        shared.load_row(mode, i, &mut row);
        M::grad_accum(grad, &row, d, err);
    }
    cache.counters()
}
