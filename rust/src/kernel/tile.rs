//! Tiled step drivers — one per (algorithm, phase), generic over the
//! compile-time `(J, R)` shape.
//!
//! Each driver walks its slot range sample-by-sample (factor phases must:
//! a later sample may touch a row an earlier sample just updated, and the
//! serial backend is defined as exactly the sequential trajectory) but
//! performs *all* per-sample arithmetic through the fixed-width
//! microkernels in [`super::micro`], whose fully unrolled `J`/`R` loops are
//! the CPU mirror of the L1 Pallas `[S, J] x [J, R]` tiles.  The
//! storage-scheme drivers thread an [`InvariantCache`] through the range,
//! implementing the calc-vs-store knob at the block level.
//!
//! Everything here is bit-identical to the scalar oracle in
//! [`crate::cpu_ref::step`]; the `kernel_parity` integration test pins it.

use std::ops::Range;

use crate::cpu_ref::step::BlockData;
use crate::model::SharedFactors;

use super::invariant::InvariantCache;
use super::{micro, InvariantPolicy};

/// Per-range scratch: gathered rows and the forward chain, all fixed-width.
struct Scratch<const J: usize, const R: usize> {
    /// Gathered factor rows `a^(m)`, one per mode.
    rows: Vec<[f32; J]>,
    /// Projections `c^(m) = a^(m) B^(m)`.
    c: Vec<[f32; R]>,
    /// Exclusion products `d^(m)`.
    d: Vec<[f32; R]>,
    /// Prefix products of `c` (length `n + 1`).
    pre: Vec<[f32; R]>,
    /// Suffix products of `c` (length `n + 1`).
    suf: Vec<[f32; R]>,
    db: [f32; J],
    new_row: [f32; J],
}

impl<const J: usize, const R: usize> Scratch<J, R> {
    fn new(n: usize) -> Scratch<J, R> {
        Scratch {
            rows: vec![[0.0; J]; n],
            c: vec![[0.0; R]; n],
            d: vec![[0.0; R]; n],
            pre: vec![[0.0; R]; n + 1],
            suf: vec![[0.0; R]; n + 1],
            db: [0.0; J],
            new_row: [0.0; J],
        }
    }
}

/// Projections, exclusion products and the prediction for one sample from
/// pre-gathered rows — the tiled analog of the oracle's `forward_rows`,
/// same prefix/suffix multiply order.
fn forward<const J: usize, const R: usize>(cores: &[Vec<f32>], s: &mut Scratch<J, R>) -> f32 {
    let n = s.rows.len();
    for m in 0..n {
        micro::project::<J, R>(&s.rows[m], &cores[m], &mut s.c[m]);
    }
    s.pre[0] = [1.0; R];
    for m in 0..n {
        for rr in 0..R {
            s.pre[m + 1][rr] = s.pre[m][rr] * s.c[m][rr];
        }
    }
    s.suf[n] = [1.0; R];
    for m in (0..n).rev() {
        for rr in 0..R {
            s.suf[m][rr] = s.suf[m + 1][rr] * s.c[m][rr];
        }
    }
    for m in 0..n {
        for rr in 0..R {
            s.d[m][rr] = s.pre[m][rr] * s.suf[m + 1][rr];
        }
    }
    s.pre[n].iter().sum()
}

fn load_all_rows<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    coords: &[u32],
    s: &mut Scratch<J, R>,
) {
    for m in 0..data.n {
        shared.load_row(m, coords[m] as usize, &mut s.rows[m]);
    }
}

/// FastTuckerPlus factor step (Eq. 12): update all factor rows per sample.
pub(crate) fn plus_factor<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
) {
    let hp = data.hyper;
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        for m in 0..data.n {
            micro::db_rows::<J, R>(&data.cores[m], &s.d[m], &mut s.db);
            micro::sgd_row::<J>(&s.rows[m], &s.db, err, hp.lr_a, hp.lam_a, &mut s.new_row);
            shared.store_row(m, coords[m] as usize, &s.new_row);
        }
    }
}

/// FastTuckerPlus core step: accumulate `∂B^(m)` for every mode into
/// `grad` (`[N, J, R]`).
pub(crate) fn plus_core<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    range: Range<usize>,
    grad: &mut [f32],
) {
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        for m in 0..data.n {
            micro::grad_accum::<J, R>(
                &mut grad[m * J * R..(m + 1) * J * R],
                &s.rows[m],
                &s.d[m],
                err,
            );
        }
    }
}

/// FastTucker factor step for one mode (Eq. 8): full forward, update only
/// the target mode's row.
pub(crate) fn mode_factor<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
) {
    let hp = data.hyper;
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        micro::db_rows::<J, R>(&data.cores[mode], &s.d[mode], &mut s.db);
        micro::sgd_row::<J>(&s.rows[mode], &s.db, err, hp.lr_a, hp.lam_a, &mut s.new_row);
        shared.store_row(mode, coords[mode] as usize, &s.new_row);
    }
}

/// FastTucker core step for one mode (Eq. 9): accumulate `∂B^(mode)` into
/// `grad` (`[J, R]`).
pub(crate) fn mode_core<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
) {
    let mut s = Scratch::<J, R>::new(data.n);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward::<J, R>(data.cores, &mut s);
        let err = data.values[e] - xhat;
        micro::grad_accum::<J, R>(grad, &s.rows[mode], &s.d[mode], err);
    }
}

/// FasterTucker factor step for one mode (storage scheme): `d` via the
/// [`InvariantCache`], own projection recomputed from the live row.
pub(crate) fn stored_factor<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    policy: InvariantPolicy,
) {
    let hp = data.hyper;
    let core = &data.cores[mode];
    let mut cache = InvariantCache::<R>::new(policy, data.n);
    let mut row = [0f32; J];
    let mut new_row = [0f32; J];
    let mut db = [0f32; J];
    let mut c_own = [0f32; R];
    for e in range {
        let i = data.coord(e, mode) as usize;
        let d = cache.exclusion(data, e, mode);
        shared.load_row(mode, i, &mut row);
        micro::project::<J, R>(&row, core, &mut c_own);
        let err = data.values[e] - micro::dot::<R>(&c_own, d);
        micro::db_rows::<J, R>(core, d, &mut db);
        micro::sgd_row::<J>(&row, &db, err, hp.lr_a, hp.lam_a, &mut new_row);
        shared.store_row(mode, i, &new_row);
    }
}

/// FasterTucker core step for one mode (storage scheme): prediction from
/// stored `C` rows, gradient into `grad` (`[J, R]`).
pub(crate) fn stored_core<const J: usize, const R: usize>(
    shared: &SharedFactors<'_>,
    data: &BlockData<'_>,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
    policy: InvariantPolicy,
) {
    let mut cache = InvariantCache::<R>::new(policy, data.n);
    let mut row = [0f32; J];
    for e in range {
        let i = data.coord(e, mode) as usize;
        let d = cache.exclusion(data, e, mode);
        let crow: &[f32; R] = (&data.c_store[mode][i * R..i * R + R])
            .try_into()
            .expect("stored C row width");
        let err = data.values[e] - micro::dot::<R>(crow, d);
        shared.load_row(mode, i, &mut row);
        micro::grad_accum::<J, R>(grad, &row, d, err);
    }
}
