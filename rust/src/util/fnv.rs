//! FNV-1a 64-bit hashing — the corruption tripwire every on-disk format in
//! this repo uses (FTCK checkpoints, FTB2 store sections).
//!
//! FNV-1a is not cryptographic; it detects accidental corruption (bit rot,
//! truncation, torn writes), which is exactly the failure model of local
//! checkpoint and dataset files.  One shared implementation keeps the
//! formats' checksums byte-compatible with each other and with the
//! documented specs.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100_0000_01b3;

/// Hash a byte slice with 64-bit FNV-1a.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // reference values of the 64-bit FNV-1a test suite
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn sensitive_to_every_byte() {
        let base = fnv1a(b"hello world");
        for i in 0..b"hello world".len() {
            let mut bytes = b"hello world".to_vec();
            bytes[i] ^= 1;
            assert_ne!(fnv1a(&bytes), base, "flip at byte {i} not detected");
        }
    }
}
