//! Scoped worker pool over std threads (no tokio/rayon in the offline set).
//!
//! The coordinator's host-side hot path — gathering factor rows for the next
//! block while the PJRT executable runs the current one — is parallelised
//! with `parallel_chunks`, the only primitive we need: split `n` items into
//! per-thread ranges and run a closure on each.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (env `FT_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("FT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(range)` over disjoint chunks of `0..n` on up to `threads` workers.
/// Blocks until all chunks are done.  `f` must be `Sync` (it is shared).
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(lo..hi));
        }
    });
}

/// A tiny free-list of reusable byte buffers.
///
/// The paged tensor store ([`crate::data::PagedTensor`]) recycles evicted
/// page buffers through one of these instead of round-tripping every
/// eviction through the allocator; anything that loads fixed-size chunks
/// in a loop can use it the same way.  `take` hands out a zero-filled
/// buffer of exactly the requested length (reusing a retired allocation
/// when one is available), `put` retires a buffer for reuse.  The free
/// list is capped so a burst of odd-sized buffers cannot pin memory.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Vec<Vec<u8>>,
}

/// Retired buffers kept around for reuse (beyond this they are dropped).
const POOL_KEEP: usize = 8;

impl BufferPool {
    /// An empty pool.
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// A zero-filled buffer of length `len`, reusing a retired allocation
    /// when one is available.
    pub fn take(&mut self, len: usize) -> Vec<u8> {
        let mut buf = self.free.pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0);
        buf
    }

    /// Retire a buffer for later reuse.
    pub fn put(&mut self, buf: Vec<u8>) {
        if self.free.len() < POOL_KEEP {
            self.free.push(buf);
        }
    }
}

/// Work-stealing-ish dynamic scheduler: workers grab items one index at a
/// time via an atomic counter.  Better than `parallel_chunks` when item cost
/// is very uneven (e.g. fiber-sampler batches).
pub fn parallel_items<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(1000, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn items_cover_all_once() {
        let hits: Vec<AtomicU64> = (0..537).map(|_| AtomicU64::new(0)).collect();
        parallel_items(537, 5, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn buffer_pool_recycles_and_zeroes() {
        let mut pool = BufferPool::new();
        let mut a = pool.take(16);
        assert_eq!(a, vec![0u8; 16]);
        a.iter_mut().for_each(|b| *b = 0xFF);
        let ptr = a.as_ptr();
        pool.put(a);
        // same allocation comes back, zeroed, even at a different length
        let b = pool.take(8);
        assert_eq!(b, vec![0u8; 8]);
        assert_eq!(b.as_ptr(), ptr);
        let c = pool.take(4);
        assert_eq!(c, vec![0u8; 4]);
    }

    #[test]
    fn zero_and_one_items() {
        parallel_chunks(0, 4, |_| panic!("should not run"));
        let ran = AtomicU64::new(0);
        parallel_chunks(1, 4, |r| {
            assert_eq!(r, 0..1);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }
}
