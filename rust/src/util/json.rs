//! Minimal JSON parser + emitter (no serde in the offline crate set).
//!
//! Covers the full JSON grammar we exchange with the build pipeline:
//! `artifacts/manifest.json`, metrics dumps, bench rows and checkpoints.
//! Numbers parse to f64; integer access checks round-trip exactness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The contained string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The contained number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The contained number as usize, if it round-trips exactly.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The contained array, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The contained bool, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // -0.0 must stay on the float path ("-0" parses back with
                // the sign bit; "0" would not) — the serve wire protocol
                // relies on every finite f32 round-tripping bit-exactly
                if n.fract() == 0.0 && n.abs() < 1e15 && !(*n == 0.0 && n.is_sign_negative()) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder: object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience builder: number.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Convenience builder: string.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Convenience builder: array.
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"format":1,"artifacts":[{"name":"k_n3","inputs":[[3,512,16],[2]],"s":512}],"dtype":"f32"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("k_n3"));
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"c\" é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" é"));
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [("0", 0.0), ("-3.5", -3.5), ("1e3", 1000.0), ("2.5e-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want));
        }
    }

    #[test]
    fn negative_zero_roundtrips_with_its_sign() {
        let dumped = Json::Num(-0.0).dump();
        assert_eq!(dumped, "-0");
        let back = Json::parse(&dumped).unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative());
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2"] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"[[1,2],[3,[4,null,true,false]]]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[1], Json::Null);
    }
}
