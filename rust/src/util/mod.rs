//! Dependency-free substrates: rng, json, thread pool, CLI parsing.
//!
//! The build environment is offline with a fixed vendored crate set (no
//! `rand`/`serde`/`rayon`/`clap`/`tokio`/`criterion`) — see DESIGN.md §3.
//! Each substitute is small, documented and unit-tested.

pub mod cli;
pub mod fnv;
pub mod json;
pub mod pool;
pub mod rng;
