//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own: a SplitMix64
//! seeder + PCG32 (XSH-RR) streams.  PCG32 is statistically solid for
//! simulation workloads, cheap (one 64-bit LCG step per draw), and lets every
//! subsystem (synth data, samplers, init) own an independent, reproducible
//! stream derived from a single run seed.

/// SplitMix64: used to expand one user seed into well-mixed stream seeds.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the sequence.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (pcg_xsh_rr_64_32): the workhorse stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a stream from `seed`; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xDA94_2042_E4DD_58B5));
        let mut rng = Self {
            state: 0,
            inc: (sm.next_u64() << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    /// Next 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut m = (self.next_u32() as u64).wrapping_mul(bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                m = (self.next_u32() as u64).wrapping_mul(bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in `[0, bound)` (bound may exceed u32::MAX).
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        if bound <= u32::MAX as usize {
            self.gen_range(bound as u32) as usize
        } else {
            (self.next_u64() % bound as u64) as usize
        }
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (pairs cached would add state; the
    /// single-draw form is fine for init/synth workloads).
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = (1.0 - self.gen_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed index sampler over `[0, n)` with exponent `s` — used by
/// the synthetic generators to reproduce the index skew of real rating
/// tensors (a few very active users/items, a long tail).
/// Rejection-inversion (Hörmann & Derflinger), O(1) amortized per draw.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dividing: f64,
}

impl Zipf {
    /// Sampler over `[0, n)` with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let n = n as f64;
        let h = |x: f64, s: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        Self {
            n,
            s,
            h_x1: h(1.5, s) - 1.0,
            h_n: h(n + 0.5, s),
            dividing: h(0.5, s),
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-9 {
            x.exp() - 1.0
        } else {
            (1.0 + x * (1.0 - self.s)).powf(1.0 / (1.0 - self.s)) - 1.0
        }
    }

    /// Draw an index in `[0, n)` (0 is the most frequent).
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        loop {
            let u = self.dividing + rng.gen_f64() * (self.h_n - self.dividing);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n);
            if k - x <= self.h_x1
                || u >= {
                    let hk = if (self.s - 1.0).abs() < 1e-9 {
                        (k + 0.5).ln()
                    } else {
                        ((k + 0.5).powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
                    };
                    hk - k.powf(-self.s)
                }
            {
                return k as usize - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_reproducible() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg32_streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_unbiased_bounds() {
        let mut rng = Pcg32::new(7, 3);
        for bound in [1u32, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(rng.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_f32_in_unit_interval() {
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..1000 {
            let v = rng.gen_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(9, 0);
        let n = 20_000;
        let (mut sum, mut sumsq) = (0f64, 0f64);
        for _ in 0..n {
            let v = rng.gen_normal() as f64;
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_skew_and_bounds() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Pcg32::new(3, 0);
        let mut head = 0usize;
        let n = 10_000;
        for _ in 0..n {
            let k = z.sample(&mut rng);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // top-1% of indices should take far more than 1% of mass
        assert!(head > n / 20, "head {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(5, 5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
