//! Tiny flag parser (no clap in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Unknown flags are an error so typos surface immediately.

use std::collections::BTreeMap;

/// Parsed command-line flags and positionals.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    allowed: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name).  `allowed` lists valid flag
    /// names; boolean flags get the value `"true"`.
    ///
    /// Every entry of `bools` must also appear in `allowed` — a mismatch
    /// is a declaration bug in the caller and surfaces as an `Err` here
    /// rather than as a flag that can never be set.
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        allowed: &[&str],
        bools: &[&str],
    ) -> Result<Args, String> {
        if let Some(b) = bools.iter().find(|b| !allowed.contains(*b)) {
            return Err(format!(
                "internal: boolean flag --{b} is not in the allowed list"
            ));
        }
        let mut out = Args {
            allowed: allowed.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                let (key, val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if !allowed.contains(&key.as_str()) {
                    return Err(format!("unknown flag --{key}"));
                }
                let val = match val {
                    Some(v) => v,
                    None if bools.contains(&key.as_str()) => "true".to_string(),
                    None => it
                        .next()
                        .ok_or_else(|| format!("--{key} needs a value"))?,
                };
                out.flags.insert(key, val);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Check that `key` was declared in the `allowed` list handed to
    /// [`Args::parse`].  A failure here is a programmer typo in a lookup
    /// key, not user input — release builds used to silently return
    /// `None` for these, hiding the bug.
    fn declared(&self, key: &str) -> Result<(), String> {
        if self.allowed.iter().any(|k| k == key) {
            Ok(())
        } else {
            Err(format!(
                "internal: lookup of undeclared flag --{key} (not in the Args::parse allowed list)"
            ))
        }
    }

    /// Raw value of `--key`, if present.
    ///
    /// # Panics
    /// If `key` was never declared to [`Args::parse`] — that is a bug in
    /// the calling command, in every build profile.  Use
    /// [`Args::get_parse`] for the `Err`-returning variant.
    pub fn get(&self, key: &str) -> Option<&str> {
        if let Err(e) = self.declared(key) {
            panic!("{e}");
        }
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Value of `--key`, or `default` when absent.  Panics like
    /// [`Args::get`] on an undeclared key.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse `--key` into `T`, or return `default` when absent.  An
    /// undeclared lookup key is an `Err` (not a silent `None`-as-default).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        self.declared(key)?;
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Whether boolean `--key` was given (or set to a truthy value).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Positional (non-flag) arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Parse a coordinate list: comma- and/or whitespace-separated `u32`s
/// (`"1,2,3"`, `"1 2 3"`, `"1, 2, 3"` all work — the forms query tools
/// paste).  Rejects empty input and non-numeric tokens.
pub fn parse_u32_list(s: &str) -> Result<Vec<u32>, String> {
    let out: Result<Vec<u32>, String> = s
        .split(|c: char| c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<u32>().map_err(|_| format!("bad coordinate {t:?}")))
        .collect();
    let out = out?;
    if out.is_empty() {
        return Err("empty coordinate list".to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let a = Args::parse(
            argv(&["--n", "3", "--j=16", "--verbose", "pos1"]),
            &["n", "j", "verbose"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get("n"), Some("3"));
        assert_eq!(a.get_parse("j", 0usize).unwrap(), 16);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(argv(&["--nope"]), &["n"], &[]).is_err());
        assert!(Args::parse(argv(&["--n"]), &["n"], &[]).is_err());
    }

    #[test]
    fn rejects_bool_outside_allowed() {
        assert!(Args::parse(argv(&[]), &["n"], &["verbose"]).is_err());
    }

    #[test]
    fn undeclared_lookup_is_an_error() {
        let a = Args::parse(argv(&["--n", "3"]), &["n"], &[]).unwrap();
        assert!(a.get_parse("typo", 0usize).is_err());
    }

    #[test]
    #[should_panic(expected = "undeclared flag --typo")]
    fn undeclared_get_panics() {
        let a = Args::parse(argv(&[]), &["n"], &[]).unwrap();
        let _ = a.get("typo");
    }

    #[test]
    fn u32_list_forms() {
        assert_eq!(parse_u32_list("1,2,3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_u32_list("1 2 3").unwrap(), vec![1, 2, 3]);
        assert_eq!(parse_u32_list("1, 2,  3").unwrap(), vec![1, 2, 3]);
        assert!(parse_u32_list("").is_err());
        assert!(parse_u32_list("1,x,3").is_err());
        assert!(parse_u32_list("-1").is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv(&[]), &["n"], &[]).unwrap();
        assert_eq!(a.get_parse("n", 7usize).unwrap(), 7);
        assert_eq!(a.get_or("n", "x"), "x");
    }
}
