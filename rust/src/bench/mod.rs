//! Measurement harness (criterion is not in the offline crate set).
//!
//! Methodology: `warmup` untimed runs, then `reps` timed runs; report
//! median and MAD (median absolute deviation) — robust to the occasional
//! scheduler hiccup that pollutes mean/stddev on shared machines.  Rows are
//! printed as a human table and appended as JSON lines for regeneration
//! scripts (EXPERIMENTS.md cites these).

use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// One measurement row.
#[derive(Clone, Debug)]
pub struct Row {
    /// Case label (`<config>/<phase>` for phase rows).
    pub label: String,
    /// Median wall time in seconds.
    pub median_s: f64,
    /// Median absolute deviation in seconds.
    pub mad_s: f64,
    /// Timed repetitions behind the median.
    pub reps: usize,
    /// free-form extras (speedup columns, padding ratios, ...)
    pub extra: Vec<(String, f64)>,
}

impl Row {
    /// Serialize for the `BENCH_JSON` scrape lines.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("label", json::s(&self.label)),
            ("median_s", json::num(self.median_s)),
            ("mad_s", json::num(self.mad_s)),
            ("reps", json::num(self.reps as f64)),
        ];
        let extras: Vec<(String, Json)> = self
            .extra
            .iter()
            .map(|(k, v)| (k.clone(), json::num(*v)))
            .collect();
        let mut obj = match json::obj(pairs.drain(..).collect()) {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        for (k, v) in extras {
            obj.insert(k, v);
        }
        Json::Obj(obj)
    }
}

/// Time `f` with the harness methodology; `f` returns a scalar that is
/// folded into a black-box sink so the work cannot be optimized away.
pub fn measure<F: FnMut() -> f64>(label: &str, warmup: usize, reps: usize, mut f: F) -> Row {
    let mut sink = 0f64;
    for _ in 0..warmup {
        sink += f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        sink += f();
        times.push(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(sink);
    let (median, mad) = median_mad(&mut times);
    Row {
        label: label.to_string(),
        median_s: median,
        mad_s: mad,
        reps: reps.max(1),
        extra: Vec::new(),
    }
}

/// `p`-th percentile (0–100) of a sample, nearest-rank on the sorted data
/// (sorts in place).  Used for the serving-latency p50/p99 rows.
pub fn percentile(xs: &mut [f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p.clamp(0.0, 100.0) / 100.0 * (xs.len() - 1) as f64).round() as usize;
    xs[rank]
}

/// Median and MAD of a sample (sorts in place).
pub fn median_mad(xs: &mut [f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = xs[xs.len() / 2];
    let mut dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (med, dev[dev.len() / 2])
}

/// Pretty-print a set of rows as an aligned table with a title, and emit
/// `BENCH_JSON {..}` lines that tooling can scrape from bench output.
pub fn report(title: &str, rows: &[Row]) {
    println!("\n=== {title} ===");
    let w = rows.iter().map(|r| r.label.len()).max().unwrap_or(8).max(8);
    println!("{:<w$}  {:>12}  {:>10}  extras", "case", "median", "mad");
    for r in rows {
        print!(
            "{:<w$}  {:>12}  {:>10}",
            r.label,
            fmt_secs(r.median_s),
            fmt_secs(r.mad_s)
        );
        for (k, v) in &r.extra {
            print!("  {k}={v:.4}");
        }
        println!();
    }
    for r in rows {
        println!("BENCH_JSON {}", r.to_json().dump());
    }
}

/// Human-scale duration formatting.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Effective host memory bandwidth (bytes/s) measured with a large memcpy —
/// feeds the Table 7 analytic traffic model.
pub fn measure_bandwidth() -> f64 {
    let n = 64 * 1024 * 1024 / 4; // 64 MiB of f32
    let src = vec![1.0f32; n];
    let mut dst = vec![0.0f32; n];
    // warm
    dst.copy_from_slice(&src);
    let t0 = Instant::now();
    let reps = 8;
    for _ in 0..reps {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    }
    let dt = t0.elapsed().as_secs_f64();
    // read + write per copy
    (reps * 2 * n * 4) as f64 / dt
}

/// Measure the two training phases (factor / core) of one configuration on
/// one tensor — the primitive every paper-table bench is built from.
/// Returns `[factor_row, core_row]` with memory-time and padding extras.
pub fn bench_phases(
    label: &str,
    train: &crate::tensor::SparseTensor,
    cfg: crate::coordinator::TrainConfig,
    warmup: usize,
    reps: usize,
) -> anyhow::Result<Vec<Row>> {
    let mut trainer = crate::coordinator::Trainer::new(train, cfg)?;
    let mut mk = |phase: &str| -> anyhow::Result<Row> {
        let mut mems = Vec::new();
        let mut pads = Vec::new();
        let mut row = {
            let trainer = &mut trainer;
            let mems = &mut mems;
            let pads = &mut pads;
            measure(&format!("{label}/{phase}"), warmup, reps, move || {
                let st = if phase == "factor" {
                    trainer.factor_phase(train).expect("factor phase")
                } else {
                    trainer.core_phase(train).expect("core phase")
                };
                mems.push(st.memory().as_secs_f64());
                pads.push(st.padding_ratio());
                st.total().as_secs_f64()
            })
        };
        let (mem, _) = median_mad(&mut mems);
        row.extra.push(("memory_s".into(), mem));
        row.extra.push(("padding".into(), pads.last().copied().unwrap_or(0.0)));
        Ok(row)
    };
    Ok(vec![mk("factor")?, mk("core")?])
}

/// Convenience: time a single closure once (setup-heavy paths).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_mad_basics() {
        let mut xs = vec![5.0, 1.0, 3.0];
        let (m, d) = median_mad(&mut xs);
        assert_eq!(m, 3.0);
        assert_eq!(d, 2.0);
    }

    #[test]
    fn percentile_ranks() {
        let mut xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 100.0);
        assert_eq!(percentile(&mut xs, 50.0), 51.0); // nearest-rank on 0..=99
        let mut one = vec![7.0];
        assert_eq!(percentile(&mut one, 99.0), 7.0);
    }

    #[test]
    fn measure_produces_sane_row() {
        let r = measure("t", 1, 3, || {
            std::thread::sleep(Duration::from_millis(2));
            1.0
        });
        assert!(r.median_s >= 0.001);
        assert_eq!(r.reps, 3);
    }

    #[test]
    fn bandwidth_positive() {
        let bw = measure_bandwidth();
        assert!(bw > 1e8, "bandwidth {bw}"); // > 100 MB/s on anything real
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(0.002).ends_with(" ms"));
        assert!(fmt_secs(2e-6).ends_with(" µs"));
    }
}
