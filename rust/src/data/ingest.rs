//! Streaming ingest: text COO or `FTB1` binary → `FTB2` paged store, in
//! constant memory.
//!
//! Raw HOHDST tensors "are impractical due to significant memory
//! overhead" (the paper's motivation) — so the converter never
//! materializes the tensor.  Text input is parsed line by line through
//! [`io::parse_text_into`] straight into a [`StoreWriter`]; `FTB1` input
//! (whose layout is all-coords-then-all-values) is zipped entry by entry
//! from two cursors over the same file.  In both cases the resident set
//! is one section buffer: `peak_buffered` in the returned stats is the
//! high-water mark the memory-bound tests assert on.
//!
//! The writer re-validates every entry (coordinate bounds, finite
//! values), so a hostile or corrupt input fails with a located error and
//! a bad store is never produced.

use std::fs::File;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::store::{StoreMeta, StoreWriter};
use crate::tensor::io::{self, EntrySink};

/// What one ingest run did.
#[derive(Clone, Debug, PartialEq)]
pub struct IngestStats {
    /// Entries written.
    pub nnz: u64,
    /// Sections written.
    pub pages: u64,
    /// Bytes of the finished store.
    pub out_bytes: u64,
    /// High-water mark of entries buffered in RAM (≤ the page size, by
    /// construction).
    pub peak_buffered: usize,
}

/// Convert `input` (text COO or `FTB1`, sniffed by magic) into an `FTB2`
/// store at `output` with `page_entries` entries per section.
pub fn ingest(input: &Path, output: &Path, page_entries: usize) -> Result<IngestStats> {
    let mut f = File::open(input).with_context(|| format!("open {input:?}"))?;
    let mut magic = [0u8; 4];
    let sniffed = match f.read_exact(&mut magic) {
        Ok(()) => &magic,
        // shorter than 4 bytes: not a binary format, let the text parser
        // produce its located error
        Err(_) => b"\0\0\0\0",
    };
    match sniffed {
        b"FTB1" => ingest_ftb1(input, output, page_entries)
            .with_context(|| format!("ingesting FTB1 {input:?}")),
        b"FTB2" => bail!("{input:?} is already an FTB2 store"),
        _ => {
            f.seek(SeekFrom::Start(0))?;
            ingest_text(BufReader::new(f), output, page_entries)
                .with_context(|| format!("ingesting text {input:?}"))
        }
    }
}

/// Sink adapter: create the store when the `dims` header arrives, then
/// stream every entry into it.
struct WriterSink<'a> {
    output: &'a Path,
    page_entries: usize,
    writer: Option<StoreWriter>,
}

impl EntrySink for WriterSink<'_> {
    fn on_dims(&mut self, dims: &[u32]) -> Result<()> {
        self.writer = Some(StoreWriter::create(self.output, dims, self.page_entries)?);
        Ok(())
    }

    fn on_entry(&mut self, coords: &[u32], value: f32) -> Result<()> {
        self.writer
            .as_mut()
            .expect("on_dims precedes entries")
            .push(coords, value)
    }
}

/// Stream a text COO reader into a new store (see [`ingest`]).
pub fn ingest_text<R: BufRead>(
    reader: R,
    output: &Path,
    page_entries: usize,
) -> Result<IngestStats> {
    let mut sink = WriterSink {
        output,
        page_entries,
        writer: None,
    };
    io::parse_text_into(reader, &mut sink)?;
    let writer = sink.writer.expect("parse_text_into guarantees a dims header");
    finish(writer)
}

fn ingest_ftb1(input: &Path, output: &Path, page_entries: usize) -> Result<IngestStats> {
    let f = File::open(input)?;
    let file_len = f.metadata()?.len();
    let mut coords_r = BufReader::new(f);
    let header = io::read_ftb1_header(&mut coords_r)?;
    header.check_len(file_len)?;
    let n = header.dims.len();
    // second cursor over the same file, positioned at the values block
    // (FTB1 is coords-then-values, so a constant-memory conversion zips
    // two sequential streams instead of loading either side)
    let mut values_r = BufReader::new(File::open(input)?);
    values_r.seek(SeekFrom::Start(header.values_offset()))?;
    let mut writer = StoreWriter::create(output, &header.dims, page_entries)?;
    let mut cbuf = vec![0u8; n * 4];
    let mut coords = vec![0u32; n];
    let mut vbuf = [0u8; 4];
    for e in 0..header.nnz {
        coords_r
            .read_exact(&mut cbuf)
            .with_context(|| format!("entry {e}: coords"))?;
        for (c, b) in coords.iter_mut().zip(cbuf.chunks_exact(4)) {
            *c = u32::from_le_bytes(b.try_into().unwrap());
        }
        values_r
            .read_exact(&mut vbuf)
            .with_context(|| format!("entry {e}: value"))?;
        writer.push(&coords, f32::from_le_bytes(vbuf))?;
    }
    finish(writer)
}

fn finish(writer: StoreWriter) -> Result<IngestStats> {
    let peak_buffered = writer.peak_buffered();
    let meta: StoreMeta = writer.finish()?;
    Ok(IngestStats {
        nnz: meta.nnz,
        pages: meta.num_pages(),
        out_bytes: meta.file_len()?,
        peak_buffered,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::read_store;
    use crate::tensor::io::{toy_dataset, write_binary, write_text};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ft_ingest_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn text_and_ftb1_ingest_agree_bitwise() {
        let t = toy_dataset();
        let text = tmp("toy.coo");
        let ftb1 = tmp("toy.ftb");
        write_text(&t, &text).unwrap();
        write_binary(&t, &ftb1).unwrap();
        let s1 = ingest(&text, &tmp("from_text.ftb2"), 7).unwrap();
        let s2 = ingest(&ftb1, &tmp("from_ftb1.ftb2"), 7).unwrap();
        assert_eq!(s1.nnz, t.nnz() as u64);
        assert_eq!(s1, s2);
        let a = read_store(&tmp("from_text.ftb2")).unwrap();
        let b = read_store(&tmp("from_ftb1.ftb2")).unwrap();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.values, b.values);
        assert_eq!(a.indices, t.indices);
        assert_eq!(a.values, t.values); // text round-trip is value-exact
    }

    #[test]
    fn ingest_rejects_bad_inputs() {
        let bad = tmp("bad.coo");
        std::fs::write(&bad, "dims 4 4\n0 0 not_a_number\n").unwrap();
        let err = ingest(&bad, &tmp("bad.ftb2"), 8).unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
        // a failed ingest must not leave anything at the destination —
        // the writer works on a .tmp sibling until finish() renames it
        assert!(!tmp("bad.ftb2").exists(), "failed ingest left a store behind");
        // re-ingesting a store is an error, not a silent copy
        let t = toy_dataset();
        let store = tmp("already.ftb2");
        crate::data::store::write_store(&t, &store, 8).unwrap();
        assert!(ingest(&store, &tmp("twice.ftb2"), 8).is_err());
    }

    #[test]
    fn memory_is_bounded_by_the_page() {
        let t = toy_dataset(); // 64 entries
        let text = tmp("bound.coo");
        write_text(&t, &text).unwrap();
        let stats = ingest(&text, &tmp("bound.ftb2"), 5).unwrap();
        assert!(stats.peak_buffered <= 5, "peak {}", stats.peak_buffered);
        assert_eq!(stats.pages, (t.nnz() as u64).div_ceil(5));
    }
}
