//! The `FTB2` on-disk tensor store: a paged, checksummed binary layout for
//! HOHDST tensors too large to hold in RAM.
//!
//! ## Layout (all integers little-endian)
//!
//! ```text
//! offset            field
//! 0                 magic  b"FTB2"
//! 4                 version        u32 (currently 1)
//! 8                 order N        u32 (2..=16)
//! 12                page entries P u32 (entries per section, 1..=2^22)
//! 16                nnz            u64
//! 24                value sum      f64 bit pattern (sum of values as f64,
//!                                  accumulated in entry order)
//! 32                dims           u32 x N
//! 32 + 4N           header checksum  u64 FNV-1a over bytes [0, 32 + 4N)
//! --- then ceil(nnz / P) sections, section p holding the L_p = min(P,
//!     nnz - pP) entries [pP, pP + L_p):
//! ...               coords         u32 x (L_p * N), entry-major
//! ...               values         f32 x L_p
//! ...               section checksum u64 FNV-1a over the section payload
//! ```
//!
//! Every section before the last is full, so section offsets are pure
//! arithmetic — the paged reader seeks straight to a section with one
//! `read_at`, no index required.  The default page size equals the CPU
//! backend's sampler block size `S`
//! ([`crate::coordinator::backend::CPU_BLOCK_S`]), so one page fault per
//! uniformly-sampled block is the expected steady state.
//!
//! Every byte of the file is covered by a checksum (header bytes by the
//! header checksum, payload bytes by their section checksum, and the
//! checksum fields by their own mismatch), and the header additionally
//! pins the exact file length — so truncation, trailing garbage and any
//! single-bit flip are all detected by [`open_store`] / [`verify_store`]
//! (pinned by a bit-flip sweep test over a golden fixture).

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::tensor::SparseTensor;
use crate::util::fnv::fnv1a;

/// Magic bytes of the paged store format.
pub const MAGIC: &[u8; 4] = b"FTB2";

/// Current store format version.
pub const VERSION: u32 = 1;

/// Default entries per section — the CPU backend's sampler block size, so
/// a staged block touches one page in the sequential limit.
pub const DEFAULT_PAGE_ENTRIES: usize = crate::coordinator::backend::CPU_BLOCK_S;

/// Largest accepted entries-per-section (keeps one page buffer small
/// enough to be "a chunk", not "the dataset").
pub const MAX_PAGE_ENTRIES: usize = 1 << 22;

/// Parsed FTB2 header: everything needed to address and verify sections.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreMeta {
    /// Dimension sizes `I_n`, length N.
    pub dims: Vec<u32>,
    /// Entries per section (all sections except the last hold exactly
    /// this many).
    pub page_entries: usize,
    /// Total stored entries.
    pub nnz: u64,
    /// Sum of all values, accumulated as `f64` in entry order (the
    /// constant-memory analog of [`SparseTensor::mean_value`]'s sum).
    pub value_sum: f64,
}

impl StoreMeta {
    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Header length in bytes (magic through header checksum).
    pub fn header_len(&self) -> u64 {
        40 + 4 * self.dims.len() as u64
    }

    /// Number of sections.
    pub fn num_pages(&self) -> u64 {
        self.nnz.div_ceil(self.page_entries as u64)
    }

    /// Entries held by section `page` (full except possibly the last).
    pub fn page_len(&self, page: u64) -> usize {
        let lo = page * self.page_entries as u64;
        debug_assert!(lo < self.nnz || (self.nnz == 0 && page == 0));
        (self.nnz - lo).min(self.page_entries as u64) as usize
    }

    /// Payload bytes of section `page` (coords + values, no checksum).
    pub fn page_payload_bytes(&self, page: u64) -> usize {
        self.page_len(page) * (self.order() + 1) * 4
    }

    /// Absolute file offset of section `page`.
    pub fn page_offset(&self, page: u64) -> u64 {
        let full = (self.page_entries * (self.order() + 1) * 4 + 8) as u64;
        self.header_len() + page * full
    }

    /// Exact file length this header implies, with overflow-checked
    /// arithmetic so a hostile `nnz` cannot wrap into a plausible size.
    pub fn file_len(&self) -> Result<u64> {
        let per_entry = (self.order() as u64 + 1) * 4;
        let payload = self
            .nnz
            .checked_mul(per_entry)
            .ok_or_else(|| anyhow!("nnz {} overflows the addressable payload", self.nnz))?;
        self.header_len()
            .checked_add(payload)
            .and_then(|x| x.checked_add(self.num_pages() * 8))
            .ok_or_else(|| anyhow!("store length overflows u64"))
    }

    /// Mean of the stored values — bit-identical to
    /// [`SparseTensor::mean_value`] on the same data because both divide
    /// the same in-order `f64` sum.
    pub fn mean_value(&self) -> f32 {
        if self.nnz == 0 {
            return 0.0;
        }
        (self.value_sum / self.nnz as f64) as f32
    }

    /// Serialize the header, including its trailing checksum.
    pub fn header_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.header_len() as usize);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.order() as u32).to_le_bytes());
        out.extend_from_slice(&(self.page_entries as u32).to_le_bytes());
        out.extend_from_slice(&self.nnz.to_le_bytes());
        out.extend_from_slice(&self.value_sum.to_bits().to_le_bytes());
        for &d in &self.dims {
            out.extend_from_slice(&d.to_le_bytes());
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }
}

fn read_exact<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<()> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) => Err(anyhow!("store truncated: {e}")),
    }
}

/// Read and verify an FTB2 header from `r` (checksum + sanity ranges; the
/// caller checks the file length against [`StoreMeta::file_len`]).
pub fn read_header<R: Read>(r: &mut R) -> Result<StoreMeta> {
    let mut fixed = [0u8; 16];
    read_exact(r, &mut fixed)?;
    ensure!(&fixed[0..4] == MAGIC, "not an FTB2 store (bad magic)");
    let version = u32::from_le_bytes(fixed[4..8].try_into().unwrap());
    ensure!(version == VERSION, "unsupported FTB2 version {version}");
    let order = u32::from_le_bytes(fixed[8..12].try_into().unwrap()) as usize;
    ensure!((2..=16).contains(&order), "implausible order {order}");
    let page_entries = u32::from_le_bytes(fixed[12..16].try_into().unwrap()) as usize;
    ensure!(
        (1..=MAX_PAGE_ENTRIES).contains(&page_entries),
        "implausible page size {page_entries}"
    );
    let mut rest = vec![0u8; 16 + 4 * order + 8];
    read_exact(r, &mut rest)?;
    let (body, tail) = rest.split_at(16 + 4 * order);
    let nnz = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let value_sum = f64::from_bits(u64::from_le_bytes(body[8..16].try_into().unwrap()));
    let dims: Vec<u32> = body[16..]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let mut header = fixed.to_vec();
    header.extend_from_slice(body);
    ensure!(
        fnv1a(&header) == stored,
        "FTB2 header checksum mismatch (corrupt or truncated store)"
    );
    ensure!(
        nnz == 0 || value_sum.is_finite(),
        "FTB2 header carries a non-finite value sum"
    );
    Ok(StoreMeta {
        dims,
        page_entries,
        nnz,
        value_sum,
    })
}

/// Open a store and verify its header and exact file length.  Section
/// payloads are *not* scanned — [`verify_store`] does that.
pub fn open_store(path: &Path) -> Result<(File, StoreMeta)> {
    let mut f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let meta = read_header(&mut f).with_context(|| format!("{path:?}"))?;
    let want = meta.file_len()?;
    let stat = f.metadata().with_context(|| format!("stat {path:?}"))?;
    let have = stat.len();
    ensure!(
        have == want,
        "{path:?}: header implies {want} bytes but the file has {have} \
         (truncated or corrupt store)"
    );
    Ok((f, meta))
}

/// Open a store and verify every section checksum with one sequential,
/// constant-memory pass (one page buffer).  This is what
/// [`crate::data::PagedTensor::open`] runs, so any store that reaches the
/// training loop is known-good end to end.
pub fn verify_store(path: &Path) -> Result<(File, StoreMeta)> {
    let (mut f, meta) = open_store(path)?;
    let mut payload = vec![0u8; meta.page_payload_bytes(0).max(1)];
    let mut tail = [0u8; 8];
    for page in 0..meta.num_pages() {
        let len = meta.page_payload_bytes(page);
        read_exact(&mut f, &mut payload[..len])
            .with_context(|| format!("{path:?}: section {page}"))?;
        read_exact(&mut f, &mut tail).with_context(|| format!("{path:?}: section {page}"))?;
        ensure!(
            fnv1a(&payload[..len]) == u64::from_le_bytes(tail),
            "{path:?}: section {page} checksum mismatch (corrupt store)"
        );
    }
    Ok((f, meta))
}

/// Materialize a whole store into RAM (checksums verified).  This is the
/// `read_auto` path for small `.ftb2` files; large tensors should stay
/// paged through [`crate::data::PagedTensor`] instead.
pub fn read_store(path: &Path) -> Result<SparseTensor> {
    let (mut f, meta) = open_store(path)?;
    let n = meta.order();
    let mut t = SparseTensor::new(meta.dims.clone());
    t.indices.reserve(meta.nnz as usize * n);
    t.values.reserve(meta.nnz as usize);
    let mut payload = vec![0u8; meta.page_payload_bytes(0).max(1)];
    let mut tail = [0u8; 8];
    for page in 0..meta.num_pages() {
        let len = meta.page_payload_bytes(page);
        read_exact(&mut f, &mut payload[..len])
            .with_context(|| format!("{path:?}: section {page}"))?;
        read_exact(&mut f, &mut tail).with_context(|| format!("{path:?}: section {page}"))?;
        ensure!(
            fnv1a(&payload[..len]) == u64::from_le_bytes(tail),
            "{path:?}: section {page} checksum mismatch (corrupt store)"
        );
        let entries = meta.page_len(page);
        let (coords, values) = payload[..len].split_at(entries * n * 4);
        for c in coords.chunks_exact(4) {
            t.indices.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        for v in values.chunks_exact(4) {
            t.values.push(f32::from_le_bytes(v.try_into().unwrap()));
        }
    }
    t.validate().with_context(|| format!("{path:?}"))?;
    Ok(t)
}

/// Streaming FTB2 writer with memory bounded by one section.
///
/// `push` buffers at most `page_entries` entries before flushing a
/// checksummed section to disk, so ingesting an arbitrarily large tensor
/// holds O(page) memory by construction (the ingest tests assert the
/// tracked [`StoreWriter::peak_buffered`] never exceeds the page size).
/// The header is written as a placeholder at create time and patched with
/// the final `nnz` / value sum / checksum in [`StoreWriter::finish`].
///
/// Like the FTCK checkpoint writer, all bytes go to a sibling `*.tmp`
/// file that [`StoreWriter::finish`] fsyncs and renames into place — an
/// ingest that errors out (or a crash mid-write) never leaves a
/// plausible-looking store at the destination path, only a `.tmp`.
pub struct StoreWriter {
    w: BufWriter<File>,
    path: std::path::PathBuf,
    tmp: std::path::PathBuf,
    dims: Vec<u32>,
    page_entries: usize,
    coords: Vec<u32>,
    values: Vec<f32>,
    scratch: Vec<u8>,
    nnz: u64,
    value_sum: f64,
    pages: u64,
    peak_buffered: usize,
}

impl StoreWriter {
    /// Create `path` and write a placeholder header.  `dims` must have
    /// 2..=16 modes; `page_entries` must be in `1..=MAX_PAGE_ENTRIES`.
    pub fn create(path: &Path, dims: &[u32], page_entries: usize) -> Result<StoreWriter> {
        ensure!(
            (2..=16).contains(&dims.len()),
            "FTB2 stores hold tensors of order 2..=16, got {}",
            dims.len()
        );
        ensure!(
            (1..=MAX_PAGE_ENTRIES).contains(&page_entries),
            "page size {page_entries} out of range 1..={MAX_PAGE_ENTRIES}"
        );
        let name = path
            .file_name()
            .with_context(|| format!("store path {path:?} has no file name"))?;
        let tmp = path.with_file_name(format!("{}.tmp", name.to_string_lossy()));
        let file = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        let mut w = BufWriter::new(file);
        let placeholder = StoreMeta {
            dims: dims.to_vec(),
            page_entries,
            nnz: 0,
            value_sum: 0.0,
        };
        w.write_all(&placeholder.header_bytes())
            .with_context(|| format!("write {tmp:?}"))?;
        let n = dims.len();
        Ok(StoreWriter {
            w,
            path: path.to_path_buf(),
            tmp,
            dims: dims.to_vec(),
            page_entries,
            coords: Vec::with_capacity(page_entries * n),
            values: Vec::with_capacity(page_entries),
            scratch: Vec::with_capacity(page_entries * (n + 1) * 4),
            nnz: 0,
            value_sum: 0.0,
            pages: 0,
            peak_buffered: 0,
        })
    }

    /// Append one entry.  Coordinates are bounds-checked against the dims
    /// and the value must be finite, so every store on disk satisfies the
    /// [`SparseTensor::validate`] invariants by construction.
    pub fn push(&mut self, coords: &[u32], value: f32) -> Result<()> {
        ensure!(
            coords.len() == self.dims.len(),
            "entry {}: expected {} coordinates, got {}",
            self.nnz,
            self.dims.len(),
            coords.len()
        );
        for (m, (&c, &d)) in coords.iter().zip(&self.dims).enumerate() {
            ensure!(
                c < d,
                "entry {}: mode-{m} index {c} out of bounds (dim {d})",
                self.nnz
            );
        }
        ensure!(
            value.is_finite(),
            "entry {}: non-finite value {value}",
            self.nnz
        );
        self.coords.extend_from_slice(coords);
        self.values.push(value);
        self.nnz += 1;
        self.value_sum += value as f64;
        self.peak_buffered = self.peak_buffered.max(self.values.len());
        if self.values.len() == self.page_entries {
            self.flush_page()?;
        }
        Ok(())
    }

    fn flush_page(&mut self) -> Result<()> {
        if self.values.is_empty() {
            return Ok(());
        }
        self.scratch.clear();
        for &c in &self.coords {
            self.scratch.extend_from_slice(&c.to_le_bytes());
        }
        for &v in &self.values {
            self.scratch.extend_from_slice(&v.to_le_bytes());
        }
        let sum = fnv1a(&self.scratch);
        self.w.write_all(&self.scratch)?;
        self.w.write_all(&sum.to_le_bytes())?;
        self.pages += 1;
        self.coords.clear();
        self.values.clear();
        Ok(())
    }

    /// Largest number of entries ever buffered in RAM (tests assert this
    /// never exceeds the page size — the constant-memory contract).
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// Entries pushed so far.
    pub fn nnz(&self) -> u64 {
        self.nnz
    }

    /// Sections flushed so far (a partial tail section flushes in
    /// [`StoreWriter::finish`]).
    pub fn pages(&self) -> u64 {
        self.pages
    }

    /// Flush the tail section, patch the header with the final counts and
    /// checksum, fsync, and rename the `.tmp` file into place.  Returns
    /// the finished store's metadata.
    pub fn finish(mut self) -> Result<StoreMeta> {
        self.flush_page()?;
        self.w.flush()?;
        let mut f = self
            .w
            .into_inner()
            .map_err(|e| anyhow!("finalize store: {}", e.error()))?;
        let meta = StoreMeta {
            dims: self.dims,
            page_entries: self.page_entries,
            nnz: self.nnz,
            value_sum: self.value_sum,
        };
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&meta.header_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&self.tmp, &self.path)
            .with_context(|| format!("rename {:?} -> {:?}", self.tmp, self.path))?;
        Ok(meta)
    }
}

/// Write an in-RAM tensor as an FTB2 store (entry order preserved).
pub fn write_store(t: &SparseTensor, path: &Path, page_entries: usize) -> Result<StoreMeta> {
    let mut w = StoreWriter::create(path, &t.dims, page_entries)?;
    for e in 0..t.nnz() {
        w.push(t.coords(e), t.values[e])?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::io::toy_dataset;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ft_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_exact_across_page_sizes() {
        let t = toy_dataset();
        for page in [1usize, 3, 16, 64, 4096] {
            let p = tmp(&format!("toy_{page}.ftb2"));
            let meta = write_store(&t, &p, page).unwrap();
            assert_eq!(meta.nnz, t.nnz() as u64);
            assert_eq!(meta.num_pages(), (t.nnz() as u64).div_ceil(page as u64));
            assert_eq!(meta.file_len().unwrap(), std::fs::metadata(&p).unwrap().len());
            verify_store(&p).unwrap();
            let u = read_store(&p).unwrap();
            assert_eq!(u.dims, t.dims);
            assert_eq!(u.indices, t.indices);
            assert_eq!(u.values, t.values);
        }
    }

    #[test]
    fn mean_matches_in_ram_bitwise() {
        let t = toy_dataset();
        let p = tmp("mean.ftb2");
        let meta = write_store(&t, &p, 7).unwrap();
        assert_eq!(meta.mean_value().to_bits(), t.mean_value().to_bits());
    }

    #[test]
    fn writer_rejects_invalid_entries() {
        let p = tmp("invalid.ftb2");
        let mut w = StoreWriter::create(&p, &[4, 4], 8).unwrap();
        assert!(w.push(&[0, 4], 1.0).is_err()); // out of bounds
        assert!(w.push(&[0], 1.0).is_err()); // arity
        assert!(w.push(&[0, 0], f32::NAN).is_err()); // non-finite
        w.push(&[0, 0], 1.0).unwrap();
        w.finish().unwrap();
    }

    #[test]
    fn truncation_and_garbage_detected() {
        let t = toy_dataset();
        let p = tmp("trunc.ftb2");
        write_store(&t, &p, 16).unwrap();
        let good = std::fs::read(&p).unwrap();
        let bad = tmp("trunc_bad.ftb2");
        std::fs::write(&bad, &good[..good.len() - 3]).unwrap();
        assert!(open_store(&bad).is_err());
        let mut trailing = good.clone();
        trailing.extend_from_slice(b"junk");
        std::fs::write(&bad, &trailing).unwrap();
        assert!(open_store(&bad).is_err());
        std::fs::write(&bad, b"FTB2").unwrap();
        assert!(open_store(&bad).is_err());
    }

    #[test]
    fn empty_store_roundtrips() {
        let p = tmp("empty.ftb2");
        let w = StoreWriter::create(&p, &[3, 3, 3], 8).unwrap();
        let meta = w.finish().unwrap();
        assert_eq!(meta.nnz, 0);
        assert_eq!(meta.num_pages(), 0);
        let u = read_store(&p).unwrap();
        assert_eq!(u.nnz(), 0);
        assert_eq!(u.dims, vec![3, 3, 3]);
    }
}
