//! [`PagedTensor`]: an out-of-core [`TensorView`] over an FTB2 store.
//!
//! The training loop's access pattern is random *within an epoch* (the
//! sampler shuffles entry ids) but strongly block-local: one staged block
//! gathers `S` consecutive slots of the shuffled id list, and with the
//! store's default page size equal to the CPU block size the working set
//! at any instant is a handful of sections.  So the reader keeps a small
//! LRU of decoded-on-demand page buffers (recycled through
//! [`BufferPool`]) and serves every gather with positioned reads
//! (`read_at`-style, no seek state), which also makes it safe to share
//! across the staging producer thread.
//!
//! Memory is bounded by `cache_pages * page_bytes` regardless of tensor
//! size — the whole point of the store.  [`PagedTensor::open`] verifies
//! every section checksum up front (one sequential constant-memory pass),
//! so the infallible [`TensorView::load_entry`] hot path only re-checks
//! the checksum of each page it faults in; a mismatch there means the
//! file changed underneath a live run and panics with a clear message.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Result;

use crate::data::store::{self, StoreMeta};
use crate::data::view::TensorView;
use crate::util::fnv::fnv1a;
use crate::util::json::{self, Json};
use crate::util::pool::BufferPool;

/// Default number of cached pages (× the default page size ≈ a few MB).
pub const DEFAULT_CACHE_PAGES: usize = 8;

/// Page-cache traffic counters: cumulative when read through
/// [`PagedTensor::cache_stats_full`], or per-epoch deltas when carried
/// on a [`crate::session::EpochEvent`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Accesses served from a cached page.
    pub hits: u64,
    /// Accesses that faulted a page in from disk.
    pub loads: u64,
    /// Bytes read from disk faulting pages in (payload + checksums).
    pub bytes_read: u64,
}

impl CacheStats {
    /// Hit fraction over all accesses; `None` before any traffic.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.loads;
        (total > 0).then(|| self.hits as f64 / total as f64)
    }

    /// The traffic between an `earlier` reading and this one
    /// (saturating, so a swapped argument order cannot panic).
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            loads: self.loads.saturating_sub(earlier.loads),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
        }
    }

    /// Serialize for epoch stats JSON and `metrics.jsonl`.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("hits", json::num(self.hits as f64)),
            ("loads", json::num(self.loads as f64)),
            ("bytes_read", json::num(self.bytes_read as f64)),
        ];
        if let Some(rate) = self.hit_rate() {
            fields.push(("hit_rate", json::num(rate)));
        }
        json::obj(fields)
    }
}

/// Out-of-core sparse tensor backed by a verified FTB2 store.
pub struct PagedTensor {
    file: File,
    path: PathBuf,
    meta: StoreMeta,
    cache: Mutex<PageCache>,
}

struct PageCache {
    cap: usize,
    clock: u64,
    slots: Vec<Slot>,
    pool: BufferPool,
    hits: u64,
    loads: u64,
    bytes_read: u64,
}

struct Slot {
    page: u64,
    last_use: u64,
    /// Raw section bytes (payload + trailing checksum), decoded per access.
    bytes: Vec<u8>,
}

impl PagedTensor {
    /// Open `path`, verifying the header, the exact file length and every
    /// section checksum, with the default cache size.
    pub fn open(path: &Path) -> Result<PagedTensor> {
        PagedTensor::open_with_cache(path, DEFAULT_CACHE_PAGES)
    }

    /// Like [`PagedTensor::open`] with an explicit page-cache capacity
    /// (≥ 1).  Tests use tiny capacities to force eviction traffic.
    pub fn open_with_cache(path: &Path, cache_pages: usize) -> Result<PagedTensor> {
        let (file, meta) = store::verify_store(path)?;
        Ok(PagedTensor {
            file,
            path: path.to_path_buf(),
            meta,
            cache: Mutex::new(PageCache {
                cap: cache_pages.max(1),
                clock: 0,
                slots: Vec::new(),
                pool: BufferPool::new(),
                hits: 0,
                loads: 0,
                bytes_read: 0,
            }),
        })
    }

    /// The store's parsed header.
    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// The path this tensor pages from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Page-cache counters since open: `(hits, loads)`.  A sequential
    /// scan shows ~one load per page; the shuffled training stream shows
    /// the locality the block/page alignment buys.
    pub fn cache_stats(&self) -> (u64, u64) {
        let c = self.cache.lock().unwrap();
        (c.hits, c.loads)
    }

    /// Full cumulative cache counters since open, including the bytes
    /// read from disk — what the session reports per epoch (as deltas)
    /// when training from a store.
    pub fn cache_stats_full(&self) -> CacheStats {
        let c = self.cache.lock().unwrap();
        CacheStats {
            hits: c.hits,
            loads: c.loads,
            bytes_read: c.bytes_read,
        }
    }
}

impl TensorView for PagedTensor {
    fn dims(&self) -> &[u32] {
        &self.meta.dims
    }

    fn nnz(&self) -> usize {
        self.meta.nnz as usize
    }

    fn load_entry(&self, e: usize, out: &mut [u32]) -> f32 {
        assert!(
            e < self.meta.nnz as usize,
            "entry {e} out of range (nnz {})",
            self.meta.nnz
        );
        let n = self.meta.order();
        debug_assert_eq!(out.len(), n);
        let page = e as u64 / self.meta.page_entries as u64;
        let slot = e % self.meta.page_entries;
        let mut cache = self.cache.lock().unwrap();
        let bytes = cache.fetch(page, &self.file, &self.path, &self.meta);
        let base = slot * n * 4;
        for (m, c) in out.iter_mut().enumerate() {
            let at = base + m * 4;
            *c = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
        }
        let vat = self.meta.page_len(page) * n * 4 + slot * 4;
        f32::from_le_bytes(bytes[vat..vat + 4].try_into().unwrap())
    }

    fn mean_value(&self) -> f32 {
        self.meta.mean_value()
    }
}

impl std::fmt::Debug for PagedTensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedTensor")
            .field("path", &self.path)
            .field("dims", &self.meta.dims)
            .field("nnz", &self.meta.nnz)
            .field("page_entries", &self.meta.page_entries)
            .finish()
    }
}

impl PageCache {
    /// Return the cached bytes of `page`, faulting it in (and evicting
    /// the least-recently-used slot) on a miss.
    fn fetch(&mut self, page: u64, file: &File, path: &Path, meta: &StoreMeta) -> &[u8] {
        self.clock += 1;
        if let Some(i) = self.slots.iter().position(|s| s.page == page) {
            self.slots[i].last_use = self.clock;
            self.hits += 1;
            return &self.slots[i].bytes;
        }
        self.loads += 1;
        let len = meta.page_payload_bytes(page);
        self.bytes_read += len as u64 + 8;
        let mut bytes = self.pool.take(len + 8);
        read_exact_at(file, &mut bytes, meta.page_offset(page)).unwrap_or_else(|e| {
            panic!("{path:?}: reading FTB2 section {page} failed mid-run: {e}")
        });
        let stored = u64::from_le_bytes(bytes[len..].try_into().unwrap());
        assert_eq!(
            fnv1a(&bytes[..len]),
            stored,
            "{path:?}: FTB2 section {page} checksum mismatch \
             (store modified while mapped?)"
        );
        let slot = Slot {
            page,
            last_use: self.clock,
            bytes,
        };
        if self.slots.len() >= self.cap {
            let (i, _) = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.last_use)
                .expect("cache capacity is >= 1");
            let old = std::mem::replace(&mut self.slots[i], slot);
            self.pool.put(old.bytes);
            &self.slots[i].bytes
        } else {
            self.slots.push(slot);
            &self.slots.last().expect("just pushed").bytes
        }
    }
}

/// Positioned read that leaves no shared seek state (safe under the
/// staging producer thread and any concurrent readers).
#[cfg(unix)]
fn read_exact_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, offset)
}

/// Positioned read via `seek_read` (Windows moves the cursor, which is
/// fine: every access goes through this helper with absolute offsets).
#[cfg(windows)]
fn read_exact_at(file: &File, mut buf: &mut [u8], mut offset: u64) -> std::io::Result<()> {
    use std::os::windows::fs::FileExt;
    while !buf.is_empty() {
        match file.seek_read(buf, offset) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "unexpected end of store",
                ))
            }
            Ok(k) => {
                buf = &mut buf[k..];
                offset += k as u64;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::write_store;
    use crate::tensor::io::toy_dataset;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ft_paged_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn paged_matches_ram_under_eviction_pressure() {
        let t = toy_dataset();
        let p = tmp("toy.ftb2");
        write_store(&t, &p, 5).unwrap();
        // capacity 2 over ceil(64/5) = 13 pages: plenty of eviction
        let paged = PagedTensor::open_with_cache(&p, 2).unwrap();
        assert_eq!(paged.dims(), &t.dims[..]);
        assert_eq!(TensorView::nnz(&paged), t.nnz());
        let n = t.order();
        let mut c = vec![0u32; n];
        // a deliberately cache-hostile access order
        for round in 0..3 {
            for e in (0..t.nnz()).rev().chain(0..t.nnz()) {
                let v = paged.load_entry(e, &mut c);
                assert_eq!(&c[..], t.coords(e), "round {round} entry {e}");
                assert_eq!(v.to_bits(), t.values[e].to_bits());
            }
        }
        let (hits, loads) = paged.cache_stats();
        assert!(loads > 13, "eviction never happened (loads {loads})");
        assert!(hits > 0);
        assert_eq!(paged.mean_value().to_bits(), t.mean_value().to_bits());
        assert!(TensorView::as_sparse(&paged).is_none());
    }

    #[test]
    fn sequential_scan_loads_each_page_once() {
        let t = toy_dataset();
        let p = tmp("seq.ftb2");
        let meta = write_store(&t, &p, 16).unwrap();
        let paged = PagedTensor::open(&p).unwrap();
        let mut c = vec![0u32; t.order()];
        for e in 0..t.nnz() {
            paged.load_entry(e, &mut c);
        }
        let (_, loads) = paged.cache_stats();
        assert_eq!(loads, meta.num_pages());
    }

    #[test]
    fn full_stats_track_bytes_and_deltas() {
        let t = toy_dataset();
        let p = tmp("full.ftb2");
        write_store(&t, &p, 16).unwrap();
        let paged = PagedTensor::open(&p).unwrap();
        let mut c = vec![0u32; t.order()];
        paged.load_entry(0, &mut c);
        let first = paged.cache_stats_full();
        assert_eq!(first.loads, 1);
        // page payload (coords + values) plus the 8-byte checksum
        let n = t.order() as u64;
        assert_eq!(first.bytes_read, 16 * (n * 4 + 4) + 8);
        // full scan: legacy and full counters agree
        for e in 0..t.nnz() {
            paged.load_entry(e, &mut c);
        }
        let full = paged.cache_stats_full();
        let (hits, loads) = paged.cache_stats();
        assert_eq!((full.hits, full.loads), (hits, loads));
        assert!(full.bytes_read > first.bytes_read);
        let delta = full.delta_since(&first);
        assert_eq!(delta.loads, full.loads - 1);
        assert!(delta.hit_rate().unwrap() > 0.0);
        // swapped order saturates instead of panicking
        assert_eq!(first.delta_since(&full), CacheStats::default());
    }
}
