//! [`TensorView`]: the read abstraction the sampling/staging pipeline
//! consumes, implemented by both the in-RAM [`SparseTensor`] and the paged
//! out-of-core [`crate::data::PagedTensor`].
//!
//! The training hot path only ever needs three things from the data: the
//! shape, the entry count, and random-access gathers of `(coords, value)`
//! by entry id (the ids come from the sampler's shuffled schedule).  This
//! trait captures exactly that surface, so
//! [`crate::sampler::stream::stage`], the phase driver and the
//! [`crate::coordinator::Trainer`] are generic over where the entries
//! live — RAM or a checksummed on-disk store paged in on demand.

use crate::tensor::SparseTensor;

/// Read-only view of a sparse COO tensor, addressable by entry id.
///
/// `Sync` is a supertrait because the staging producer
/// ([`crate::sampler::StagedStream`]) gathers entries from a scoped
/// thread while the consumer executes the previous block.
pub trait TensorView: Sync {
    /// Dimension sizes `I_n`, length N.
    fn dims(&self) -> &[u32];

    /// Number of stored (observed) entries.
    fn nnz(&self) -> usize;

    /// Copy entry `e`'s coordinates into `out` (length N) and return its
    /// value.  `e` must be `< nnz()`; `out` must have length `order()`.
    fn load_entry(&self, e: usize, out: &mut [u32]) -> f32;

    /// Mean of the stored values.  Implementations must accumulate in
    /// `f64` over entries in id order, so the in-RAM and out-of-core
    /// views of the same data agree bit-for-bit (the model init consumes
    /// this, and trajectory parity depends on it).
    fn mean_value(&self) -> f32;

    /// Tensor order N.
    fn order(&self) -> usize {
        self.dims().len()
    }

    /// The in-RAM tensor behind this view, when there is one.  The
    /// per-mode sampling indexes (mode-slice and fiber grouping) hold
    /// O(nnz) entry lists and are only built from RAM; callers that need
    /// them use this to reject out-of-core sources with a clear error.
    fn as_sparse(&self) -> Option<&SparseTensor> {
        None
    }
}

impl TensorView for SparseTensor {
    fn dims(&self) -> &[u32] {
        &self.dims
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn load_entry(&self, e: usize, out: &mut [u32]) -> f32 {
        out.copy_from_slice(self.coords(e));
        self.values[e]
    }

    fn mean_value(&self) -> f32 {
        SparseTensor::mean_value(self)
    }

    fn as_sparse(&self) -> Option<&SparseTensor> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_tensor_view_matches_inherent_accessors() {
        let mut t = SparseTensor::new(vec![4, 5]);
        t.push(&[1, 2], 1.5);
        t.push(&[3, 4], -2.5);
        let v: &dyn TensorView = &t;
        assert_eq!(v.dims(), &[4, 5]);
        assert_eq!(v.order(), 2);
        assert_eq!(v.nnz(), 2);
        let mut c = [0u32; 2];
        assert_eq!(v.load_entry(1, &mut c), -2.5);
        assert_eq!(c, [3, 4]);
        assert_eq!(v.mean_value(), t.mean_value());
        assert!(v.as_sparse().is_some());
    }
}
