//! [`ShardView`]: a [`TensorView`] restricted to a member's assigned
//! sections, for sharded data-parallel training.
//!
//! The distributed layer deals section ids to members
//! ([`crate::dist::shard::assign`]); this adapter turns "sections
//! `{3, 4, 9}` of that tensor" back into an ordinary dense-id
//! `TensorView` (local ids `0..shard_nnz`), so the existing sampler /
//! staging / [`crate::coordinator::Trainer`] stack runs over a shard
//! completely unchanged.  Sections map to entry-id ranges: section `s`
//! covers global entries `[s * section_entries, (s + 1) * section_entries)`
//! clamped to `nnz` — for a [`crate::data::PagedTensor`] that is exactly
//! one FTB2 section (so a worker's page working set is its own shard);
//! for an in-RAM tensor the driver picks a synthetic `section_entries`.
//!
//! Adjacent assigned sections merge into one contiguous segment, and
//! local → global translation is a binary search over the segment prefix
//! sums — O(log segments), with segments ≤ sections ≪ nnz.

use crate::data::view::TensorView;
use crate::tensor::SparseTensor;

/// A contiguous-by-segments window onto a base [`TensorView`].
///
/// When the full id range is assigned (e.g. a single worker holding every
/// section), the view is the identity: local id == global id, and
/// `mean_value` sees the same entries in the same order as the base —
/// the property behind the byte-for-byte 1-worker parity test.
pub struct ShardView<'a> {
    base: &'a dyn TensorView,
    /// Half-open global entry ranges, ascending and non-overlapping.
    segments: Vec<(usize, usize)>,
    /// `prefix[i]` = number of local entries before `segments[i]`;
    /// one extra trailing element equal to `nnz`.
    prefix: Vec<usize>,
    nnz: usize,
}

impl<'a> ShardView<'a> {
    /// View `sections` (each spanning `section_entries` global entry ids,
    /// the last clamped to `base.nnz()`) of `base`.  Duplicate section
    /// ids are collapsed; out-of-range sections contribute no entries.
    ///
    /// # Panics
    /// If `section_entries == 0`.
    pub fn new(base: &'a dyn TensorView, sections: &[u32], section_entries: usize) -> ShardView<'a> {
        assert!(section_entries > 0, "section_entries must be positive");
        let total = base.nnz();
        let mut ids: Vec<u32> = sections.to_vec();
        ids.sort_unstable();
        ids.dedup();
        let mut segments: Vec<(usize, usize)> = Vec::new();
        for s in ids {
            let lo = (s as usize).saturating_mul(section_entries).min(total);
            let hi = lo.saturating_add(section_entries).min(total);
            if lo == hi {
                continue;
            }
            match segments.last_mut() {
                // adjacent sections fuse, so a single-worker shard is one
                // segment [0, nnz) and lookups cost nothing
                Some(last) if last.1 == lo => last.1 = hi,
                _ => segments.push((lo, hi)),
            }
        }
        let mut prefix = Vec::with_capacity(segments.len() + 1);
        let mut acc = 0usize;
        for &(lo, hi) in &segments {
            prefix.push(acc);
            acc += hi - lo;
        }
        prefix.push(acc);
        ShardView {
            base,
            segments,
            prefix,
            nnz: acc,
        }
    }

    /// Global entry id for local id `e` (`e < nnz()`).
    pub fn global_id(&self, e: usize) -> usize {
        debug_assert!(e < self.nnz);
        // index of the segment containing local id e
        let seg = self.prefix.partition_point(|&p| p <= e) - 1;
        self.segments[seg].0 + (e - self.prefix[seg])
    }

    /// Number of merged contiguous segments (diagnostics / tests).
    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }
}

impl TensorView for ShardView<'_> {
    fn dims(&self) -> &[u32] {
        self.base.dims()
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn load_entry(&self, e: usize, out: &mut [u32]) -> f32 {
        self.base.load_entry(self.global_id(e), out)
    }

    fn mean_value(&self) -> f32 {
        // f64 accumulation in local-id order, per the trait contract; for
        // the identity shard this walks the same ids as the base view
        let mut sum = 0.0f64;
        let mut coords = vec![0u32; self.base.order()];
        for e in 0..self.nnz {
            sum += f64::from(self.base.load_entry(self.global_id(e), &mut coords));
        }
        if self.nnz == 0 {
            0.0
        } else {
            (sum / self.nnz as f64) as f32
        }
    }

    fn as_sparse(&self) -> Option<&SparseTensor> {
        // shards never expose the base tensor: the per-mode indexes built
        // from it would cover entries outside this shard
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(n: usize) -> SparseTensor {
        let mut t = SparseTensor::new(vec![64, 64]);
        for e in 0..n {
            t.push(&[e as u32 % 64, (e as u32 * 7) % 64], e as f32 + 0.5);
        }
        t
    }

    #[test]
    fn identity_shard_matches_base() {
        let t = tensor(100);
        let v = ShardView::new(&t, &[0, 1, 2, 3], 25);
        assert_eq!(v.nnz(), 100);
        assert_eq!(v.segment_count(), 1, "adjacent sections must fuse");
        assert_eq!(v.mean_value(), TensorView::mean_value(&t));
        let mut a = [0u32; 2];
        let mut b = [0u32; 2];
        for e in [0usize, 1, 50, 99] {
            assert_eq!(v.load_entry(e, &mut a), t.load_entry(e, &mut b));
            assert_eq!(a, b);
        }
    }

    #[test]
    fn sparse_sections_map_to_global_ids() {
        let t = tensor(100);
        // sections of 10 entries; take 2, 5, 9 (out-of-order + duplicate)
        let v = ShardView::new(&t, &[9, 2, 5, 2], 10);
        assert_eq!(v.nnz(), 30);
        assert_eq!(v.segment_count(), 3);
        assert_eq!(v.global_id(0), 20);
        assert_eq!(v.global_id(9), 29);
        assert_eq!(v.global_id(10), 50);
        assert_eq!(v.global_id(29), 99);
        let mut c = [0u32; 2];
        assert_eq!(v.load_entry(10, &mut c), 50.5);
    }

    #[test]
    fn tail_section_clamps_to_nnz() {
        let t = tensor(25);
        // 3 sections of 10: the last holds entries 20..25 only
        let v = ShardView::new(&t, &[2], 10);
        assert_eq!(v.nnz(), 5);
        assert_eq!(v.global_id(4), 24);
        // a section wholly past the end contributes nothing
        let v = ShardView::new(&t, &[7], 10);
        assert_eq!(v.nnz(), 0);
        assert_eq!(v.mean_value(), 0.0);
    }

    #[test]
    fn shards_never_expose_the_base_indexes() {
        let t = tensor(10);
        let v = ShardView::new(&t, &[0], 10);
        assert!(v.as_sparse().is_none());
    }
}
