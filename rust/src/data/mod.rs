//! The out-of-core data layer: how tensors too large for RAM reach the
//! trainer.
//!
//! Four pieces (ARCHITECTURE.md §The data layer has the diagram):
//!
//! * [`store`] — the `FTB2` on-disk format: a checksummed header plus
//!   fixed-size sections of entry-major coordinates + values, sized so
//!   one section lines up with the sampler's block size.  Includes the
//!   constant-memory [`store::StoreWriter`] and whole-file verify /
//!   materialize helpers.
//! * [`ingest`] — streaming converters (text COO and `FTB1` → `FTB2`)
//!   whose resident set is one section, by construction.
//! * [`view`] / [`paged`] — the [`TensorView`] trait the staging pipeline
//!   gathers through, with the in-RAM [`crate::tensor::SparseTensor`]
//!   and the LRU-paged [`PagedTensor`] as its two implementations.
//! * [`shard`] — [`ShardView`], the section-range window the distributed
//!   layer ([`crate::dist`]) trains each worker through.
//!
//! End to end: `fasttucker ingest --input big.coo --out big.ftb2` then
//! `fasttucker train --store big.ftb2` trains FastTuckerPlus without ever
//! holding the tensor in RAM, on a block stream bit-identical to the
//! in-RAM run's (pinned by `tests/data_pipeline.rs`).

pub mod ingest;
pub mod paged;
pub mod shard;
pub mod store;
pub mod view;

pub use ingest::{ingest as ingest_file, IngestStats};
pub use paged::{CacheStats, PagedTensor};
pub use shard::ShardView;
pub use store::{StoreMeta, StoreWriter};
pub use view::TensorView;
