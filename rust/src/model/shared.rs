//! Sharded factor-matrix access for Hogwild-style parallel SGD.
//!
//! The paper's factor phase scatters updated rows from many warps into the
//! factor matrices without synchronization — colliding writes are a benign
//! race (§ Hogwild).  Rust forbids plain data races, so [`SharedFactors`]
//! reinterprets each factor matrix as a slice of `AtomicU32` and performs
//! per-element *relaxed* loads/stores of the f32 bit patterns: the same
//! lock-free semantics (no ordering, last-writer-wins per element) with
//! defined behavior.
//!
//! A single-threaded worker going through this view performs exactly the
//! same arithmetic as direct `&mut` access, which is why the serial
//! `CpuRef` backend and the `ParallelCpu` backend share one scalar step
//! implementation (`cpu_ref::step`) and produce bit-identical trajectories
//! at `workers = 1`.

use std::sync::atomic::{AtomicU32, Ordering};

use super::TuckerModel;

/// Atomic view over a model's factor matrices, shareable across worker
/// threads for the duration of a block execution.
pub struct SharedFactors<'a> {
    modes: Vec<&'a [AtomicU32]>,
    j: usize,
}

/// Reinterpret an exclusively borrowed f32 slice as atomics.
///
/// Sound because `AtomicU32` has the same size, alignment and bit validity
/// as `u32`/`f32`, and the `&mut` borrow guarantees no other non-atomic
/// access for the view's lifetime.
fn as_atomic(v: &mut [f32]) -> &[AtomicU32] {
    unsafe { std::slice::from_raw_parts(v.as_mut_ptr() as *const AtomicU32, v.len()) }
}

impl<'a> SharedFactors<'a> {
    /// Build the view from the factor matrices (one `I_n x J` slab per
    /// mode).  Callers typically split-borrow `&mut model.factors` so the
    /// cores stay readable alongside.
    pub fn new(factors: &'a mut [Vec<f32>], j: usize) -> SharedFactors<'a> {
        SharedFactors {
            modes: factors.iter_mut().map(|f| as_atomic(f)).collect(),
            j,
        }
    }

    /// Row width J of the viewed factor matrices.
    #[inline]
    pub fn j(&self) -> usize {
        self.j
    }

    /// Load row `i` of mode `mode` into `out` (length J).
    #[inline]
    pub fn load_row(&self, mode: usize, i: usize, out: &mut [f32]) {
        let row = &self.modes[mode][i * self.j..(i + 1) * self.j];
        for (o, a) in out.iter_mut().zip(row) {
            *o = f32::from_bits(a.load(Ordering::Relaxed));
        }
    }

    /// Store `row` (length J) into row `i` of mode `mode` — the lock-free
    /// scatter: element-wise relaxed stores, last writer wins.
    #[inline]
    pub fn store_row(&self, mode: usize, i: usize, row: &[f32]) {
        let dst = &self.modes[mode][i * self.j..(i + 1) * self.j];
        for (a, &v) in dst.iter().zip(row) {
            a.store(v.to_bits(), Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_rows() {
        let mut model = TuckerModel::init(&[8, 8], 16, 16, 3);
        let before = model.factors[0][16..32].to_vec();
        {
            let shared = SharedFactors::new(&mut model.factors, 16);
            let mut row = vec![0f32; 16];
            shared.load_row(0, 1, &mut row);
            assert_eq!(row, before);
            for v in row.iter_mut() {
                *v += 1.0;
            }
            shared.store_row(0, 1, &row);
        }
        for (a, b) in model.factors[0][16..32].iter().zip(&before) {
            assert!((a - (b + 1.0)).abs() < 1e-6);
        }
    }

    #[test]
    fn concurrent_disjoint_rows_are_exact() {
        let mut model = TuckerModel::init(&[64, 8], 16, 16, 5);
        let expect: Vec<Vec<f32>> = (0..64)
            .map(|i| {
                model.factors[0][i * 16..(i + 1) * 16]
                    .iter()
                    .map(|v| v * 2.0)
                    .collect()
            })
            .collect();
        {
            let shared = &SharedFactors::new(&mut model.factors, 16);
            crate::util::pool::parallel_items(64, 4, |i| {
                let mut row = vec![0f32; 16];
                shared.load_row(0, i, &mut row);
                for v in row.iter_mut() {
                    *v *= 2.0;
                }
                shared.store_row(0, i, &row);
            });
        }
        for (i, want) in expect.iter().enumerate() {
            assert_eq!(&model.factors[0][i * 16..(i + 1) * 16], &want[..]);
        }
    }
}
