//! Model state: factor matrices `A^(n) ∈ R^{I_n x J}`, core matrices
//! `B^(n) ∈ R^{J x R}`, the gather/scatter hot path that feeds the PJRT
//! executables, and checkpointing.
//!
//! Storage is row-major `Vec<f32>` per mode.  J and R are uniform across
//! modes (the paper sets J_n = 16 for all n) and multiples of 16 to keep
//! every matmul WMMA/MXU-tileable.

pub mod shared;

pub use shared::SharedFactors;

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Pcg32;

/// The decomposition parameters for one tensor.
#[derive(Clone, Debug)]
pub struct TuckerModel {
    /// Dimension sizes `I_n` of the decomposed tensor.
    pub dims: Vec<u32>,
    /// Factor rank J (uniform across modes).
    pub j: usize,
    /// Kruskal rank R of the core.
    pub r: usize,
    /// `factors[n]` is `I_n x J` row-major.
    pub factors: Vec<Vec<f32>>,
    /// `cores[n]` is `J x R` row-major.
    pub cores: Vec<Vec<f32>>,
}

impl TuckerModel {
    /// Random init ~ N(0, 1/sqrt(J)) offset slightly positive, matching the
    /// common rating-data init (keeps early predictions near the mean).
    pub fn init(dims: &[u32], j: usize, r: usize, seed: u64) -> Self {
        assert!(j % 16 == 0 && r % 16 == 0, "J and R must be multiples of 16");
        let mut rng = Pcg32::new(seed, 0x0DE1);
        let scale_a = 1.0 / (j as f32).sqrt();
        let scale_b = 1.0 / (r as f32).sqrt();
        let factors = dims
            .iter()
            .map(|&d| {
                (0..d as usize * j)
                    .map(|_| rng.gen_normal() * scale_a + 0.5 * scale_a)
                    .collect()
            })
            .collect();
        let cores = dims
            .iter()
            .map(|_| {
                (0..j * r)
                    .map(|_| rng.gen_normal() * scale_b + 0.5 * scale_b)
                    .collect()
            })
            .collect();
        Self {
            dims: dims.to_vec(),
            j,
            r,
            factors,
            cores,
        }
    }

    /// Init calibrated so the initial prediction magnitude matches
    /// `mean_value`: solves `R * (J μ_a μ_b)^N ≈ mean` for the entry means.
    /// Essential for high orders — with the naive init the per-mode dots are
    /// ~0.25, so an order-8 prediction is 0.25^8 ≈ 1e-5 and every gradient
    /// vanishes (the HHLST regime the paper targets needs this).
    pub fn init_with_mean(dims: &[u32], j: usize, r: usize, seed: u64, mean_value: f32) -> Self {
        let mut model = Self::init(dims, j, r, seed);
        let n = dims.len() as f32;
        let target = (mean_value.abs().max(0.1) / r as f32).powf(1.0 / n);
        // per-entry mean so that J * mu_a * mu_b = target
        let mu = (target / j as f32).sqrt();
        let mut rng = Pcg32::new(seed, 0xCA1B);
        for f in model.factors.iter_mut().chain(model.cores.iter_mut()) {
            for w in f.iter_mut() {
                *w = mu * (1.0 + 0.3 * rng.gen_normal());
            }
        }
        model
    }

    /// Tensor order N.
    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Row `i` of mode `mode`'s factor matrix (length J).
    #[inline]
    pub fn factor_row(&self, mode: usize, i: usize) -> &[f32] {
        &self.factors[mode][i * self.j..(i + 1) * self.j]
    }

    /// Predict one entry on the CPU (scalar path; eval/serving fallback).
    pub fn predict_one(&self, coords: &[u32]) -> f32 {
        let n = self.order();
        let (j, r) = (self.j, self.r);
        let mut acc = vec![1.0f32; r];
        for m in 0..n {
            let row = self.factor_row(m, coords[m] as usize);
            let core = &self.cores[m];
            for rr in 0..r {
                let mut dot = 0.0f32;
                for jj in 0..j {
                    dot += row[jj] * core[jj * r + rr];
                }
                acc[rr] *= dot;
            }
        }
        acc.iter().sum()
    }

    /// Gather factor rows for a batch into `out` laid out `[N, S, J]`
    /// (mode-major), the layout the L1 kernels expect.  `coords` is the
    /// entry-major COO index slab for the batch (full `[S, N]`, zero-padded
    /// past `valid`).  Rows beyond `valid` are zeroed (inert padding — see
    /// `test_padding_rows_are_inert` in the python suite).
    pub fn gather_batch(&self, coords: &[u32], valid: usize, out: &mut [f32]) {
        let n = self.order();
        let j = self.j;
        let s = out.len() / (n * j);
        debug_assert_eq!(out.len(), n * s * j);
        debug_assert!(valid <= s);
        debug_assert!(coords.len() >= valid * n);
        for m in 0..n {
            let dst_mode = &mut out[m * s * j..(m + 1) * s * j];
            let fm = &self.factors[m];
            for e in 0..valid {
                let row = coords[e * n + m] as usize;
                dst_mode[e * j..(e + 1) * j].copy_from_slice(&fm[row * j..(row + 1) * j]);
            }
            dst_mode[valid * j..].fill(0.0);
        }
    }

    /// Scatter updated rows `[N, S, J]` back into the factor matrices.
    /// Duplicate rows within a batch: the last occurrence wins (Hogwild-style
    /// benign race, as in the paper's warp-parallel updates).
    pub fn scatter_batch(&mut self, coords: &[u32], valid: usize, updated: &[f32]) {
        let n = self.order();
        let j = self.j;
        let s = updated.len() / (n * j);
        for m in 0..n {
            let src_mode = &updated[m * s * j..(m + 1) * s * j];
            let fm = &mut self.factors[m];
            for e in 0..valid {
                let row = coords[e * n + m] as usize;
                fm[row * j..(row + 1) * j].copy_from_slice(&src_mode[e * j..(e + 1) * j]);
            }
        }
    }

    /// Gather with mode order rotated so tensor mode `mode` lands at output
    /// position 0 (the per-mode baseline kernels always update index 0):
    /// output position `k` holds rows of tensor mode `(mode + k) % N`.
    pub fn gather_batch_rotated(&self, coords: &[u32], valid: usize, mode: usize, out: &mut [f32]) {
        let n = self.order();
        let j = self.j;
        let s = out.len() / (n * j);
        for k in 0..n {
            let src_mode = (mode + k) % n;
            let dst = &mut out[k * s * j..(k + 1) * s * j];
            let fm = &self.factors[src_mode];
            for e in 0..valid {
                let row = coords[e * n + src_mode] as usize;
                dst[e * j..(e + 1) * j].copy_from_slice(&fm[row * j..(row + 1) * j]);
            }
            dst[valid * j..].fill(0.0);
        }
    }

    /// Gather only `mode`'s rows into `[S, J]`.
    pub fn gather_mode_rows(&self, mode: usize, coords: &[u32], valid: usize, out: &mut [f32]) {
        let n = self.order();
        let j = self.j;
        let fm = &self.factors[mode];
        for e in 0..valid {
            let row = coords[e * n + mode] as usize;
            out[e * j..(e + 1) * j].copy_from_slice(&fm[row * j..(row + 1) * j]);
        }
        out[valid * j..].fill(0.0);
    }

    /// Scatter `[S, J]` updated rows back into `mode`'s factor matrix.
    pub fn scatter_mode_rows(&mut self, mode: usize, coords: &[u32], valid: usize, rows: &[f32]) {
        let n = self.order();
        let j = self.j;
        let fm = &mut self.factors[mode];
        for e in 0..valid {
            let row = coords[e * n + mode] as usize;
            fm[row * j..(row + 1) * j].copy_from_slice(&rows[e * j..(e + 1) * j]);
        }
    }

    /// Pack cores into `[N, J, R]` (mode-major) for the kernels.
    pub fn pack_cores(&self, out: &mut [f32]) {
        let sz = self.j * self.r;
        debug_assert_eq!(out.len(), self.order() * sz);
        for (m, core) in self.cores.iter().enumerate() {
            out[m * sz..(m + 1) * sz].copy_from_slice(core);
        }
    }

    /// Pack cores with `mode` rotated to the front (baseline per-mode
    /// kernels always update index 0).
    pub fn pack_cores_rotated(&self, mode: usize, out: &mut [f32]) {
        let n = self.order();
        let sz = self.j * self.r;
        for k in 0..n {
            let src = (mode + k) % n;
            out[k * sz..(k + 1) * sz].copy_from_slice(&self.cores[src]);
        }
    }

    /// Apply an accumulated core gradient `[N, J, R]`:
    /// `B^(n) += lr * (grad^(n)/count - lam*B^(n))` — the paper's
    /// accumulate-then-apply (Alg. 5 atomicAdd analog).
    pub fn apply_core_grad(&mut self, grad: &[f32], count: usize, lr: f32, lam: f32) {
        let sz = self.j * self.r;
        let scale = lr / count.max(1) as f32;
        for (m, core) in self.cores.iter_mut().enumerate() {
            let g = &grad[m * sz..(m + 1) * sz];
            for (w, &gv) in core.iter_mut().zip(g) {
                *w += scale * gv - lr * lam * *w;
            }
        }
    }

    /// Same for a single rotated mode (baseline kernels): gradient is `[J,R]`
    /// for `mode`.
    pub fn apply_core_grad_mode(&mut self, mode: usize, grad: &[f32], count: usize, lr: f32, lam: f32) {
        let scale = lr / count.max(1) as f32;
        let core = &mut self.cores[mode];
        for (w, &gv) in core.iter_mut().zip(grad) {
            *w += scale * gv - lr * lam * *w;
        }
    }

    /// Frobenius norm of all parameters (divergence tripwire).
    pub fn param_norm(&self) -> f64 {
        let mut acc = 0f64;
        for f in &self.factors {
            acc += f.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        }
        for c in &self.cores {
            acc += c.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>();
        }
        acc.sqrt()
    }

    // --- checkpointing ----------------------------------------------------

    const MAGIC: &'static [u8; 4] = b"FTM1";

    /// Write a binary checkpoint (`FTM1` format).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        self.write_ftm1(&mut w)
    }

    /// Load a binary checkpoint written by [`TuckerModel::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
        Self::read_ftm1(&mut r)
    }

    /// Encode the model as `FTM1` bytes — the exact byte sequence
    /// [`TuckerModel::save`] writes to disk, so checkpoints and wire
    /// payloads are `cmp`-comparable.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.byte_len());
        self.write_ftm1(&mut buf)
            .expect("writing to a Vec cannot fail");
        buf
    }

    /// Decode an `FTM1` byte buffer produced by [`TuckerModel::to_bytes`]
    /// (or read from a checkpoint file).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = bytes;
        let model = Self::read_ftm1(&mut r)?;
        if !r.is_empty() {
            bail!("trailing bytes after the model checkpoint");
        }
        Ok(model)
    }

    fn byte_len(&self) -> usize {
        let floats: usize = self.factors.iter().map(Vec::len).sum::<usize>()
            + self.cores.iter().map(Vec::len).sum::<usize>();
        4 + 4 * (3 + self.dims.len()) + 4 * floats
    }

    fn write_ftm1<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(Self::MAGIC)?;
        w.write_all(&(self.order() as u32).to_le_bytes())?;
        w.write_all(&(self.j as u32).to_le_bytes())?;
        w.write_all(&(self.r as u32).to_le_bytes())?;
        for &d in &self.dims {
            w.write_all(&d.to_le_bytes())?;
        }
        for f in &self.factors {
            for v in f {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        for c in &self.cores {
            for v in c {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    fn read_ftm1<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("not a model checkpoint");
        }
        let order = read_u32(r)? as usize;
        if order == 0 || order > 16 {
            bail!("implausible model order {order}");
        }
        let j = read_u32(r)? as usize;
        let rr = read_u32(r)? as usize;
        let mut dims = Vec::with_capacity(order);
        for _ in 0..order {
            dims.push(read_u32(r)?);
        }
        let mut factors = Vec::with_capacity(order);
        for &d in &dims {
            factors.push(read_f32s(r, d as usize * j)?);
        }
        let mut cores = Vec::with_capacity(order);
        for _ in 0..order {
            cores.push(read_f32s(r, j * rr)?);
        }
        Ok(Self {
            dims,
            j,
            r: rr,
            factors,
            cores,
        })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TuckerModel {
        TuckerModel::init(&[10, 12, 14], 16, 16, 42)
    }

    #[test]
    fn bytes_roundtrip_is_exact() {
        let m = model();
        let bytes = m.to_bytes();
        let back = TuckerModel::from_bytes(&bytes).unwrap();
        assert_eq!(m.dims, back.dims);
        assert_eq!((m.j, m.r), (back.j, back.r));
        assert_eq!(m.factors, back.factors);
        assert_eq!(m.cores, back.cores);
        assert!(TuckerModel::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(TuckerModel::from_bytes(b"FTMX").is_err());
    }

    #[test]
    fn init_shapes() {
        let m = model();
        assert_eq!(m.factors[0].len(), 10 * 16);
        assert_eq!(m.factors[2].len(), 14 * 16);
        assert_eq!(m.cores[1].len(), 16 * 16);
    }

    #[test]
    #[should_panic]
    fn init_rejects_non_multiple_of_16() {
        TuckerModel::init(&[4, 4], 8, 16, 0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut m = model();
        let coords: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 9, 11, 13];
        let (n, s, j) = (3, 4, 16);
        let mut buf = vec![0f32; n * s * j];
        m.gather_batch(&coords, 3, &mut buf);
        // padding zeroed
        assert!(buf[0 * s * j + 3 * j..(0 * s * j) + 4 * j].iter().all(|&v| v == 0.0));
        // gathered rows match source
        assert_eq!(&buf[0..j], m.factor_row(0, 0));
        assert_eq!(&buf[s * j + j..s * j + 2 * j], m.factor_row(1, 4));
        // scatter modified rows back
        let mut upd = buf.clone();
        for v in upd.iter_mut() {
            *v += 1.0;
        }
        m.scatter_batch(&coords, 3, &upd);
        assert!((m.factor_row(0, 0)[0] - (buf[0] + 1.0)).abs() < 1e-6);
        assert!((m.factor_row(2, 13)[5] - (buf[2 * s * j + 2 * j + 5] + 1.0)).abs() < 1e-6);
    }

    #[test]
    fn predict_matches_manual() {
        let m = TuckerModel::init(&[4, 4], 16, 16, 7);
        let p = m.predict_one(&[1, 2]);
        // manual: sum_r (a1.b^(1)_r)(a2.b^(2)_r)
        let mut want = 0f32;
        for r in 0..16 {
            let mut p1 = 0f32;
            let mut p2 = 0f32;
            for j in 0..16 {
                p1 += m.factor_row(0, 1)[j] * m.cores[0][j * 16 + r];
                p2 += m.factor_row(1, 2)[j] * m.cores[1][j * 16 + r];
            }
            want += p1 * p2;
        }
        assert!((p - want).abs() < 1e-4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = model();
        let dir = std::env::temp_dir().join("ft_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.ftm");
        m.save(&p).unwrap();
        let u = TuckerModel::load(&p).unwrap();
        assert_eq!(m.dims, u.dims);
        assert_eq!(m.factors, u.factors);
        assert_eq!(m.cores, u.cores);
    }

    #[test]
    fn rotated_core_pack() {
        let m = model();
        let sz = 16 * 16;
        let mut buf = vec![0f32; 3 * sz];
        m.pack_cores_rotated(1, &mut buf);
        assert_eq!(&buf[0..sz], &m.cores[1][..]);
        assert_eq!(&buf[sz..2 * sz], &m.cores[2][..]);
        assert_eq!(&buf[2 * sz..], &m.cores[0][..]);
    }

    #[test]
    fn core_grad_apply() {
        let mut m = model();
        let before = m.cores[0][0];
        let grad = vec![1.0f32; 3 * 16 * 16];
        m.apply_core_grad(&grad, 10, 0.1, 0.0);
        assert!((m.cores[0][0] - (before + 0.1 / 10.0)).abs() < 1e-6);
    }
}
