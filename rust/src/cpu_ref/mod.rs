//! Pure-Rust scalar reference implementations of Algorithms 1-3.
//!
//! Three roles:
//! 1. correctness oracle for the HLO/PJRT path (integration tests assert the
//!    runtime-backed trainer matches these to f32 tolerance);
//! 2. the "CUDA cores, no batching" analog in the Table 8 / Fig. 4 speedup
//!    experiments (scalar dot products ≙ per-thread FMA path);
//! 3. the convergence baseline for the Fig. 1 analog (faithful sequential
//!    per-sample updates, no Hogwild batching effects).
//!
//! The whole-pass functions below are the *oracles*; the CPU execution
//! backends run the block-level re-formulation (same per-sample math,
//! scheduled by `coordinator::phases`, optionally Hogwild-parallel)
//! through the tiled kernels in [`crate::kernel`], with the scalar
//! versions in [`step`] as the reference path and shape fallback.

pub mod step;

use crate::model::TuckerModel;
use crate::tensor::{FiberIndex, ModeSliceIndex, SparseTensor};
use crate::util::rng::Pcg32;

/// Hyper-parameters shared by all algorithms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hyper {
    /// Factor-matrix learning rate.
    pub lr_a: f32,
    /// Core-matrix learning rate.
    pub lr_b: f32,
    /// Factor-matrix L2 regularization.
    pub lam_a: f32,
    /// Core-matrix L2 regularization.
    pub lam_b: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Self {
            lr_a: 0.01,
            lr_b: 0.005,
            lam_a: 0.01,
            lam_b: 0.01,
        }
    }
}

/// Scratch to avoid per-sample allocation.
struct Scratch {
    c: Vec<f32>,   // N x R projection rows
    d: Vec<f32>,   // N x R complementary products
    pre: Vec<f32>, // (N+1) x R prefix
    suf: Vec<f32>, // (N+1) x R suffix
}

impl Scratch {
    fn new(n: usize, r: usize) -> Self {
        Self {
            c: vec![0.0; n * r],
            d: vec![0.0; n * r],
            pre: vec![0.0; (n + 1) * r],
            suf: vec![0.0; (n + 1) * r],
        }
    }
}

/// Compute per-mode projections c^(n) = a^(n) B^(n), the exclusion products
/// d^(n) (prefix/suffix trick) and the prediction for one entry.
fn forward(model: &TuckerModel, coords: &[u32], s: &mut Scratch) -> f32 {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    for m in 0..n {
        let row = model.factor_row(m, coords[m] as usize);
        let core = &model.cores[m];
        let c = &mut s.c[m * r..(m + 1) * r];
        c.fill(0.0);
        for jj in 0..j {
            let a = row[jj];
            let brow = &core[jj * r..(jj + 1) * r];
            for rr in 0..r {
                c[rr] += a * brow[rr];
            }
        }
    }
    // prefix/suffix
    s.pre[..r].fill(1.0);
    for m in 0..n {
        for rr in 0..r {
            s.pre[(m + 1) * r + rr] = s.pre[m * r + rr] * s.c[m * r + rr];
        }
    }
    s.suf[n * r..(n + 1) * r].fill(1.0);
    for m in (0..n).rev() {
        for rr in 0..r {
            s.suf[m * r + rr] = s.suf[(m + 1) * r + rr] * s.c[m * r + rr];
        }
    }
    for m in 0..n {
        for rr in 0..r {
            s.d[m * r + rr] = s.pre[m * r + rr] * s.suf[(m + 1) * r + rr];
        }
    }
    s.pre[n * r..(n + 1) * r].iter().sum()
}

/// One FastTuckerPlus (Alg. 3) factor pass over the given entry order:
/// per sample, update ALL factor rows simultaneously (Eq. 12).
pub fn plus_factor_pass(model: &mut TuckerModel, t: &SparseTensor, order: &[u32], hp: Hyper) {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    let mut s = Scratch::new(n, r);
    let mut db = vec![0.0f32; j];
    for &e in order {
        let coords = t.coords(e as usize).to_vec();
        let xhat = forward(model, &coords, &mut s);
        let err = t.values[e as usize] - xhat;
        for m in 0..n {
            // db = d^(m) B^(m)^T
            let core = &model.cores[m];
            for jj in 0..j {
                let mut acc = 0.0f32;
                let brow = &core[jj * r..(jj + 1) * r];
                for rr in 0..r {
                    acc += s.d[m * r + rr] * brow[rr];
                }
                db[jj] = acc;
            }
            let row_start = coords[m] as usize * j;
            let row = &mut model.factors[m][row_start..row_start + j];
            for jj in 0..j {
                row[jj] += hp.lr_a * (err * db[jj] - hp.lam_a * row[jj]);
            }
        }
    }
}

/// One FastTuckerPlus (Alg. 3) core pass: accumulate gradients for all
/// B^(n) over `order`, then apply once (Eq. 13 with the paper's
/// accumulate-then-atomicAdd schedule).
pub fn plus_core_pass(model: &mut TuckerModel, t: &SparseTensor, order: &[u32], hp: Hyper) {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    let mut s = Scratch::new(n, r);
    let mut grad = vec![0.0f32; n * j * r];
    for &e in order {
        let coords = t.coords(e as usize);
        let xhat = forward(model, coords, &mut s);
        let err = t.values[e as usize] - xhat;
        for m in 0..n {
            let row = model.factor_row(m, coords[m] as usize);
            let g = &mut grad[m * j * r..(m + 1) * j * r];
            for jj in 0..j {
                let ea = err * row[jj];
                for rr in 0..r {
                    g[jj * r + rr] += ea * s.d[m * r + rr];
                }
            }
        }
    }
    model.apply_core_grad(&grad, order.len(), hp.lr_b, hp.lam_b);
}

/// One FastTucker (Alg. 1) factor pass: for each mode n, walk Ω grouped by
/// slice (Ω_{i_n}^(n)) and update only a^(n)_{i_n,:} per sample (Eq. 8).
pub fn fasttucker_factor_pass(
    model: &mut TuckerModel,
    t: &SparseTensor,
    slices: &[ModeSliceIndex],
    hp: Hyper,
) {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    let mut s = Scratch::new(n, r);
    let mut db = vec![0.0f32; j];
    for (mode, idx) in slices.iter().enumerate() {
        for i in 0..model.dims[mode] as usize {
            for &e in idx.slice(i) {
                let coords = t.coords(e as usize).to_vec();
                let xhat = forward(model, &coords, &mut s);
                let err = t.values[e as usize] - xhat;
                let core = &model.cores[mode];
                for jj in 0..j {
                    let mut acc = 0.0f32;
                    for rr in 0..r {
                        acc += s.d[mode * r + rr] * core[jj * r + rr];
                    }
                    db[jj] = acc;
                }
                let row_start = coords[mode] as usize * j;
                let row = &mut model.factors[mode][row_start..row_start + j];
                for jj in 0..j {
                    row[jj] += hp.lr_a * (err * db[jj] - hp.lam_a * row[jj]);
                }
            }
        }
    }
}

/// One FastTucker (Alg. 1) core pass: per mode, accumulate grad of B^(n)
/// over all of Ω, apply at mode end (Eq. 9).
pub fn fasttucker_core_pass(model: &mut TuckerModel, t: &SparseTensor, hp: Hyper) {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    let mut s = Scratch::new(n, r);
    for mode in 0..n {
        let mut grad = vec![0.0f32; j * r];
        for e in 0..t.nnz() {
            let coords = t.coords(e);
            let xhat = forward(model, coords, &mut s);
            let err = t.values[e] - xhat;
            let row = model.factor_row(mode, coords[mode] as usize);
            for jj in 0..j {
                let ea = err * row[jj];
                for rr in 0..r {
                    grad[jj * r + rr] += ea * s.d[mode * r + rr];
                }
            }
        }
        model.apply_core_grad_mode(mode, &grad, t.nnz(), hp.lr_b, hp.lam_b);
    }
}

/// One FasterTucker (Alg. 2) factor pass with the storage scheme: C^(n) is
/// precomputed per mode pass and *read*; only the target mode's projection
/// is recomputed as its rows change.
pub fn fastertucker_factor_pass(
    model: &mut TuckerModel,
    t: &SparseTensor,
    fibers: &[FiberIndex],
    hp: Hyper,
) {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    let mut db = vec![0.0f32; j];
    let mut d = vec![0.0f32; r];
    let mut c_own = vec![0.0f32; r];
    for (mode, idx) in fibers.iter().enumerate() {
        // storage scheme: C^(k) for all k (refreshed at mode-pass start)
        let c_stored: Vec<Vec<f32>> = (0..n).map(|m| compute_c_full(model, m)).collect();
        for f in 0..idx.num_fibers() {
            let fiber = idx.fiber(f);
            // d is shared by the whole fiber (all non-target coords equal)
            let c0 = t.coords(fiber[0] as usize);
            d.fill(1.0);
            for m in 0..n {
                if m == mode {
                    continue;
                }
                let crow = &c_stored[m][c0[m] as usize * r..(c0[m] as usize + 1) * r];
                for rr in 0..r {
                    d[rr] *= crow[rr];
                }
            }
            for &e in fiber {
                let coords = t.coords(e as usize).to_vec();
                // recompute own projection from the live row
                let row_start = coords[mode] as usize * j;
                {
                    let row = &model.factors[mode][row_start..row_start + j];
                    let core = &model.cores[mode];
                    c_own.fill(0.0);
                    for jj in 0..j {
                        for rr in 0..r {
                            c_own[rr] += row[jj] * core[jj * r + rr];
                        }
                    }
                }
                let xhat: f32 = (0..r).map(|rr| c_own[rr] * d[rr]).sum();
                let err = t.values[e as usize] - xhat;
                let core = &model.cores[mode];
                for jj in 0..j {
                    let mut acc = 0.0f32;
                    for rr in 0..r {
                        acc += d[rr] * core[jj * r + rr];
                    }
                    db[jj] = acc;
                }
                let row = &mut model.factors[mode][row_start..row_start + j];
                for jj in 0..j {
                    row[jj] += hp.lr_a * (err * db[jj] - hp.lam_a * row[jj]);
                }
            }
        }
    }
}

/// One FasterTucker (Alg. 2) core pass (storage scheme).
pub fn fastertucker_core_pass(
    model: &mut TuckerModel,
    t: &SparseTensor,
    fibers: &[FiberIndex],
    hp: Hyper,
) {
    let n = model.order();
    let (j, r) = (model.j, model.r);
    let mut d = vec![0.0f32; r];
    for (mode, idx) in fibers.iter().enumerate() {
        let c_stored: Vec<Vec<f32>> = (0..n).map(|m| compute_c_full(model, m)).collect();
        let mut grad = vec![0.0f32; j * r];
        let mut count = 0usize;
        for f in 0..idx.num_fibers() {
            let fiber = idx.fiber(f);
            let c0 = t.coords(fiber[0] as usize);
            d.fill(1.0);
            for m in 0..n {
                if m == mode {
                    continue;
                }
                let crow = &c_stored[m][c0[m] as usize * r..(c0[m] as usize + 1) * r];
                for rr in 0..r {
                    d[rr] *= crow[rr];
                }
            }
            for &e in fiber {
                let coords = t.coords(e as usize);
                let crow =
                    &c_stored[mode][coords[mode] as usize * r..(coords[mode] as usize + 1) * r];
                let xhat: f32 = (0..r).map(|rr| crow[rr] * d[rr]).sum();
                let err = t.values[e as usize] - xhat;
                let row = model.factor_row(mode, coords[mode] as usize);
                for jj in 0..j {
                    let ea = err * row[jj];
                    for rr in 0..r {
                        grad[jj * r + rr] += ea * d[rr];
                    }
                }
                count += 1;
            }
        }
        model.apply_core_grad_mode(mode, &grad, count, hp.lr_b, hp.lam_b);
    }
}

/// Dense projection table C^(n) = A^(n) B^(n)  (I_n x R).
pub fn compute_c_full(model: &TuckerModel, mode: usize) -> Vec<f32> {
    let (j, r) = (model.j, model.r);
    let i = model.dims[mode] as usize;
    let mut c = vec![0.0f32; i * r];
    let f = &model.factors[mode];
    let core = &model.cores[mode];
    for row in 0..i {
        let a = &f[row * j..(row + 1) * j];
        let cr = &mut c[row * r..(row + 1) * r];
        for jj in 0..j {
            let av = a[jj];
            let brow = &core[jj * r..(jj + 1) * r];
            for rr in 0..r {
                cr[rr] += av * brow[rr];
            }
        }
    }
    c
}

/// RMSE / MAE over a test tensor (scalar path).
pub fn evaluate(model: &TuckerModel, test: &SparseTensor) -> (f64, f64) {
    let mut s = Scratch::new(model.order(), model.r);
    let mut sse = 0f64;
    let mut sae = 0f64;
    for e in 0..test.nnz() {
        let xhat = forward(model, test.coords(e), &mut s);
        let err = (test.values[e] - xhat) as f64;
        sse += err * err;
        sae += err.abs();
    }
    let n = test.nnz().max(1) as f64;
    ((sse / n).sqrt(), sae / n)
}

/// Shuffled epoch order for the Plus passes.
pub fn epoch_order(nnz: usize, seed: u64, epoch: u64) -> Vec<u32> {
    let mut rng = Pcg32::new(seed, 0xE40C ^ epoch);
    let mut ids: Vec<u32> = (0..nnz as u32).collect();
    rng.shuffle(&mut ids);
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthConfig};
    use crate::tensor::split::train_test_split;

    fn setup() -> (TuckerModel, SparseTensor, SparseTensor) {
        let t = generate(&SynthConfig::order_sweep(3, 32, 3000, 21));
        let (train, test) = train_test_split(&t, 0.2, 1);
        let model = TuckerModel::init(&train.dims, 16, 16, 5);
        (model, train, test)
    }

    #[test]
    fn plus_converges() {
        let (mut model, train, test) = setup();
        let (rmse0, _) = evaluate(&model, &test);
        let hp = Hyper::default();
        for epoch in 0..12 {
            let order = epoch_order(train.nnz(), 3, epoch);
            plus_factor_pass(&mut model, &train, &order, hp);
            plus_core_pass(&mut model, &train, &order, hp);
        }
        let (rmse1, mae1) = evaluate(&model, &test);
        assert!(
            rmse1 < rmse0 * 0.8,
            "no convergence: {rmse0} -> {rmse1} (mae {mae1})"
        );
        assert!(model.param_norm().is_finite());
    }

    #[test]
    fn fasttucker_converges() {
        let (mut model, train, test) = setup();
        let (rmse0, _) = evaluate(&model, &test);
        let hp = Hyper::default();
        let slices: Vec<_> = (0..3).map(|m| ModeSliceIndex::build(&train, m)).collect();
        for _ in 0..8 {
            fasttucker_factor_pass(&mut model, &train, &slices, hp);
            fasttucker_core_pass(&mut model, &train, hp);
        }
        let (rmse1, _) = evaluate(&model, &test);
        assert!(rmse1 < rmse0 * 0.9, "no convergence: {rmse0} -> {rmse1}");
    }

    #[test]
    fn fastertucker_converges() {
        let (mut model, train, test) = setup();
        let (rmse0, _) = evaluate(&model, &test);
        let hp = Hyper::default();
        let fibers: Vec<_> = (0..3).map(|m| FiberIndex::build(&train, m)).collect();
        for _ in 0..8 {
            fastertucker_factor_pass(&mut model, &train, &fibers, hp);
            fastertucker_core_pass(&mut model, &train, &fibers, hp);
        }
        let (rmse1, _) = evaluate(&model, &test);
        assert!(rmse1 < rmse0 * 0.9, "no convergence: {rmse0} -> {rmse1}");
    }

    #[test]
    fn compute_c_matches_predict() {
        let (model, train, _) = setup();
        let n = model.order();
        let cs: Vec<Vec<f32>> = (0..n).map(|m| compute_c_full(&model, m)).collect();
        for e in (0..train.nnz()).step_by(97) {
            let coords = train.coords(e);
            let mut want = 0f32;
            for rr in 0..model.r {
                let mut p = 1f32;
                for m in 0..n {
                    p *= cs[m][coords[m] as usize * model.r + rr];
                }
                want += p;
            }
            let got = model.predict_one(coords);
            assert!((want - got).abs() < 1e-3, "{want} vs {got}");
        }
    }
}
