//! Block-level *scalar* step kernels — the per-sample oracle the tiled
//! kernels in [`crate::kernel`] are verified against.
//!
//! Each function processes a contiguous `range` of valid slots from a
//! staged block (`coords` `[S, N]` / `values` `[S]` slabs) and performs the
//! per-sample math of one algorithm — the same equations as the whole-pass
//! oracles in the parent module, restructured around blocks so the generic
//! phase driver (`coordinator::phases`) can schedule them.
//!
//! The CPU backends normally dispatch through the tiled kernels
//! ([`crate::kernel::run_factor_range`] / [`crate::kernel::run_core_range`]);
//! the `*_scalar` functions here are the runtime-width reference path,
//! selected by [`crate::kernel::KernelPolicy::Scalar`] (CLI:
//! `--cpu-kernel scalar`) and used as the fallback for `(J, R)` shapes
//! without a monomorphized tile.
//!
//! All factor access goes through [`SharedFactors`] (relaxed atomic rows):
//!
//! * `workers = 1` — the serial `CpuRef` backend; relaxed atomics on a
//!   single thread are plain loads/stores, so trajectories are exactly the
//!   sequential per-sample semantics.
//! * `workers > 1` — the `ParallelCpu` backend shards `range` across
//!   threads; colliding row writes are the paper's benign Hogwild race,
//!   expressed as last-writer-wins relaxed stores.
//!
//! Core-phase functions never write the model: they accumulate into a
//! caller-provided gradient slab (per-worker locals, merged afterwards),
//! the paper's accumulate-then-atomicAdd schedule.

use std::ops::Range;

use crate::model::SharedFactors;

use super::Hyper;

/// Read-only inputs shared by every step in a block.
pub struct BlockData<'a> {
    /// Core matrices `B^(n)`, `J x R` row-major each.
    pub cores: &'a [Vec<f32>],
    /// Stored projection tables `C^(n)` (`I_n x R`); empty for algorithms
    /// that do not use the storage scheme.
    pub c_store: &'a [Vec<f32>],
    /// Entry coordinates `[S, N]`, entry-major, valid slots compacted to
    /// the front.
    pub coords: &'a [u32],
    /// The same coordinates laid out `[N, S]` *mode-major* (one contiguous
    /// lane per mode), as staged by `sampler::stream`.  May be empty when a
    /// caller only has the entry-major slab; kernels that scan a single
    /// mode use [`BlockData::coord`], which prefers the lane layout.
    pub lanes: &'a [u32],
    /// Entry values `[S]`.
    pub values: &'a [f32],
    /// Tensor order N.
    pub n: usize,
    /// Factor rank J (columns of each `A^(n)` row).
    pub j: usize,
    /// Kruskal rank R (columns of each `B^(n)`).
    pub r: usize,
    /// Learning rates / regularization for the update rules.
    pub hyper: Hyper,
}

impl BlockData<'_> {
    /// Coordinates of slot `e`, entry-major (one cache line per sample).
    #[inline]
    pub fn entry_coords(&self, e: usize) -> &[u32] {
        &self.coords[e * self.n..(e + 1) * self.n]
    }

    /// Mode-`m` coordinate of slot `e`.  Reads the contiguous mode-major
    /// lane when the block was staged with one (sequential scans of a
    /// single mode touch consecutive words), the entry-major slab
    /// otherwise.
    #[inline]
    pub fn coord(&self, e: usize, m: usize) -> u32 {
        if self.lanes.is_empty() {
            self.coords[e * self.n + m]
        } else {
            // lane stride is the staged slot count S == values.len()
            debug_assert_eq!(self.lanes.len(), self.n * self.values.len());
            self.lanes[m * self.values.len() + e]
        }
    }
}

/// Per-worker scratch (no per-sample allocation).
struct Scratch {
    rows: Vec<f32>,    // N x J gathered factor rows
    new_row: Vec<f32>, // J updated row
    c: Vec<f32>,       // N x R projections
    d: Vec<f32>,       // N x R exclusion products
    pre: Vec<f32>,     // (N+1) x R prefix
    suf: Vec<f32>,     // (N+1) x R suffix
    db: Vec<f32>,      // J
}

impl Scratch {
    fn new(n: usize, j: usize, r: usize) -> Scratch {
        Scratch {
            rows: vec![0.0; n * j],
            new_row: vec![0.0; j],
            c: vec![0.0; n * r],
            d: vec![0.0; n * r],
            pre: vec![0.0; (n + 1) * r],
            suf: vec![0.0; (n + 1) * r],
            db: vec![0.0; j],
        }
    }
}

/// Projections c^(n), exclusion products d^(n) and the prediction, from
/// pre-gathered rows (the staged analog of the oracle's `forward`).
fn forward_rows(data: &BlockData, s: &mut Scratch) -> f32 {
    let (n, j, r) = (data.n, data.j, data.r);
    for m in 0..n {
        let row = &s.rows[m * j..(m + 1) * j];
        let core = &data.cores[m];
        let c = &mut s.c[m * r..(m + 1) * r];
        c.fill(0.0);
        for jj in 0..j {
            let a = row[jj];
            let brow = &core[jj * r..(jj + 1) * r];
            for rr in 0..r {
                c[rr] += a * brow[rr];
            }
        }
    }
    s.pre[..r].fill(1.0);
    for m in 0..n {
        for rr in 0..r {
            s.pre[(m + 1) * r + rr] = s.pre[m * r + rr] * s.c[m * r + rr];
        }
    }
    s.suf[n * r..(n + 1) * r].fill(1.0);
    for m in (0..n).rev() {
        for rr in 0..r {
            s.suf[m * r + rr] = s.suf[(m + 1) * r + rr] * s.c[m * r + rr];
        }
    }
    for m in 0..n {
        for rr in 0..r {
            s.d[m * r + rr] = s.pre[m * r + rr] * s.suf[(m + 1) * r + rr];
        }
    }
    s.pre[n * r..(n + 1) * r].iter().sum()
}

#[inline]
fn load_all_rows(shared: &SharedFactors<'_>, data: &BlockData, coords: &[u32], s: &mut Scratch) {
    let j = data.j;
    for m in 0..data.n {
        shared.load_row(m, coords[m] as usize, &mut s.rows[m * j..(m + 1) * j]);
    }
}

#[inline]
fn db_from_core(core: &[f32], d: &[f32], j: usize, r: usize, db: &mut [f32]) {
    for jj in 0..j {
        let mut acc = 0.0f32;
        let brow = &core[jj * r..(jj + 1) * r];
        for rr in 0..r {
            acc += d[rr] * brow[rr];
        }
        db[jj] = acc;
    }
}

/// FastTuckerPlus (Alg. 3) factor step: update ALL factor rows of each
/// sample simultaneously (Eq. 12).  Scalar reference path.
pub fn plus_factor_scalar(shared: &SharedFactors<'_>, data: &BlockData, range: Range<usize>) {
    let (n, j, r) = (data.n, data.j, data.r);
    let hp = data.hyper;
    let mut s = Scratch::new(n, j, r);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward_rows(data, &mut s);
        let err = data.values[e] - xhat;
        for m in 0..n {
            db_from_core(&data.cores[m], &s.d[m * r..(m + 1) * r], j, r, &mut s.db);
            let row = &s.rows[m * j..(m + 1) * j];
            for jj in 0..j {
                s.new_row[jj] = row[jj] + hp.lr_a * (err * s.db[jj] - hp.lam_a * row[jj]);
            }
            shared.store_row(m, coords[m] as usize, &s.new_row);
        }
    }
}

/// FastTuckerPlus (Alg. 3) core step: accumulate `∂B^(n)` for every mode
/// into `grad` (`[N, J, R]`), applied once per phase by the caller.
/// Scalar reference path.
pub fn plus_core_scalar(
    shared: &SharedFactors<'_>,
    data: &BlockData,
    range: Range<usize>,
    grad: &mut [f32],
) {
    let (n, j, r) = (data.n, data.j, data.r);
    let mut s = Scratch::new(n, j, r);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward_rows(data, &mut s);
        let err = data.values[e] - xhat;
        for m in 0..n {
            let row = &s.rows[m * j..(m + 1) * j];
            let g = &mut grad[m * j * r..(m + 1) * j * r];
            for jj in 0..j {
                let ea = err * row[jj];
                for rr in 0..r {
                    g[jj * r + rr] += ea * s.d[m * r + rr];
                }
            }
        }
    }
}

/// FastTucker (Alg. 1) factor step for one mode: full forward, update only
/// `a^(mode)` (Eq. 8).  Scalar reference path.
pub fn mode_factor_scalar(
    shared: &SharedFactors<'_>,
    data: &BlockData,
    mode: usize,
    range: Range<usize>,
) {
    let (n, j, r) = (data.n, data.j, data.r);
    let hp = data.hyper;
    let mut s = Scratch::new(n, j, r);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward_rows(data, &mut s);
        let err = data.values[e] - xhat;
        db_from_core(&data.cores[mode], &s.d[mode * r..(mode + 1) * r], j, r, &mut s.db);
        let row = &s.rows[mode * j..(mode + 1) * j];
        for jj in 0..j {
            s.new_row[jj] = row[jj] + hp.lr_a * (err * s.db[jj] - hp.lam_a * row[jj]);
        }
        shared.store_row(mode, coords[mode] as usize, &s.new_row);
    }
}

/// FastTucker (Alg. 1) core step for one mode: accumulate `∂B^(mode)` into
/// `grad` (`[J, R]`), applied at pass end (Eq. 9).  Scalar reference path.
pub fn mode_core_scalar(
    shared: &SharedFactors<'_>,
    data: &BlockData,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
) {
    let (n, j, r) = (data.n, data.j, data.r);
    let mut s = Scratch::new(n, j, r);
    for e in range {
        let coords = data.entry_coords(e);
        load_all_rows(shared, data, coords, &mut s);
        let xhat = forward_rows(data, &mut s);
        let err = data.values[e] - xhat;
        let row = &s.rows[mode * j..(mode + 1) * j];
        for jj in 0..j {
            let ea = err * row[jj];
            for rr in 0..r {
                grad[jj * r + rr] += ea * s.d[mode * r + rr];
            }
        }
    }
}

/// Exclusion product d from the stored projection tables (all modes except
/// `mode`) for one entry.
#[inline]
fn stored_d(data: &BlockData, coords: &[u32], mode: usize, d: &mut [f32]) {
    let r = data.r;
    d.fill(1.0);
    for m in 0..data.n {
        if m == mode {
            continue;
        }
        let row = coords[m] as usize;
        let crow = &data.c_store[m][row * r..(row + 1) * r];
        for rr in 0..r {
            d[rr] *= crow[rr];
        }
    }
}

/// FasterTucker (Alg. 2) factor step for one mode (storage scheme): d from
/// stored C rows, own projection recomputed from the live row.  Scalar
/// reference path.
pub fn stored_factor_scalar(
    shared: &SharedFactors<'_>,
    data: &BlockData,
    mode: usize,
    range: Range<usize>,
) {
    let (j, r) = (data.j, data.r);
    let hp = data.hyper;
    let mut d = vec![0f32; r];
    let mut c_own = vec![0f32; r];
    let mut row = vec![0f32; j];
    let mut new_row = vec![0f32; j];
    let mut db = vec![0f32; j];
    let core = &data.cores[mode];
    for e in range {
        let coords = data.entry_coords(e);
        stored_d(data, coords, mode, &mut d);
        shared.load_row(mode, coords[mode] as usize, &mut row);
        c_own.fill(0.0);
        for jj in 0..j {
            let a = row[jj];
            let brow = &core[jj * r..(jj + 1) * r];
            for rr in 0..r {
                c_own[rr] += a * brow[rr];
            }
        }
        let xhat: f32 = (0..r).map(|rr| c_own[rr] * d[rr]).sum();
        let err = data.values[e] - xhat;
        db_from_core(core, &d, j, r, &mut db);
        for jj in 0..j {
            new_row[jj] = row[jj] + hp.lr_a * (err * db[jj] - hp.lam_a * row[jj]);
        }
        shared.store_row(mode, coords[mode] as usize, &new_row);
    }
}

/// FasterTucker (Alg. 2) core step for one mode (storage scheme):
/// prediction entirely from stored C rows, gradient into `grad` (`[J, R]`).
/// Scalar reference path.
pub fn stored_core_scalar(
    shared: &SharedFactors<'_>,
    data: &BlockData,
    mode: usize,
    range: Range<usize>,
    grad: &mut [f32],
) {
    let (j, r) = (data.j, data.r);
    let mut d = vec![0f32; r];
    let mut row = vec![0f32; j];
    for e in range {
        let coords = data.entry_coords(e);
        stored_d(data, coords, mode, &mut d);
        let crow_lo = coords[mode] as usize * r;
        let crow = &data.c_store[mode][crow_lo..crow_lo + r];
        let xhat: f32 = (0..r).map(|rr| crow[rr] * d[rr]).sum();
        let err = data.values[e] - xhat;
        shared.load_row(mode, coords[mode] as usize, &mut row);
        for jj in 0..j {
            let ea = err * row[jj];
            for rr in 0..r {
                grad[jj * r + rr] += ea * d[rr];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TuckerModel;
    use crate::synth::{generate, SynthConfig};
    use crate::tensor::SparseTensor;

    fn staged(t: &SparseTensor) -> (Vec<u32>, Vec<f32>) {
        let mut coords = Vec::new();
        let mut values = Vec::new();
        for e in 0..t.nnz() {
            coords.extend_from_slice(t.coords(e));
            values.push(t.values[e]);
        }
        (coords, values)
    }

    /// The block step over one full-tensor "block" in entry order must match
    /// the whole-pass oracle exactly (same math, same order).
    #[test]
    fn plus_factor_step_matches_oracle_pass() {
        let t = generate(&SynthConfig::order_sweep(3, 24, 800, 3));
        let hp = Hyper::default();
        let mut a = TuckerModel::init(&t.dims, 16, 16, 9);
        let mut b = a.clone();

        let order: Vec<u32> = (0..t.nnz() as u32).collect();
        super::super::plus_factor_pass(&mut a, &t, &order, hp);

        let (coords, values) = staged(&t);
        let cores = b.cores.clone();
        {
            let shared = SharedFactors::new(&mut b.factors, 16);
            let data = BlockData {
                cores: &cores,
                c_store: &[],
                coords: &coords,
                lanes: &[],
                values: &values,
                n: 3,
                j: 16,
                r: 16,
                hyper: hp,
            };
            plus_factor_scalar(&shared, &data, 0..t.nnz());
        }
        for m in 0..3 {
            for (x, y) in a.factors[m].iter().zip(&b.factors[m]) {
                assert!((x - y).abs() < 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn plus_core_step_matches_oracle_pass() {
        let t = generate(&SynthConfig::order_sweep(3, 24, 800, 5));
        let hp = Hyper::default();
        let mut a = TuckerModel::init(&t.dims, 16, 16, 11);
        let mut b = a.clone();

        let order: Vec<u32> = (0..t.nnz() as u32).collect();
        super::super::plus_core_pass(&mut a, &t, &order, hp);

        let (coords, values) = staged(&t);
        let cores = b.cores.clone();
        let mut grad = vec![0f32; 3 * 16 * 16];
        {
            let shared = SharedFactors::new(&mut b.factors, 16);
            let data = BlockData {
                cores: &cores,
                c_store: &[],
                coords: &coords,
                lanes: &[],
                values: &values,
                n: 3,
                j: 16,
                r: 16,
                hyper: hp,
            };
            plus_core_scalar(&shared, &data, 0..t.nnz(), &mut grad);
        }
        b.apply_core_grad(&grad, t.nnz(), hp.lr_b, hp.lam_b);
        for m in 0..3 {
            for (x, y) in a.cores[m].iter().zip(&b.cores[m]) {
                assert!((x - y).abs() < 1e-6, "{x} vs {y}");
            }
        }
    }

    /// `coord()` must read identically through the entry-major slab and the
    /// mode-major lanes.
    #[test]
    fn coord_agrees_across_layouts() {
        let t = generate(&SynthConfig::order_sweep(3, 16, 200, 7));
        let (coords, values) = staged(&t);
        let n = t.order();
        let s = values.len();
        let mut lanes = vec![0u32; n * s];
        for m in 0..n {
            for e in 0..s {
                lanes[m * s + e] = coords[e * n + m];
            }
        }
        let with_lanes = BlockData {
            cores: &[],
            c_store: &[],
            coords: &coords,
            lanes: &lanes,
            values: &values,
            n,
            j: 16,
            r: 16,
            hyper: Hyper::default(),
        };
        let without = BlockData {
            lanes: &[],
            ..with_lanes
        };
        for e in (0..s).step_by(7) {
            for m in 0..n {
                assert_eq!(with_lanes.coord(e, m), without.coord(e, m));
                assert_eq!(without.coord(e, m), with_lanes.entry_coords(e)[m]);
            }
        }
    }
}
