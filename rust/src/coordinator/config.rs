//! Run configuration for the trainer / CLI / benches.

use std::path::PathBuf;

use crate::cpu_ref::Hyper;
use crate::kernel::KernelPolicy;

/// Which decomposition algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Algorithm 1 (per-mode convex SGD, mode-slice sampling).
    FastTucker,
    /// Algorithm 2 (per-mode SGD with stored C rows, fiber sampling with
    /// warp-aligned groups — the paper's cuFasterTucker).
    FasterTucker,
    /// Algorithm 2 with densely packed fibers (the paper's
    /// cuFasterTuckerCOO): full occupancy, no shared-intermediate reuse.
    FasterTuckerCoo,
    /// Algorithm 3 — the paper's contribution (two-block non-convex SGD,
    /// uniform sampling).
    Plus,
}

impl Algo {
    /// Parse a CLI value (`plus`, `fasttucker`, `fastertucker`,
    /// `fastertuckercoo`).
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "fasttucker" => Some(Algo::FastTucker),
            "fastertucker" => Some(Algo::FasterTucker),
            "fastertuckercoo" => Some(Algo::FasterTuckerCoo),
            "plus" | "fasttuckerplus" => Some(Algo::Plus),
            _ => None,
        }
    }

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Algo::FastTucker => "fasttucker",
            Algo::FasterTucker => "fastertucker",
            Algo::FasterTuckerCoo => "fastertuckercoo",
            Algo::Plus => "plus",
        }
    }

    /// Stable numeric code for on-disk headers (the serve checkpoint
    /// format).  Append-only: never renumber published codes.
    pub fn code(self) -> u32 {
        match self {
            Algo::FastTucker => 0,
            Algo::FasterTucker => 1,
            Algo::FasterTuckerCoo => 2,
            Algo::Plus => 3,
        }
    }

    /// Inverse of [`Algo::code`].
    pub fn from_code(code: u32) -> Option<Algo> {
        match code {
            0 => Some(Algo::FastTucker),
            1 => Some(Algo::FasterTucker),
            2 => Some(Algo::FasterTuckerCoo),
            3 => Some(Algo::Plus),
            _ => None,
        }
    }

    /// The corresponding row of the Table-4 analytic cost model.
    pub fn cost_algo(self) -> crate::cost::Algo {
        match self {
            Algo::FastTucker => crate::cost::Algo::FastTucker,
            Algo::FasterTucker | Algo::FasterTuckerCoo => crate::cost::Algo::FasterTucker,
            Algo::Plus => crate::cost::Algo::FastTuckerPlus,
        }
    }
}

/// Kernel variant: MXU/dot-shaped (the Tensor-Core analog) or
/// VPU/elementwise (the CUDA-Core analog).  See DESIGN.md §Hardware-Adaptation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Tensor-Core analog: matmul-shaped L1 kernels on the MXU.
    Tc,
    /// CUDA-Core analog: elementwise/vector L1 kernels on the VPU.
    Cc,
}

impl Variant {
    /// Parse a CLI value (`tc` / `cc`).
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "tc" => Some(Variant::Tc),
            "cc" => Some(Variant::Cc),
            _ => None,
        }
    }

    /// Artifact-name suffix for this variant.
    pub fn suffix(self) -> &'static str {
        match self {
            Variant::Tc => "tc",
            Variant::Cc => "cc",
        }
    }

    /// Canonical CLI name (`parse(name()) == Some(self)`).
    pub fn name(self) -> &'static str {
        self.suffix()
    }
}

/// C^(n) handling for FastTuckerPlus (§5.6): recompute per batch on the
/// matrix unit, or precompute + read rows.  On the CPU backends the same
/// knob selects the [`crate::kernel::InvariantPolicy`] of the
/// storage-scheme kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Recompute projections per batch — "computation instead of storage".
    Calculation,
    /// Precompute the C^(n) tables and read rows back per batch.
    Storage,
}

impl Strategy {
    /// Parse a CLI value (`calc`/`calculation` or `store`/`storage`).
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "calculation" | "calc" => Some(Strategy::Calculation),
            "storage" | "store" => Some(Strategy::Storage),
            _ => None,
        }
    }

    /// Canonical CLI name (`parse(name()) == Some(self)`).
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Calculation => "calculation",
            Strategy::Storage => "storage",
        }
    }
}

/// Execution backend: the PJRT/HLO path (the system under test), the
/// scalar CPU reference (oracle / scalar baseline), or the Hogwild
/// multi-threaded CPU engine (the paper's per-thread FMA path, parallel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Compiled PJRT/HLO artifacts (the L1/L2 kernels).
    Hlo,
    /// Single-threaded CPU kernels — the sequential reference.
    CpuRef,
    /// Multi-threaded Hogwild CPU engine (`--threads K`).
    ParallelCpu,
}

impl Backend {
    /// Parse a CLI value (`hlo`, `cpu`, `parallel`, and aliases).
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "hlo" => Some(Backend::Hlo),
            "cpu" | "cpuref" | "cpu_ref" => Some(Backend::CpuRef),
            "parallel" | "parallelcpu" | "parallel-cpu" | "parallel_cpu" => {
                Some(Backend::ParallelCpu)
            }
            _ => None,
        }
    }

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Hlo => "hlo",
            Backend::CpuRef => "cpu_ref",
            Backend::ParallelCpu => "parallel_cpu",
        }
    }
}

/// Full trainer configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Decomposition algorithm (Table-3 sampling strategy follows from it).
    pub algo: Algo,
    /// L1 kernel variant for the HLO backend (Tensor-Core vs CUDA-Core
    /// analog).
    pub variant: Variant,
    /// Calculation-vs-storage handling of the projection tables (§5.6).
    pub strategy: Strategy,
    /// Execution backend.
    pub backend: Backend,
    /// Factor rank J (uniform across modes, multiple of 16).
    pub j: usize,
    /// Kruskal rank R (multiple of 16).
    pub r: usize,
    /// SGD learning rates and regularization.
    pub hyper: Hyper,
    /// Run seed (model init, sampling shuffles, splits).
    pub seed: u64,
    /// Directory holding the compiled HLO artifacts + manifest.
    pub artifact_dir: PathBuf,
    /// Worker threads for the `ParallelCpu` backend's Hogwild block
    /// sharding (0 = auto-detect via `util::pool::default_threads`).
    pub threads: usize,
    /// CPU step implementation: tiled fixed-width microkernels (default),
    /// the scalar oracle (`--cpu-kernel scalar`), or the runtime-detected
    /// SIMD tier (`--cpu-kernel simd`).
    pub cpu_kernel: KernelPolicy,
    /// Sharded data-parallel workers for the [`crate::dist`] layer
    /// (0 = serial training through [`crate::session::Session`]).  When
    /// > 0, `train` runs N in-process workers over disjoint section
    /// ranges with barrier averaging; requires the `plus` algorithm and
    /// a CPU backend (see [`crate::session::SpecError`]).
    pub workers: usize,
}

impl TrainConfig {
    /// Whether the HLO backend's compiled artifacts are present under
    /// [`TrainConfig::artifact_dir`] (the manifest the runtime loads).
    /// Examples and tools use this to fall back to a CPU backend from a
    /// clean checkout.
    pub fn hlo_available(&self) -> bool {
        self.artifact_dir.join("manifest.json").exists()
    }

    /// The best backend this checkout can actually run: [`Backend::Hlo`]
    /// when the compiled artifacts are present under
    /// [`TrainConfig::artifact_dir`], [`Backend::ParallelCpu`] otherwise.
    ///
    /// This fixes the clean-checkout footgun where `TrainConfig::default()`
    /// selects the HLO backend and `Trainer::new` then fails without
    /// `artifacts/`.  [`crate::session::RunSpec`] defaults, the examples
    /// and the CLI's no-flag paths all route through this.
    pub fn auto_backend(&self) -> Backend {
        if self.hlo_available() {
            Backend::Hlo
        } else {
            Backend::ParallelCpu
        }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            algo: Algo::Plus,
            variant: Variant::Tc,
            strategy: Strategy::Calculation,
            backend: Backend::Hlo,
            j: 16,
            r: 16,
            hyper: Hyper::default(),
            seed: 42,
            artifact_dir: PathBuf::from("artifacts"),
            threads: 0,
            cpu_kernel: KernelPolicy::Tiled,
            workers: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_enums() {
        assert_eq!(Algo::parse("plus"), Some(Algo::Plus));
        assert_eq!(Algo::parse("fasttucker"), Some(Algo::FastTucker));
        assert_eq!(Algo::parse("x"), None);
        assert_eq!(Variant::parse("tc"), Some(Variant::Tc));
        assert_eq!(Strategy::parse("storage"), Some(Strategy::Storage));
        assert_eq!(Backend::parse("cpu"), Some(Backend::CpuRef));
        assert_eq!(Backend::parse("parallel"), Some(Backend::ParallelCpu));
        // name() round-trips through parse() for every config enum
        for a in [
            Algo::FastTucker,
            Algo::FasterTucker,
            Algo::FasterTuckerCoo,
            Algo::Plus,
        ] {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        for v in [Variant::Tc, Variant::Cc] {
            assert_eq!(Variant::parse(v.name()), Some(v));
        }
        for s in [Strategy::Calculation, Strategy::Storage] {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        for b in [Backend::Hlo, Backend::CpuRef, Backend::ParallelCpu] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        for k in [KernelPolicy::Tiled, KernelPolicy::Scalar, KernelPolicy::Simd] {
            assert_eq!(KernelPolicy::parse(k.name()), Some(k));
        }
        assert_eq!(KernelPolicy::parse("avx2"), None);
        // code() round-trips through from_code()
        for a in [
            Algo::FastTucker,
            Algo::FasterTucker,
            Algo::FasterTuckerCoo,
            Algo::Plus,
        ] {
            assert_eq!(Algo::from_code(a.code()), Some(a));
        }
        assert_eq!(Algo::from_code(99), None);
        assert_eq!(TrainConfig::default().cpu_kernel, KernelPolicy::Tiled);
    }

    #[test]
    fn auto_backend_follows_artifacts() {
        let dir = std::env::temp_dir().join("ft_auto_backend_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = TrainConfig {
            artifact_dir: dir.clone(),
            ..TrainConfig::default()
        };
        assert_eq!(cfg.auto_backend(), Backend::ParallelCpu);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), b"{}").unwrap();
        assert_eq!(cfg.auto_backend(), Backend::Hlo);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
