//! Run configuration for the trainer / CLI / benches.

use std::path::PathBuf;

use crate::cpu_ref::Hyper;

/// Which decomposition algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Algorithm 1 (per-mode convex SGD, mode-slice sampling).
    FastTucker,
    /// Algorithm 2 (per-mode SGD with stored C rows, fiber sampling with
    /// warp-aligned groups — the paper's cuFasterTucker).
    FasterTucker,
    /// Algorithm 2 with densely packed fibers (the paper's
    /// cuFasterTuckerCOO): full occupancy, no shared-intermediate reuse.
    FasterTuckerCoo,
    /// Algorithm 3 — the paper's contribution (two-block non-convex SGD,
    /// uniform sampling).
    Plus,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "fasttucker" => Some(Algo::FastTucker),
            "fastertucker" => Some(Algo::FasterTucker),
            "fastertuckercoo" => Some(Algo::FasterTuckerCoo),
            "plus" | "fasttuckerplus" => Some(Algo::Plus),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algo::FastTucker => "fasttucker",
            Algo::FasterTucker => "fastertucker",
            Algo::FasterTuckerCoo => "fastertuckercoo",
            Algo::Plus => "plus",
        }
    }

    pub fn cost_algo(self) -> crate::cost::Algo {
        match self {
            Algo::FastTucker => crate::cost::Algo::FastTucker,
            Algo::FasterTucker | Algo::FasterTuckerCoo => crate::cost::Algo::FasterTucker,
            Algo::Plus => crate::cost::Algo::FastTuckerPlus,
        }
    }
}

/// Kernel variant: MXU/dot-shaped (the Tensor-Core analog) or
/// VPU/elementwise (the CUDA-Core analog).  See DESIGN.md §Hardware-Adaptation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Tc,
    Cc,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "tc" => Some(Variant::Tc),
            "cc" => Some(Variant::Cc),
            _ => None,
        }
    }

    pub fn suffix(self) -> &'static str {
        match self {
            Variant::Tc => "tc",
            Variant::Cc => "cc",
        }
    }
}

/// C^(n) handling for FastTuckerPlus (§5.6): recompute per batch on the
/// matrix unit, or precompute + read rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Calculation,
    Storage,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "calculation" | "calc" => Some(Strategy::Calculation),
            "storage" | "store" => Some(Strategy::Storage),
            _ => None,
        }
    }
}

/// Execution backend: the PJRT/HLO path (the system under test), the
/// scalar CPU reference (oracle / scalar baseline), or the Hogwild
/// multi-threaded CPU engine (the paper's per-thread FMA path, parallel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Hlo,
    CpuRef,
    ParallelCpu,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "hlo" => Some(Backend::Hlo),
            "cpu" | "cpuref" | "cpu_ref" => Some(Backend::CpuRef),
            "parallel" | "parallelcpu" | "parallel-cpu" | "parallel_cpu" => {
                Some(Backend::ParallelCpu)
            }
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Hlo => "hlo",
            Backend::CpuRef => "cpu_ref",
            Backend::ParallelCpu => "parallel_cpu",
        }
    }
}

/// Full trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub algo: Algo,
    pub variant: Variant,
    pub strategy: Strategy,
    pub backend: Backend,
    pub j: usize,
    pub r: usize,
    pub hyper: Hyper,
    pub seed: u64,
    pub artifact_dir: PathBuf,
    /// Worker threads for the `ParallelCpu` backend's Hogwild block
    /// sharding (0 = auto-detect via `util::pool::default_threads`).
    pub threads: usize,
}

impl TrainConfig {
    /// Whether the HLO backend's compiled artifacts are present under
    /// [`TrainConfig::artifact_dir`] (the manifest the runtime loads).
    /// Examples and tools use this to fall back to a CPU backend from a
    /// clean checkout.
    pub fn hlo_available(&self) -> bool {
        self.artifact_dir.join("manifest.json").exists()
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            algo: Algo::Plus,
            variant: Variant::Tc,
            strategy: Strategy::Calculation,
            backend: Backend::Hlo,
            j: 16,
            r: 16,
            hyper: Hyper::default(),
            seed: 42,
            artifact_dir: PathBuf::from("artifacts"),
            threads: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_enums() {
        assert_eq!(Algo::parse("plus"), Some(Algo::Plus));
        assert_eq!(Algo::parse("fasttucker"), Some(Algo::FastTucker));
        assert_eq!(Algo::parse("x"), None);
        assert_eq!(Variant::parse("tc"), Some(Variant::Tc));
        assert_eq!(Strategy::parse("storage"), Some(Strategy::Storage));
        assert_eq!(Backend::parse("cpu"), Some(Backend::CpuRef));
        assert_eq!(Backend::parse("parallel"), Some(Backend::ParallelCpu));
        // name() round-trips through parse()
        for b in [Backend::Hlo, Backend::CpuRef, Backend::ParallelCpu] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
    }
}
