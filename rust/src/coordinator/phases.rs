//! Backend-independent phase driver: one generic factor phase and one
//! generic core phase for every (algorithm, backend) combination.
//!
//! A phase is a sequence of *passes* (a single all-modes pass for
//! FastTuckerPlus, one pass per tensor mode for the baseline algorithms).
//! Each pass streams staged blocks from the pipelined scheduler
//! ([`StagedStream`]) — sampling and staging of block *k+1* overlap the
//! execution of block *k* on a producer thread — and hands every block to
//! the configured [`StepBackend`].  Core-phase gradients accumulate in a
//! [`CoreAccum`] and are applied once per pass (the paper's
//! accumulate-then-atomicAdd schedule).
//!
//! Timing semantics: `st.sample` records the *exposed* sampling/staging
//! time (the wait on the producer), so a well-pipelined run shows it near
//! zero even though staging work still happens — that differential IS the
//! pipelining win the paper's overlap argument predicts.

use anyhow::Result;

use crate::coordinator::backend::{CoreAccum, Phase, StepBackend};
use crate::coordinator::config::{Algo, TrainConfig};
use crate::coordinator::metrics::{time_into, PhaseStats};
use crate::data::TensorView;
use crate::model::TuckerModel;
use crate::sampler::{BlockIter, StagedStream};
use crate::tensor::{FiberIndex, ModeSliceIndex};

/// Seed salt separating the core phase's sample stream from the factor
/// phase's (kept from the pre-refactor trainer for continuity).
const CORE_SEED_SALT: u64 = 0xC0DE;

/// Pass schedule for one phase: `None` = all-modes (Plus), `Some(m)` = the
/// per-mode passes of the baseline algorithms.
fn schedule(algo: Algo, order: usize) -> Vec<Option<usize>> {
    match algo {
        Algo::Plus => vec![None],
        Algo::FastTucker | Algo::FasterTucker | Algo::FasterTuckerCoo => {
            (0..order).map(Some).collect()
        }
    }
}

/// Block source for one pass of one algorithm.  Generic over the data
/// view: the uniform (Plus) schedule needs only the entry count, so it
/// streams from an out-of-core store; the grouped schedules read the
/// prebuilt in-RAM indexes.
#[allow(clippy::too_many_arguments)]
fn block_iter<'a, T: TensorView + ?Sized>(
    algo: Algo,
    train: &'a T,
    slice_idx: &'a [ModeSliceIndex],
    fiber_idx: &'a [FiberIndex],
    mode: Option<usize>,
    s: usize,
    seed: u64,
    epoch: u64,
) -> BlockIter<'a> {
    match (algo, mode) {
        (Algo::Plus, None) => BlockIter::uniform(train, s, seed, epoch),
        (Algo::FastTucker, Some(m)) => BlockIter::mode_slice(&slice_idx[m], s, seed, epoch),
        (Algo::FasterTucker, Some(m)) => BlockIter::fiber(&fiber_idx[m], s, seed, epoch),
        (Algo::FasterTuckerCoo, Some(m)) => BlockIter::fiber_coo(&fiber_idx[m], s, seed, epoch),
        _ => unreachable!("pass schedule / algorithm mismatch"),
    }
}

/// Run one phase (factor or core) of one epoch.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_phase<T: TensorView + ?Sized>(
    phase: Phase,
    cfg: &TrainConfig,
    backend: &mut dyn StepBackend,
    model: &mut TuckerModel,
    train: &T,
    slice_idx: &[ModeSliceIndex],
    fiber_idx: &[FiberIndex],
    epoch_no: u64,
) -> Result<PhaseStats> {
    let mut st = PhaseStats::default();
    time_into(&mut st.precompute, || backend.refresh_c(model))?;
    let seed = match phase {
        Phase::Factor => cfg.seed,
        Phase::Core => cfg.seed ^ CORE_SEED_SALT,
    };
    let s = backend.block_size(phase);
    for mode in schedule(cfg.algo, train.order()) {
        time_into(&mut st.precompute, || backend.begin_pass(model, phase, mode))?;
        let mut acc = match phase {
            Phase::Core => Some(CoreAccum::new(model, mode)),
            Phase::Factor => None,
        };
        // iterator construction does the O(nnz) shuffle / group ordering, so
        // charge it to the sample bucket like the eager samplers were
        let iter = time_into(&mut st.sample, || {
            block_iter(
                cfg.algo, train, slice_idx, fiber_idx, mode, s, seed, epoch_no,
            )
        });
        std::thread::scope(|scope| -> Result<()> {
            let mut stream = StagedStream::spawn(scope, train, iter);
            while let Some(block) = time_into(&mut st.sample, || stream.next()) {
                match phase {
                    Phase::Factor => backend.run_factor_block(model, &block, mode, &mut st)?,
                    Phase::Core => {
                        let acc = acc.as_mut().expect("core pass has an accumulator");
                        backend.run_core_block(model, &block, mode, acc, &mut st)?;
                        acc.count += block.valid;
                    }
                }
                st.blocks += 1;
                st.samples += block.valid;
                st.padded_slots += block.s - block.valid;
            }
            Ok(())
        })?;
        if let Some(acc) = acc {
            time_into(&mut st.scatter, || {
                acc.apply(model, cfg.hyper.lr_b, cfg.hyper.lam_b)
            });
        }
    }
    Ok(st)
}
