//! The L3 coordinator, split into its three refactored layers:
//!
//! * [`trainer`] — thin driver owning model + indexes + backend;
//! * [`phases`] — generic factor/core phase logic over the streaming
//!   block scheduler (one implementation for every algorithm/backend);
//! * [`backend`] — the pluggable [`backend::StepBackend`] execution layer
//!   (PJRT/HLO, serial CPU oracle, Hogwild parallel CPU);
//!
//! plus [`config`] and [`metrics`].

pub mod backend;
pub mod config;
pub mod metrics;
pub mod phases;
pub mod trainer;

pub use backend::{make_backend, CoreAccum, HloBackend, CpuBackend, Phase, StepBackend};
pub use config::{Algo, Backend, Strategy, TrainConfig, Variant};
pub use metrics::{EpochStats, PhaseStats};
pub use trainer::{tensor_fingerprint, Trainer};
