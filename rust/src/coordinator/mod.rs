//! The L3 coordinator: configuration, training loop, metrics.

pub mod config;
pub mod metrics;
pub mod trainer;

pub use config::{Algo, Backend, Strategy, TrainConfig, Variant};
pub use metrics::{EpochStats, PhaseStats};
pub use trainer::Trainer;
