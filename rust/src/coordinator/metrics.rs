//! Per-epoch instrumentation: wall times of every pipeline stage, block
//! counts, padding waste.  These are the numbers the Table 6/7 and Fig. 2/3
//! benches report, so they are first-class here rather than ad-hoc timers.

use std::time::{Duration, Instant};

use crate::kernel::KernelCounters;
use crate::util::json::{self, Json};

/// Stage timings accumulated over one phase (factor or core) of an epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseStats {
    /// *Exposed* sampling/staging time: the wait on the pipelined block
    /// scheduler's producer thread.  Near zero when the double buffer
    /// fully hides block construction behind execution.
    pub sample: Duration,
    /// host gather of factor / C rows into staging slabs (memory access)
    pub gather: Duration,
    /// PJRT execute (compute)
    pub exec: Duration,
    /// host scatter of results back (memory access)
    pub scatter: Duration,
    /// storage-scheme C precompute
    pub precompute: Duration,
    /// Blocks executed this phase.
    pub blocks: usize,
    /// Valid (non-padding) samples processed.
    pub samples: usize,
    /// Padding slots staged but masked out.
    pub padded_slots: usize,
    /// Invariant-cache hits reported by the storage-scheme kernels.
    pub inv_hits: u64,
    /// Invariant-cache misses (recomputed exclusion products).
    pub inv_misses: u64,
}

impl PhaseStats {
    /// Wall time of the whole phase (sum of all stage buckets).
    pub fn total(&self) -> Duration {
        self.sample + self.gather + self.exec + self.scatter + self.precompute
    }

    /// Host memory-access time (the Table 7 analog: parameter reads+writes).
    pub fn memory(&self) -> Duration {
        self.gather + self.scatter + self.precompute
    }

    /// Padded slots / total slots — the Table-1 load-imbalance analog.
    pub fn padding_ratio(&self) -> f64 {
        let total = self.samples + self.padded_slots;
        if total == 0 {
            0.0
        } else {
            self.padded_slots as f64 / total as f64
        }
    }

    /// Invariant-cache hit rate over this phase's storage-scheme kernel
    /// samples; `None` when no storage-scheme kernel ran (the other
    /// algorithms report no cache traffic).
    pub fn invariant_hit_rate(&self) -> Option<f64> {
        let total = self.inv_hits + self.inv_misses;
        (total > 0).then(|| self.inv_hits as f64 / total as f64)
    }

    /// Fold one kernel range's counters into this phase.
    pub fn add_counters(&mut self, c: KernelCounters) {
        self.inv_hits += c.inv_hits;
        self.inv_misses += c.inv_misses;
    }

    /// Add another phase's counters and timings into this one.
    pub fn merge(&mut self, o: &PhaseStats) {
        self.sample += o.sample;
        self.gather += o.gather;
        self.exec += o.exec;
        self.scatter += o.scatter;
        self.precompute += o.precompute;
        self.blocks += o.blocks;
        self.samples += o.samples;
        self.padded_slots += o.padded_slots;
        self.inv_hits += o.inv_hits;
        self.inv_misses += o.inv_misses;
    }

    /// Serialize for the `BENCH_JSON` scrape lines.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("sample_s", json::num(self.sample.as_secs_f64())),
            ("gather_s", json::num(self.gather.as_secs_f64())),
            ("exec_s", json::num(self.exec.as_secs_f64())),
            ("scatter_s", json::num(self.scatter.as_secs_f64())),
            ("precompute_s", json::num(self.precompute.as_secs_f64())),
            ("total_s", json::num(self.total().as_secs_f64())),
            ("memory_s", json::num(self.memory().as_secs_f64())),
            ("blocks", json::num(self.blocks as f64)),
            ("samples", json::num(self.samples as f64)),
            ("padded_slots", json::num(self.padded_slots as f64)),
            ("padding", json::num(self.padding_ratio())),
        ];
        if let Some(rate) = self.invariant_hit_rate() {
            fields.push(("inv_hit_rate", json::num(rate)));
        }
        json::obj(fields)
    }
}

/// Both phases of one epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochStats {
    /// Factor-phase stage timings.
    pub factor: PhaseStats,
    /// Core-phase stage timings.
    pub core: PhaseStats,
}

impl EpochStats {
    /// Invariant-cache hit rate across both phases; `None` when no
    /// storage-scheme kernel ran this epoch.
    pub fn invariant_hit_rate(&self) -> Option<f64> {
        let hits = self.factor.inv_hits + self.core.inv_hits;
        let total = hits + self.factor.inv_misses + self.core.inv_misses;
        (total > 0).then(|| hits as f64 / total as f64)
    }

    /// Padding-waste ratio across both phases — the paper's Table-1
    /// load-imbalance number for the whole epoch.
    pub fn padding_ratio(&self) -> f64 {
        let samples = self.factor.samples + self.core.samples;
        let padded = self.factor.padded_slots + self.core.padded_slots;
        let total = samples + padded;
        if total == 0 {
            0.0
        } else {
            padded as f64 / total as f64
        }
    }

    /// Serialize both phases for the `BENCH_JSON` scrape lines.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("factor", self.factor.to_json()),
            ("core", self.core.to_json()),
        ])
    }
}

/// Scope timer: `let _t = Timed::new(&mut stats.gather);` — adds elapsed on
/// drop.  (Manual start/stop reads better in the trainer loop, so we also
/// expose `time_into`.)
pub fn time_into<T>(slot: &mut Duration, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    *slot += t0.elapsed();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_into_accumulates() {
        let mut d = Duration::ZERO;
        let v = time_into(&mut d, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(d >= Duration::from_millis(4));
    }

    #[test]
    fn padding_ratio() {
        let s = PhaseStats {
            samples: 75,
            padded_slots: 25,
            ..Default::default()
        };
        assert!((s.padding_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseStats {
            blocks: 2,
            samples: 10,
            ..Default::default()
        };
        let b = PhaseStats {
            blocks: 3,
            samples: 5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.blocks, 5);
        assert_eq!(a.samples, 15);
    }

    #[test]
    fn invariant_hit_rate_counts() {
        let mut s = PhaseStats::default();
        assert_eq!(s.invariant_hit_rate(), None);
        s.add_counters(KernelCounters {
            inv_hits: 3,
            inv_misses: 1,
        });
        s.add_counters(KernelCounters {
            inv_hits: 0,
            inv_misses: 4,
        });
        assert!((s.invariant_hit_rate().unwrap() - 0.375).abs() < 1e-12);
        let e = EpochStats {
            factor: s,
            core: PhaseStats::default(),
        };
        assert!((e.invariant_hit_rate().unwrap() - 0.375).abs() < 1e-12);
        assert!(s.to_json().get("inv_hit_rate").is_some());
        assert!(PhaseStats::default().to_json().get("inv_hit_rate").is_none());
    }

    #[test]
    fn json_shape() {
        let e = EpochStats::default();
        let j = e.to_json();
        assert!(j.get("factor").unwrap().get("exec_s").is_some());
    }
}
