//! The coordinator's training loop — the L3 half of the paper's system.
//!
//! One `Trainer` owns the model state, the sampling indexes, the staging
//! slabs, and (for the HLO backend) the PJRT engine with the compiled
//! kernels for the configured (algo, variant, strategy).  `epoch()` runs the
//! paper's two phases:
//!
//! 1. **factor phase** — update factor matrices (Alg. 4 analog: gather
//!    `A_Ψ` rows, execute the factor kernel, scatter updated rows back);
//! 2. **core phase** — accumulate core-matrix gradients over all blocks and
//!    apply once (Alg. 5 analog: register accumulate + atomicAdd at end).
//!
//! Every stage is timed into [`PhaseStats`] — those numbers ARE the
//! Table 6/7 / Fig. 2/3 measurements.

use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use crate::coordinator::config::{Algo, Backend, Strategy, TrainConfig};
use crate::coordinator::metrics::{time_into, EpochStats, PhaseStats};
use crate::cpu_ref;
use crate::model::TuckerModel;
use crate::runtime::{Engine, Executable};
use crate::sampler::{self, Block, PAD};
use crate::tensor::{FiberIndex, ModeSliceIndex, SparseTensor};

/// Training driver for one tensor + one configuration.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub model: TuckerModel,
    engine: Option<Engine>,
    // compiled kernels (HLO backend)
    factor_exe: Option<Rc<Executable>>,
    core_exe: Option<Rc<Executable>>,
    predict_exe: Option<Rc<Executable>>,
    compute_c_exe: Option<Rc<Executable>>,
    // sampling indexes
    slice_idx: Vec<ModeSliceIndex>,
    fiber_idx: Vec<FiberIndex>,
    // storage-scheme projection tables C^(n) (I_n x R each)
    c_store: Vec<Vec<f32>>,
    // staging slabs, reused across blocks
    buf_a: Vec<f32>,
    buf_c: Vec<f32>,
    buf_x: Vec<f32>,
    buf_cores: Vec<f32>,
    buf_coords: Vec<u32>,
    pub epoch_no: u64,
    train_nnz: usize,
}

impl Trainer {
    /// Build a trainer for `train`.  For the HLO backend this loads and
    /// compiles the artifacts for the configured algorithm.
    pub fn new(train: &SparseTensor, cfg: TrainConfig) -> Result<Trainer> {
        let n = train.order();
        let model =
            TuckerModel::init_with_mean(&train.dims, cfg.j, cfg.r, cfg.seed, train.mean_value());
        let v = cfg.variant.suffix();

        let mut engine = None;
        let (mut factor_exe, mut core_exe, mut predict_exe, mut compute_c_exe) =
            (None, None, None, None);
        if cfg.backend == Backend::Hlo {
            let eng = Engine::new(&cfg.artifact_dir)?;
            let (fk, ck) = match (cfg.algo, cfg.strategy) {
                (Algo::Plus, Strategy::Calculation) => {
                    (format!("plus_factor_{v}"), format!("plus_core_{v}"))
                }
                (Algo::Plus, Strategy::Storage) => (
                    format!("plus_factor_storage_{v}"),
                    format!("plus_core_storage_{v}"),
                ),
                (Algo::FastTucker, _) => (
                    format!("fasttucker_factor_{v}"),
                    format!("fasttucker_core_{v}"),
                ),
                (Algo::FasterTucker | Algo::FasterTuckerCoo, _) => (
                    format!("fastertucker_factor_{v}"),
                    format!("fastertucker_core_{v}"),
                ),
            };
            factor_exe = Some(eng.load(&fk, n, cfg.j, cfg.r)?);
            core_exe = Some(eng.load(&ck, n, cfg.j, cfg.r)?);
            predict_exe = Some(eng.load("predict", n, cfg.j, cfg.r)?);
            if matches!(cfg.algo, Algo::FasterTucker | Algo::FasterTuckerCoo)
                || cfg.strategy == Strategy::Storage
            {
                compute_c_exe = Some(eng.load_any_n("compute_c", cfg.j, cfg.r)?);
            }
            engine = Some(eng);
        }

        let slice_idx = if cfg.algo == Algo::FastTucker {
            (0..n).map(|m| ModeSliceIndex::build(train, m)).collect()
        } else {
            Vec::new()
        };
        let fiber_idx = if matches!(cfg.algo, Algo::FasterTucker | Algo::FasterTuckerCoo) {
            (0..n).map(|m| FiberIndex::build(train, m)).collect()
        } else {
            Vec::new()
        };
        let c_store = train
            .dims
            .iter()
            .map(|&d| vec![0f32; d as usize * cfg.r])
            .collect();

        Ok(Trainer {
            model,
            engine,
            factor_exe,
            core_exe,
            predict_exe,
            compute_c_exe,
            slice_idx,
            fiber_idx,
            c_store,
            buf_a: Vec::new(),
            buf_c: Vec::new(),
            buf_x: Vec::new(),
            buf_cores: vec![0f32; n * cfg.j * cfg.r],
            buf_coords: Vec::new(),
            epoch_no: 0,
            train_nnz: train.nnz(),
            cfg,
        })
    }

    /// Run one full iteration (factor phase + core phase) over `train`.
    pub fn epoch(&mut self, train: &SparseTensor) -> Result<EpochStats> {
        ensure!(
            train.nnz() == self.train_nnz,
            "epoch() must receive the tensor the trainer was built for"
        );
        let factor = self.factor_phase(train)?;
        let core = self.core_phase(train)?;
        self.epoch_no += 1;
        Ok(EpochStats { factor, core })
    }

    /// Factor-matrix update phase only (Table 6a measures this in isolation).
    pub fn factor_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        match self.cfg.backend {
            Backend::CpuRef => self.cpu_factor_phase(train),
            Backend::Hlo => match self.cfg.algo {
                Algo::Plus => self.plus_factor_phase(train),
                Algo::FastTucker => self.fasttucker_factor_phase(train),
                Algo::FasterTucker | Algo::FasterTuckerCoo => {
                    self.fastertucker_factor_phase(train)
                }
            },
        }
    }

    /// Core-matrix update phase only (Table 6b).
    pub fn core_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        match self.cfg.backend {
            Backend::CpuRef => self.cpu_core_phase(train),
            Backend::Hlo => match self.cfg.algo {
                Algo::Plus => self.plus_core_phase(train),
                Algo::FastTucker => self.fasttucker_core_phase(train),
                Algo::FasterTucker | Algo::FasterTuckerCoo => {
                    self.fastertucker_core_phase(train)
                }
            },
        }
    }

    // -- block staging ------------------------------------------------------

    /// Materialize a block: coords slab (valid x N) + padded value slab [S].
    fn stage_block(&mut self, train: &SparseTensor, block: &Block, s: usize) {
        let n = train.order();
        self.buf_coords.clear();
        self.buf_x.clear();
        self.buf_x.resize(s, 0.0);
        let mut slot = 0usize;
        for &id in &block.ids {
            if id == PAD {
                continue;
            }
            // compact valid entries to the front; kernels are per-slot so
            // reordering within a block is sound for uniform sampling, and
            // grouped samplers only pad at warp tails (order preserved).
            self.buf_coords.extend_from_slice(train.coords(id as usize));
            self.buf_x[slot] = train.values[id as usize];
            slot += 1;
        }
        debug_assert_eq!(slot, block.valid);
        let _ = n;
    }

    fn hp_factor(&self) -> [f32; 2] {
        [self.cfg.hyper.lr_a, self.cfg.hyper.lam_a]
    }

    /// Refresh the storage-scheme projection tables C^(n) = A^(n) B^(n)
    /// through the `compute_c` executable, in row chunks of the artifact's S.
    fn refresh_c_store(&mut self) -> Result<()> {
        let exe = self
            .compute_c_exe
            .clone()
            .context("compute_c executable not loaded")?;
        let chunk = exe.info.s;
        let (j, r) = (self.cfg.j, self.cfg.r);
        let n = self.model.order();
        let mut a_chunk = vec![0f32; chunk * j];
        for m in 0..n {
            let rows = self.model.dims[m] as usize;
            let fm = &self.model.factors[m];
            let b = &self.model.cores[m];
            let cs = &mut self.c_store[m];
            let mut lo = 0usize;
            while lo < rows {
                let hi = (lo + chunk).min(rows);
                let len = hi - lo;
                a_chunk[..len * j].copy_from_slice(&fm[lo * j..hi * j]);
                a_chunk[len * j..].fill(0.0);
                let out = exe.run(&[&a_chunk, b])?;
                cs[lo * r..hi * r].copy_from_slice(&out[0][..len * r]);
                lo = hi;
            }
        }
        Ok(())
    }

    /// Gather stored C rows for a block into `[K, S, R]` where mode `k` of
    /// the output corresponds to tensor mode `mode_of(k)`.
    fn gather_c_rows(
        &self,
        out: &mut [f32],
        coords: &[u32],
        valid: usize,
        s: usize,
        modes: &[usize],
    ) {
        let n = self.model.order();
        let r = self.cfg.r;
        for (k, &m) in modes.iter().enumerate() {
            let dst = &mut out[k * s * r..(k + 1) * s * r];
            let src = &self.c_store[m];
            for e in 0..valid {
                let row = coords[e * n + m] as usize;
                dst[e * r..(e + 1) * r].copy_from_slice(&src[row * r..(row + 1) * r]);
            }
            dst[valid * r..].fill(0.0);
        }
    }

    // -- FastTuckerPlus (Algorithm 3) ---------------------------------------

    fn plus_factor_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let exe = self.factor_exe.clone().unwrap();
        let s = exe.info.s;
        let n = train.order();
        let (j, r) = (self.cfg.j, self.cfg.r);
        let mut st = PhaseStats::default();
        let storage = self.cfg.strategy == Strategy::Storage;
        if storage {
            time_into(&mut st.precompute, || self.refresh_c_store())?;
        }
        let blocks = time_into(&mut st.sample, || {
            sampler::uniform_blocks(train, s, self.cfg.seed, self.epoch_no)
        });
        self.model.pack_cores(&mut self.buf_cores);
        let hp = self.hp_factor();
        self.buf_a.resize(n * s * j, 0.0);
        if storage {
            self.buf_c.resize(n * s * r, 0.0);
        }
        let all_modes: Vec<usize> = (0..n).collect();
        for block in &blocks {
            self.stage_block(train, block, s);
            time_into(&mut st.gather, || {
                self.model
                    .gather_batch(&self.buf_coords, block.valid, &mut self.buf_a);
            });
            let out = time_into(&mut st.exec, || {
                if storage {
                    let coords = &self.buf_coords;
                    // gather_c_rows borrows &self; split via local copy of refs
                    let mut c = std::mem::take(&mut self.buf_c);
                    self.gather_c_rows(&mut c, coords, block.valid, s, &all_modes);
                    let res = exe.run(&[&self.buf_a, &c, &self.buf_cores, &self.buf_x, &hp]);
                    self.buf_c = c;
                    res
                } else {
                    exe.run(&[&self.buf_a, &self.buf_cores, &self.buf_x, &hp])
                }
            })?;
            time_into(&mut st.scatter, || {
                self.model
                    .scatter_batch(&self.buf_coords, block.valid, &out[0]);
            });
            st.blocks += 1;
            st.samples += block.valid;
            st.padded_slots += s - block.valid;
        }
        Ok(st)
    }

    fn plus_core_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let exe = self.core_exe.clone().unwrap();
        let s = exe.info.s;
        let n = train.order();
        let (j, r) = (self.cfg.j, self.cfg.r);
        let mut st = PhaseStats::default();
        let storage = self.cfg.strategy == Strategy::Storage;
        if storage {
            time_into(&mut st.precompute, || self.refresh_c_store())?;
        }
        let blocks = time_into(&mut st.sample, || {
            sampler::uniform_blocks(train, s, self.cfg.seed ^ 0xC0DE, self.epoch_no)
        });
        self.model.pack_cores(&mut self.buf_cores);
        self.buf_a.resize(n * s * j, 0.0);
        if storage {
            self.buf_c.resize(n * s * r, 0.0);
        }
        let mut grad = vec![0f32; n * j * r];
        let all_modes: Vec<usize> = (0..n).collect();
        for block in &blocks {
            self.stage_block(train, block, s);
            time_into(&mut st.gather, || {
                self.model
                    .gather_batch(&self.buf_coords, block.valid, &mut self.buf_a);
            });
            let out = time_into(&mut st.exec, || {
                if storage {
                    let mut c = std::mem::take(&mut self.buf_c);
                    self.gather_c_rows(&mut c, &self.buf_coords, block.valid, s, &all_modes);
                    let res = exe.run(&[&self.buf_a, &c, &self.buf_x]);
                    self.buf_c = c;
                    res
                } else {
                    exe.run(&[&self.buf_a, &self.buf_cores, &self.buf_x])
                }
            })?;
            time_into(&mut st.scatter, || {
                for (g, &v) in grad.iter_mut().zip(out[0].iter()) {
                    *g += v;
                }
            });
            st.blocks += 1;
            st.samples += block.valid;
            st.padded_slots += s - block.valid;
        }
        time_into(&mut st.scatter, || {
            self.model
                .apply_core_grad(&grad, st.samples, self.cfg.hyper.lr_b, self.cfg.hyper.lam_b);
        });
        Ok(st)
    }

    // -- FastTucker (Algorithm 1) -------------------------------------------

    fn fasttucker_factor_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let exe = self.factor_exe.clone().unwrap();
        let s = exe.info.s;
        let n = train.order();
        let j = self.cfg.j;
        let mut st = PhaseStats::default();
        self.buf_a.resize(n * s * j, 0.0);
        let hp = self.hp_factor();
        for mode in 0..n {
            let blocks = time_into(&mut st.sample, || {
                sampler::mode_slice_blocks(&self.slice_idx[mode], s, self.cfg.seed, self.epoch_no)
            });
            self.model.pack_cores_rotated(mode, &mut self.buf_cores);
            for block in &blocks {
                self.stage_block(train, block, s);
                time_into(&mut st.gather, || {
                    self.model.gather_batch_rotated(
                        &self.buf_coords,
                        block.valid,
                        mode,
                        &mut self.buf_a,
                    );
                });
                let out = time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_cores, &self.buf_x, &hp])
                })?;
                time_into(&mut st.scatter, || {
                    self.model
                        .scatter_mode_rows(mode, &self.buf_coords, block.valid, &out[0]);
                });
                st.blocks += 1;
                st.samples += block.valid;
                st.padded_slots += s - block.valid;
            }
        }
        Ok(st)
    }

    fn fasttucker_core_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let exe = self.core_exe.clone().unwrap();
        let s = exe.info.s;
        let n = train.order();
        let (j, r) = (self.cfg.j, self.cfg.r);
        let mut st = PhaseStats::default();
        self.buf_a.resize(n * s * j, 0.0);
        for mode in 0..n {
            let blocks = time_into(&mut st.sample, || {
                sampler::mode_slice_blocks(
                    &self.slice_idx[mode],
                    s,
                    self.cfg.seed ^ 0xC0DE,
                    self.epoch_no,
                )
            });
            self.model.pack_cores_rotated(mode, &mut self.buf_cores);
            let mut grad = vec![0f32; j * r];
            let mut count = 0usize;
            for block in &blocks {
                self.stage_block(train, block, s);
                time_into(&mut st.gather, || {
                    self.model.gather_batch_rotated(
                        &self.buf_coords,
                        block.valid,
                        mode,
                        &mut self.buf_a,
                    );
                });
                let out = time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_cores, &self.buf_x])
                })?;
                time_into(&mut st.scatter, || {
                    for (g, &v) in grad.iter_mut().zip(out[0].iter()) {
                        *g += v;
                    }
                });
                st.blocks += 1;
                st.samples += block.valid;
                st.padded_slots += s - block.valid;
                count += block.valid;
            }
            time_into(&mut st.scatter, || {
                self.model.apply_core_grad_mode(
                    mode,
                    &grad,
                    count,
                    self.cfg.hyper.lr_b,
                    self.cfg.hyper.lam_b,
                );
            });
        }
        Ok(st)
    }

    // -- FasterTucker (Algorithm 2) -----------------------------------------

    fn fastertucker_factor_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let exe = self.factor_exe.clone().unwrap();
        let s = exe.info.s;
        let n = train.order();
        let (j, r) = (self.cfg.j, self.cfg.r);
        let mut st = PhaseStats::default();
        // Alg. 2 line 2: calculate and store C^(n).
        time_into(&mut st.precompute, || self.refresh_c_store())?;
        self.buf_a.resize(s * j, 0.0);
        self.buf_c.resize((n - 1) * s * r, 0.0);
        let hp = self.hp_factor();
        for mode in 0..n {
            let blocks = time_into(&mut st.sample, || {
                if self.cfg.algo == Algo::FasterTuckerCoo {
                    sampler::fiber_blocks_coo(&self.fiber_idx[mode], s, self.cfg.seed, self.epoch_no)
                } else {
                    sampler::fiber_blocks(&self.fiber_idx[mode], s, self.cfg.seed, self.epoch_no)
                }
            });
            let other_modes: Vec<usize> = (1..n).map(|k| (mode + k) % n).collect();
            let b0 = self.model.cores[mode].clone();
            for block in &blocks {
                self.stage_block(train, block, s);
                time_into(&mut st.gather, || {
                    self.model.gather_mode_rows(
                        mode,
                        &self.buf_coords,
                        block.valid,
                        &mut self.buf_a,
                    );
                    let mut c = std::mem::take(&mut self.buf_c);
                    self.gather_c_rows(&mut c, &self.buf_coords, block.valid, s, &other_modes);
                    self.buf_c = c;
                });
                let out = time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_c, &b0, &self.buf_x, &hp])
                })?;
                time_into(&mut st.scatter, || {
                    self.model
                        .scatter_mode_rows(mode, &self.buf_coords, block.valid, &out[0]);
                    // Alg. 2 line 13: refresh stored C rows of the updated mode.
                    let cs = &mut self.c_store[mode];
                    for e in 0..block.valid {
                        let row = self.buf_coords[e * n + mode] as usize;
                        cs[row * r..(row + 1) * r]
                            .copy_from_slice(&out[1][e * r..(e + 1) * r]);
                    }
                });
                st.blocks += 1;
                st.samples += block.valid;
                st.padded_slots += s - block.valid;
            }
        }
        Ok(st)
    }

    fn fastertucker_core_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let exe = self.core_exe.clone().unwrap();
        let s = exe.info.s;
        let n = train.order();
        let (j, r) = (self.cfg.j, self.cfg.r);
        let mut st = PhaseStats::default();
        time_into(&mut st.precompute, || self.refresh_c_store())?;
        self.buf_a.resize(s * j, 0.0);
        self.buf_c.resize((n - 1) * s * r, 0.0);
        for mode in 0..n {
            let blocks = time_into(&mut st.sample, || {
                if self.cfg.algo == Algo::FasterTuckerCoo {
                    sampler::fiber_blocks_coo(
                        &self.fiber_idx[mode],
                        s,
                        self.cfg.seed ^ 0xC0DE,
                        self.epoch_no,
                    )
                } else {
                    sampler::fiber_blocks(
                        &self.fiber_idx[mode],
                        s,
                        self.cfg.seed ^ 0xC0DE,
                        self.epoch_no,
                    )
                }
            });
            let other_modes: Vec<usize> = (1..n).map(|k| (mode + k) % n).collect();
            let b0 = self.model.cores[mode].clone();
            let mut grad = vec![0f32; j * r];
            let mut count = 0usize;
            for block in &blocks {
                self.stage_block(train, block, s);
                time_into(&mut st.gather, || {
                    self.model.gather_mode_rows(
                        mode,
                        &self.buf_coords,
                        block.valid,
                        &mut self.buf_a,
                    );
                    let mut c = std::mem::take(&mut self.buf_c);
                    self.gather_c_rows(&mut c, &self.buf_coords, block.valid, s, &other_modes);
                    self.buf_c = c;
                });
                let out = time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_c, &b0, &self.buf_x])
                })?;
                time_into(&mut st.scatter, || {
                    for (g, &v) in grad.iter_mut().zip(out[0].iter()) {
                        *g += v;
                    }
                });
                st.blocks += 1;
                st.samples += block.valid;
                st.padded_slots += s - block.valid;
                count += block.valid;
            }
            time_into(&mut st.scatter, || {
                self.model.apply_core_grad_mode(
                    mode,
                    &grad,
                    count,
                    self.cfg.hyper.lr_b,
                    self.cfg.hyper.lam_b,
                );
            });
        }
        Ok(st)
    }

    // -- CPU reference backend ----------------------------------------------

    fn cpu_factor_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let mut st = PhaseStats::default();
        let hp = self.cfg.hyper;
        time_into(&mut st.exec, || match self.cfg.algo {
            Algo::Plus => {
                let order = cpu_ref::epoch_order(train.nnz(), self.cfg.seed, self.epoch_no);
                cpu_ref::plus_factor_pass(&mut self.model, train, &order, hp);
            }
            Algo::FastTucker => {
                if self.slice_idx.is_empty() {
                    self.slice_idx = (0..train.order())
                        .map(|m| ModeSliceIndex::build(train, m))
                        .collect();
                }
                cpu_ref::fasttucker_factor_pass(&mut self.model, train, &self.slice_idx, hp);
            }
            Algo::FasterTucker | Algo::FasterTuckerCoo => {
                if self.fiber_idx.is_empty() {
                    self.fiber_idx = (0..train.order())
                        .map(|m| FiberIndex::build(train, m))
                        .collect();
                }
                cpu_ref::fastertucker_factor_pass(&mut self.model, train, &self.fiber_idx, hp);
            }
        });
        st.samples = train.nnz();
        Ok(st)
    }

    fn cpu_core_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        let mut st = PhaseStats::default();
        let hp = self.cfg.hyper;
        time_into(&mut st.exec, || match self.cfg.algo {
            Algo::Plus => {
                let order =
                    cpu_ref::epoch_order(train.nnz(), self.cfg.seed ^ 0xC0DE, self.epoch_no);
                cpu_ref::plus_core_pass(&mut self.model, train, &order, hp);
            }
            Algo::FastTucker => cpu_ref::fasttucker_core_pass(&mut self.model, train, hp),
            Algo::FasterTucker | Algo::FasterTuckerCoo => {
                cpu_ref::fastertucker_core_pass(&mut self.model, train, &self.fiber_idx, hp)
            }
        });
        st.samples = train.nnz();
        Ok(st)
    }

    // -- evaluation -----------------------------------------------------------

    /// RMSE and MAE on a held-out tensor.  Uses the `predict` artifact on the
    /// HLO backend (batched), the scalar path otherwise.
    pub fn evaluate(&mut self, test: &SparseTensor) -> Result<(f64, f64)> {
        match (&self.predict_exe, self.cfg.backend) {
            (Some(exe), Backend::Hlo) => {
                let exe = exe.clone();
                let s = exe.info.s;
                let n = test.order();
                let j = self.cfg.j;
                self.model.pack_cores(&mut self.buf_cores);
                self.buf_a.resize(n * s * j, 0.0);
                let mut sse = 0f64;
                let mut sae = 0f64;
                let ids: Vec<u32> = (0..test.nnz() as u32).collect();
                for chunk in ids.chunks(s) {
                    let block = Block {
                        ids: {
                            let mut v = chunk.to_vec();
                            v.resize(s, PAD);
                            v
                        },
                        valid: chunk.len(),
                    };
                    self.stage_block(test, &block, s);
                    self.model
                        .gather_batch(&self.buf_coords, block.valid, &mut self.buf_a);
                    let out = exe.run(&[&self.buf_a, &self.buf_cores])?;
                    for e in 0..block.valid {
                        let err = (self.buf_x[e] - out[0][e]) as f64;
                        sse += err * err;
                        sae += err.abs();
                    }
                }
                let cnt = test.nnz().max(1) as f64;
                Ok(((sse / cnt).sqrt(), sae / cnt))
            }
            _ => Ok(cpu_ref::evaluate(&self.model, test)),
        }
    }

    /// Platform string of the runtime (for logs).
    pub fn platform(&self) -> String {
        self.engine
            .as_ref()
            .map(|e| e.platform())
            .unwrap_or_else(|| "cpu_ref".to_string())
    }
}
