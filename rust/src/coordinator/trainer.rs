//! The coordinator's training driver — the L3 top of the paper's system.
//!
//! After the backend refactor the `Trainer` is deliberately thin: it owns
//! the model, the sampling indexes and a boxed [`StepBackend`], and
//! delegates both phases of `epoch()` to the generic phase driver in
//! [`crate::coordinator::phases`].  All backend- and algorithm-specific
//! execution lives behind the [`StepBackend`] trait
//! ([`crate::coordinator::backend`]); all scheduling (pass structure,
//! pipelined block streaming, gradient application) lives in the phase
//! driver.  The per-epoch [`EpochStats`] remain the Table 6/7 and
//! Fig. 2/3 measurements.

use anyhow::{bail, ensure, Result};

use crate::coordinator::backend::{self, Phase, StepBackend};
use crate::coordinator::config::{Algo, TrainConfig};
use crate::coordinator::metrics::{EpochStats, PhaseStats};
use crate::coordinator::phases;
use crate::cpu_ref;
use crate::data::TensorView;
use crate::model::TuckerModel;
use crate::serve::{ModelSnapshot, Server};
use crate::tensor::{FiberIndex, ModeSliceIndex, SparseTensor};
use crate::util::fnv::{FNV_OFFSET, FNV_PRIME};

/// Cheap structural fingerprint of a tensor: dims + nnz + first/last entry
/// (coords and value bits), FNV-1a mixed.  `epoch()` uses it to reject a
/// *different* tensor of the same size — the nnz-only check it replaces
/// accepted any same-cardinality impostor.  Generic over [`TensorView`]:
/// the in-RAM tensor and the paged store view of the same data fingerprint
/// identically (a paged view reads at most two pages here).
pub fn tensor_fingerprint<T: TensorView + ?Sized>(t: &T) -> u64 {
    fn mix(h: &mut u64, x: u64) {
        *h ^= x;
        *h = h.wrapping_mul(FNV_PRIME);
    }
    let mut h = FNV_OFFSET;
    mix(&mut h, t.order() as u64);
    for &d in t.dims() {
        mix(&mut h, d as u64);
    }
    mix(&mut h, t.nnz() as u64);
    if t.nnz() > 0 {
        let mut coords = vec![0u32; t.order()];
        let first = t.load_entry(0, &mut coords);
        for &c in &coords {
            mix(&mut h, c as u64);
        }
        let last = t.load_entry(t.nnz() - 1, &mut coords);
        for &c in &coords {
            mix(&mut h, c as u64);
        }
        mix(&mut h, first.to_bits() as u64);
        mix(&mut h, last.to_bits() as u64);
    }
    h
}

/// Training driver for one tensor + one configuration.
pub struct Trainer {
    /// The run configuration this trainer was built with.
    pub cfg: TrainConfig,
    /// The decomposition being fit (readable between epochs, e.g. for
    /// checkpointing or serving).
    pub model: TuckerModel,
    backend: Box<dyn StepBackend>,
    // sampling indexes (built per the algorithm's Table-3 strategy)
    slice_idx: Vec<ModeSliceIndex>,
    fiber_idx: Vec<FiberIndex>,
    /// Epochs completed so far (drives the per-epoch sampling streams).
    pub epoch_no: u64,
    fingerprint: u64,
}

impl Trainer {
    /// Build a trainer for `train`.  For the HLO backend this loads and
    /// compiles the artifacts for the configured algorithm; the CPU
    /// backends need no artifacts.
    ///
    /// Generic over [`TensorView`]: an in-RAM [`crate::tensor::SparseTensor`]
    /// works for every algorithm; an out-of-core view (e.g.
    /// [`crate::data::PagedTensor`]) works for [`Algo::Plus`], whose
    /// uniform sampling needs no per-mode index — the baseline algorithms'
    /// mode-slice/fiber indexes hold O(nnz) entry lists in RAM, which is
    /// exactly what an out-of-core run avoids, so those reject paged
    /// sources with an error.
    pub fn new<T: TensorView + ?Sized>(train: &T, cfg: TrainConfig) -> Result<Trainer> {
        // block ids are u32 with u32::MAX as the PAD sentinel; reject
        // larger tensors here so the samplers never silently wrap (an
        // FTB2 store can carry a u64 nnz)
        ensure!(
            train.nnz() < u32::MAX as usize,
            "tensor has {} entries; the block samplers address at most 2^32 - 2 \
             (shard the store first)",
            train.nnz()
        );
        let dims = train.dims().to_vec();
        let n = dims.len();
        let mean = train.mean_value();
        let model = TuckerModel::init_with_mean(&dims, cfg.j, cfg.r, cfg.seed, mean);
        let backend = backend::make_backend(&dims, &cfg)?;
        let sparse = train.as_sparse();
        if cfg.algo != Algo::Plus && sparse.is_none() {
            bail!(
                "algorithm {} samples through per-mode indexes, which need the tensor \
                 in RAM; out-of-core stores support the 'plus' algorithm",
                cfg.algo.name()
            );
        }
        let slice_idx = if cfg.algo == Algo::FastTucker {
            let t = sparse.expect("checked above");
            (0..n).map(|m| ModeSliceIndex::build(t, m)).collect()
        } else {
            Vec::new()
        };
        let fiber_idx = if matches!(cfg.algo, Algo::FasterTucker | Algo::FasterTuckerCoo) {
            let t = sparse.expect("checked above");
            (0..n).map(|m| FiberIndex::build(t, m)).collect()
        } else {
            Vec::new()
        };
        Ok(Trainer {
            model,
            backend,
            slice_idx,
            fiber_idx,
            epoch_no: 0,
            fingerprint: tensor_fingerprint(train),
            cfg,
        })
    }

    /// Build a trainer around an existing model instead of a fresh
    /// mean-seeded init.  The distributed worker loop
    /// ([`crate::dist::worker`]) uses this to resume each round from the
    /// coordinator's averaged model; everything else (backend build,
    /// index policy, fingerprint pinning) matches [`Trainer::new`].
    pub fn with_model<T: TensorView + ?Sized>(
        train: &T,
        cfg: TrainConfig,
        model: TuckerModel,
    ) -> Result<Trainer> {
        ensure!(
            train.nnz() < u32::MAX as usize,
            "tensor has {} entries; the block samplers address at most 2^32 - 2 \
             (shard the store first)",
            train.nnz()
        );
        ensure!(
            model.dims == train.dims(),
            "model dims {:?} do not match tensor dims {:?}",
            model.dims,
            train.dims()
        );
        ensure!(
            model.j == cfg.j && model.r == cfg.r,
            "model ranks (J={}, R={}) do not match config (J={}, R={})",
            model.j,
            model.r,
            cfg.j,
            cfg.r
        );
        // the worker loop trains shards through ShardView, which never
        // exposes an in-RAM tensor, so the index-building algorithms are
        // structurally unsupported here
        ensure!(
            cfg.algo == Algo::Plus,
            "with_model() is used by the sharded worker loop, which supports the \
             'plus' algorithm only (got {})",
            cfg.algo.name()
        );
        let dims = train.dims().to_vec();
        let backend = backend::make_backend(&dims, &cfg)?;
        Ok(Trainer {
            model,
            backend,
            slice_idx: Vec::new(),
            fiber_idx: Vec::new(),
            epoch_no: 0,
            fingerprint: tensor_fingerprint(train),
            cfg,
        })
    }

    /// Run one full iteration (factor phase + core phase) over `train`.
    pub fn epoch<T: TensorView + ?Sized>(&mut self, train: &T) -> Result<EpochStats> {
        ensure!(
            tensor_fingerprint(train) == self.fingerprint,
            "epoch() must receive the tensor the trainer was built for"
        );
        let factor = self.factor_phase(train)?;
        let core = self.core_phase(train)?;
        self.epoch_no += 1;
        Ok(EpochStats { factor, core })
    }

    /// Factor-matrix update phase only (Table 6a measures this in isolation).
    pub fn factor_phase<T: TensorView + ?Sized>(&mut self, train: &T) -> Result<PhaseStats> {
        phases::run_phase(
            Phase::Factor,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.model,
            train,
            &self.slice_idx,
            &self.fiber_idx,
            self.epoch_no,
        )
    }

    /// Core-matrix update phase only (Table 6b).
    pub fn core_phase<T: TensorView + ?Sized>(&mut self, train: &T) -> Result<PhaseStats> {
        phases::run_phase(
            Phase::Core,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.model,
            train,
            &self.slice_idx,
            &self.fiber_idx,
            self.epoch_no,
        )
    }

    /// RMSE and MAE on a held-out tensor.  Uses the backend's batched
    /// predict kernel when it has one, the scalar path otherwise.
    pub fn evaluate(&mut self, test: &SparseTensor) -> Result<(f64, f64)> {
        match self.backend.predict_batch(&self.model, test)? {
            Some(rmse_mae) => Ok(rmse_mae),
            None => Ok(cpu_ref::evaluate(&self.model, test)),
        }
    }

    /// Platform string of the runtime (for logs).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Replace the SGD hyper-parameters for subsequent epochs.  Backends
    /// capture a copy of the hypers at construction, so the session
    /// layer's learning-rate decay must go through this (rather than
    /// mutating `cfg.hyper` directly) for the change to reach the kernels.
    pub fn set_hyper(&mut self, hyper: cpu_ref::Hyper) {
        self.cfg.hyper = hyper;
        self.backend.set_hyper(hyper);
    }

    /// Freeze the current model into an immutable, epoch-tagged serving
    /// snapshot (factors, cores and precomputed projection tables).
    pub fn snapshot(&self) -> ModelSnapshot {
        ModelSnapshot::from_model(&self.model, self.cfg.algo, self.epoch_no)
    }

    /// Publish the current model to a running serve loop: hot-swaps the
    /// server's snapshot while in-flight queries keep reading the old one,
    /// so training and serving proceed concurrently.
    pub fn publish(&self, server: &Server) {
        server.publish(self.snapshot());
    }
}
