//! The coordinator's training driver — the L3 top of the paper's system.
//!
//! After the backend refactor the `Trainer` is deliberately thin: it owns
//! the model, the sampling indexes and a boxed [`StepBackend`], and
//! delegates both phases of `epoch()` to the generic phase driver in
//! [`crate::coordinator::phases`].  All backend- and algorithm-specific
//! execution lives behind the [`StepBackend`] trait
//! ([`crate::coordinator::backend`]); all scheduling (pass structure,
//! pipelined block streaming, gradient application) lives in the phase
//! driver.  The per-epoch [`EpochStats`] remain the Table 6/7 and
//! Fig. 2/3 measurements.

use anyhow::{ensure, Result};

use crate::coordinator::backend::{self, Phase, StepBackend};
use crate::coordinator::config::{Algo, TrainConfig};
use crate::coordinator::metrics::{EpochStats, PhaseStats};
use crate::coordinator::phases;
use crate::cpu_ref;
use crate::model::TuckerModel;
use crate::serve::{ModelSnapshot, Server};
use crate::tensor::{FiberIndex, ModeSliceIndex, SparseTensor};

/// Cheap structural fingerprint of a tensor: dims + nnz + first/last entry
/// (coords and value bits), FNV-1a mixed.  `epoch()` uses it to reject a
/// *different* tensor of the same size — the nnz-only check it replaces
/// accepted any same-cardinality impostor.
pub fn tensor_fingerprint(t: &SparseTensor) -> u64 {
    fn mix(h: &mut u64, x: u64) {
        *h ^= x;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    mix(&mut h, t.order() as u64);
    for &d in &t.dims {
        mix(&mut h, d as u64);
    }
    mix(&mut h, t.nnz() as u64);
    if t.nnz() > 0 {
        for &c in t.coords(0) {
            mix(&mut h, c as u64);
        }
        for &c in t.coords(t.nnz() - 1) {
            mix(&mut h, c as u64);
        }
        mix(&mut h, t.values[0].to_bits() as u64);
        mix(&mut h, t.values[t.nnz() - 1].to_bits() as u64);
    }
    h
}

/// Training driver for one tensor + one configuration.
pub struct Trainer {
    /// The run configuration this trainer was built with.
    pub cfg: TrainConfig,
    /// The decomposition being fit (readable between epochs, e.g. for
    /// checkpointing or serving).
    pub model: TuckerModel,
    backend: Box<dyn StepBackend>,
    // sampling indexes (built per the algorithm's Table-3 strategy)
    slice_idx: Vec<ModeSliceIndex>,
    fiber_idx: Vec<FiberIndex>,
    /// Epochs completed so far (drives the per-epoch sampling streams).
    pub epoch_no: u64,
    fingerprint: u64,
}

impl Trainer {
    /// Build a trainer for `train`.  For the HLO backend this loads and
    /// compiles the artifacts for the configured algorithm; the CPU
    /// backends need no artifacts.
    pub fn new(train: &SparseTensor, cfg: TrainConfig) -> Result<Trainer> {
        let n = train.order();
        let model =
            TuckerModel::init_with_mean(&train.dims, cfg.j, cfg.r, cfg.seed, train.mean_value());
        let backend = backend::make_backend(train, &cfg)?;
        let slice_idx = if cfg.algo == Algo::FastTucker {
            (0..n).map(|m| ModeSliceIndex::build(train, m)).collect()
        } else {
            Vec::new()
        };
        let fiber_idx = if matches!(cfg.algo, Algo::FasterTucker | Algo::FasterTuckerCoo) {
            (0..n).map(|m| FiberIndex::build(train, m)).collect()
        } else {
            Vec::new()
        };
        Ok(Trainer {
            model,
            backend,
            slice_idx,
            fiber_idx,
            epoch_no: 0,
            fingerprint: tensor_fingerprint(train),
            cfg,
        })
    }

    /// Run one full iteration (factor phase + core phase) over `train`.
    pub fn epoch(&mut self, train: &SparseTensor) -> Result<EpochStats> {
        ensure!(
            tensor_fingerprint(train) == self.fingerprint,
            "epoch() must receive the tensor the trainer was built for"
        );
        let factor = self.factor_phase(train)?;
        let core = self.core_phase(train)?;
        self.epoch_no += 1;
        Ok(EpochStats { factor, core })
    }

    /// Factor-matrix update phase only (Table 6a measures this in isolation).
    pub fn factor_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        phases::run_phase(
            Phase::Factor,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.model,
            train,
            &self.slice_idx,
            &self.fiber_idx,
            self.epoch_no,
        )
    }

    /// Core-matrix update phase only (Table 6b).
    pub fn core_phase(&mut self, train: &SparseTensor) -> Result<PhaseStats> {
        phases::run_phase(
            Phase::Core,
            &self.cfg,
            self.backend.as_mut(),
            &mut self.model,
            train,
            &self.slice_idx,
            &self.fiber_idx,
            self.epoch_no,
        )
    }

    /// RMSE and MAE on a held-out tensor.  Uses the backend's batched
    /// predict kernel when it has one, the scalar path otherwise.
    pub fn evaluate(&mut self, test: &SparseTensor) -> Result<(f64, f64)> {
        match self.backend.predict_batch(&self.model, test)? {
            Some(rmse_mae) => Ok(rmse_mae),
            None => Ok(cpu_ref::evaluate(&self.model, test)),
        }
    }

    /// Platform string of the runtime (for logs).
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Replace the SGD hyper-parameters for subsequent epochs.  Backends
    /// capture a copy of the hypers at construction, so the session
    /// layer's learning-rate decay must go through this (rather than
    /// mutating `cfg.hyper` directly) for the change to reach the kernels.
    pub fn set_hyper(&mut self, hyper: cpu_ref::Hyper) {
        self.cfg.hyper = hyper;
        self.backend.set_hyper(hyper);
    }

    /// Freeze the current model into an immutable, epoch-tagged serving
    /// snapshot (factors, cores and precomputed projection tables).
    pub fn snapshot(&self) -> ModelSnapshot {
        ModelSnapshot::from_model(&self.model, self.cfg.algo, self.epoch_no)
    }

    /// Publish the current model to a running serve loop: hot-swaps the
    /// server's snapshot while in-flight queries keep reading the old one,
    /// so training and serving proceed concurrently.
    pub fn publish(&self, server: &Server) {
        server.publish(self.snapshot());
    }
}
