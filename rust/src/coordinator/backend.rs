//! Pluggable execution backends — the layer between the generic phase
//! driver ([`crate::coordinator::phases`]) and the kernels.
//!
//! A [`StepBackend`] executes *staged blocks*; everything above it
//! (sampling schedule, streaming/staging, pass structure, gradient
//! application, stats accounting) is backend-independent and lives in the
//! phase driver.  Implementations:
//!
//! * [`HloBackend`] — the system under test: compiled PJRT/HLO artifacts
//!   (L1 Pallas kernels lowered through L2), plus the storage-scheme
//!   projection tables and the staging slabs they need.
//! * [`CpuBackend`] — the scalar path.  With `workers = 1` it is the
//!   sequential `cpu_ref` oracle (`Backend::CpuRef`); with `workers > 1`
//!   it is the `Backend::ParallelCpu` Hogwild engine: block slots are
//!   sharded across `std::thread` workers which scatter factor rows
//!   lock-free through [`SharedFactors`] (the paper's per-thread FMA
//!   analog, finally parallel).
//!
//! Both run the identical block schedule, so backends are comparable
//! epoch-for-epoch.

use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::coordinator::config::{Algo, Backend, Strategy, TrainConfig};
use crate::coordinator::metrics::{time_into, PhaseStats};
use crate::cpu_ref::{self, step, Hyper};
use crate::kernel::{self, InvariantPolicy, KernelCfg, KernelCounters, KernelPolicy};
use crate::model::{SharedFactors, TuckerModel};
use crate::runtime::{Engine, Executable};
use crate::sampler::StagedBlock;
use crate::tensor::SparseTensor;
use crate::util::pool;

/// Which half of the paper's two-phase iteration is running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Factor-matrix update phase (the A^(n) updates).
    Factor,
    /// Core-matrix update phase (the B^(n) gradient accumulation).
    Core,
}

/// Core-gradient accumulator for one pass: `[N, J, R]` for the all-modes
/// (Plus) schedule, `[J, R]` for a single-mode pass.  Backends add into
/// `grad`; the phase driver counts samples and applies once at pass end
/// (the paper's accumulate-then-atomicAdd schedule).
pub struct CoreAccum {
    /// Accumulated gradient slab (`[N, J, R]` or `[J, R]`, see `mode`).
    pub grad: Vec<f32>,
    /// Samples accumulated so far (the gradient is averaged on apply).
    pub count: usize,
    /// `None` for the all-modes schedule, `Some(m)` for a per-mode pass.
    pub mode: Option<usize>,
}

impl CoreAccum {
    /// Zeroed accumulator sized for `model` and the pass schedule.
    pub fn new(model: &TuckerModel, mode: Option<usize>) -> CoreAccum {
        let sz = match mode {
            None => model.order() * model.j * model.r,
            Some(_) => model.j * model.r,
        };
        CoreAccum {
            grad: vec![0f32; sz],
            count: 0,
            mode,
        }
    }

    /// Apply the accumulated gradient to the core matrices.
    pub fn apply(self, model: &mut TuckerModel, lr: f32, lam: f32) {
        match self.mode {
            None => model.apply_core_grad(&self.grad, self.count, lr, lam),
            Some(m) => model.apply_core_grad_mode(m, &self.grad, self.count, lr, lam),
        }
    }
}

/// One execution backend: runs staged blocks for both phases.
///
/// Contract with the phase driver: `refresh_c` is called once per phase
/// (before any pass), `begin_pass` once per pass (`mode = None` for the
/// all-modes Plus schedule, `Some(m)` for per-mode schedules), then
/// `run_factor_block` / `run_core_block` once per staged block.  Stage
/// timings go into the provided [`PhaseStats`]; block/sample counting is
/// the driver's job.
pub trait StepBackend {
    /// Human-readable runtime description (for logs).
    fn platform(&self) -> String;

    /// Block slot count S this backend wants for `phase`.
    fn block_size(&self, phase: Phase) -> usize;

    /// Refresh the storage-scheme projection tables `C^(n)` if this
    /// configuration uses them (no-op otherwise).
    fn refresh_c(&mut self, model: &TuckerModel) -> Result<()>;

    /// Prepare per-pass state (pack cores, snapshot `B^(mode)`, size slabs).
    fn begin_pass(&mut self, model: &TuckerModel, phase: Phase, mode: Option<usize>)
        -> Result<()>;

    /// Execute one factor-phase block: gather rows, run the update kernel,
    /// scatter updated rows back into `model`.
    fn run_factor_block(
        &mut self,
        model: &mut TuckerModel,
        block: &StagedBlock,
        mode: Option<usize>,
        st: &mut PhaseStats,
    ) -> Result<()>;

    /// Execute one core-phase block: compute the core gradient contribution
    /// and add it into `acc.grad` (factors are read-only here).
    fn run_core_block(
        &mut self,
        model: &mut TuckerModel,
        block: &StagedBlock,
        mode: Option<usize>,
        acc: &mut CoreAccum,
        st: &mut PhaseStats,
    ) -> Result<()>;

    /// Batched RMSE/MAE evaluation, if this backend has a predict kernel;
    /// `None` falls back to the scalar evaluator.
    fn predict_batch(
        &mut self,
        model: &TuckerModel,
        test: &SparseTensor,
    ) -> Result<Option<(f64, f64)>>;

    /// Replace the SGD hyper-parameters for subsequent blocks.  Backends
    /// capture a copy of [`Hyper`] at construction; the session layer's
    /// learning-rate decay calls this (through [`super::Trainer::set_hyper`])
    /// so mid-run changes actually reach the kernels.
    fn set_hyper(&mut self, hyper: Hyper);
}

/// Build the backend selected by `cfg.backend`.  Backends only need the
/// tensor *shape* (`dims`) — entry data reaches them as staged blocks —
/// so out-of-core sources construct backends without materializing
/// anything.
pub fn make_backend(dims: &[u32], cfg: &TrainConfig) -> Result<Box<dyn StepBackend>> {
    match cfg.backend {
        Backend::Hlo => Ok(Box::new(HloBackend::new(dims, cfg)?)),
        Backend::CpuRef => Ok(Box::new(CpuBackend::new(cfg, 1))),
        Backend::ParallelCpu => {
            let workers = if cfg.threads == 0 {
                pool::default_threads()
            } else {
                cfg.threads
            };
            Ok(Box::new(CpuBackend::new(cfg, workers.max(1))))
        }
    }
}

/// Gather stored C rows for a block into `[K, S, R]`, where output mode `k`
/// corresponds to tensor mode `modes[k]`.
fn gather_c_rows(
    c_store: &[Vec<f32>],
    r: usize,
    n: usize,
    out: &mut [f32],
    coords: &[u32],
    valid: usize,
    s: usize,
    modes: &[usize],
) {
    for (k, &m) in modes.iter().enumerate() {
        let dst = &mut out[k * s * r..(k + 1) * s * r];
        let src = &c_store[m];
        for e in 0..valid {
            let row = coords[e * n + m] as usize;
            dst[e * r..(e + 1) * r].copy_from_slice(&src[row * r..(row + 1) * r]);
        }
        dst[valid * r..].fill(0.0);
    }
}

// ======================================================================
// HLO / PJRT backend
// ======================================================================

/// PJRT-executed backend wrapping the compiled artifact [`Engine`].
pub struct HloBackend {
    cfg: TrainConfig,
    engine: Engine,
    factor_exe: Rc<Executable>,
    core_exe: Rc<Executable>,
    predict_exe: Rc<Executable>,
    compute_c_exe: Option<Rc<Executable>>,
    /// Storage-scheme projection tables C^(n) (I_n x R each).
    c_store: Vec<Vec<f32>>,
    // staging slabs, reused across blocks
    buf_a: Vec<f32>,
    buf_c: Vec<f32>,
    buf_cores: Vec<f32>,
    /// FasterTucker per-pass snapshot of `B^(mode)`.
    b0: Vec<f32>,
    /// Tensor modes in kernel order for C-row gathering this pass.
    pass_modes: Vec<usize>,
}

impl HloBackend {
    /// Load and compile the artifacts for the configured
    /// (algo, variant, strategy), for a tensor of shape `dims`.
    pub fn new(dims: &[u32], cfg: &TrainConfig) -> Result<HloBackend> {
        let n = dims.len();
        let v = cfg.variant.suffix();
        let engine = Engine::new(&cfg.artifact_dir)?;
        let (fk, ck) = match (cfg.algo, cfg.strategy) {
            (Algo::Plus, Strategy::Calculation) => {
                (format!("plus_factor_{v}"), format!("plus_core_{v}"))
            }
            (Algo::Plus, Strategy::Storage) => (
                format!("plus_factor_storage_{v}"),
                format!("plus_core_storage_{v}"),
            ),
            (Algo::FastTucker, _) => (
                format!("fasttucker_factor_{v}"),
                format!("fasttucker_core_{v}"),
            ),
            (Algo::FasterTucker | Algo::FasterTuckerCoo, _) => (
                format!("fastertucker_factor_{v}"),
                format!("fastertucker_core_{v}"),
            ),
        };
        let factor_exe = engine.load(&fk, n, cfg.j, cfg.r)?;
        let core_exe = engine.load(&ck, n, cfg.j, cfg.r)?;
        let predict_exe = engine.load("predict", n, cfg.j, cfg.r)?;
        let compute_c_exe = if matches!(cfg.algo, Algo::FasterTucker | Algo::FasterTuckerCoo)
            || cfg.strategy == Strategy::Storage
        {
            Some(engine.load_any_n("compute_c", cfg.j, cfg.r)?)
        } else {
            None
        };
        let c_store = dims
            .iter()
            .map(|&d| vec![0f32; d as usize * cfg.r])
            .collect();
        Ok(HloBackend {
            engine,
            factor_exe,
            core_exe,
            predict_exe,
            compute_c_exe,
            c_store,
            buf_a: Vec::new(),
            buf_c: Vec::new(),
            buf_cores: vec![0f32; n * cfg.j * cfg.r],
            b0: Vec::new(),
            pass_modes: Vec::new(),
            cfg: cfg.clone(),
        })
    }

    fn uses_c_store(&self) -> bool {
        matches!(self.cfg.algo, Algo::FasterTucker | Algo::FasterTuckerCoo)
            || (self.cfg.algo == Algo::Plus && self.cfg.strategy == Strategy::Storage)
    }

    fn storage_plus(&self) -> bool {
        self.cfg.algo == Algo::Plus && self.cfg.strategy == Strategy::Storage
    }

    fn hp_factor(&self) -> [f32; 2] {
        [self.cfg.hyper.lr_a, self.cfg.hyper.lam_a]
    }

    fn exe_for(&self, phase: Phase) -> Rc<Executable> {
        match phase {
            Phase::Factor => self.factor_exe.clone(),
            Phase::Core => self.core_exe.clone(),
        }
    }
}

impl StepBackend for HloBackend {
    fn platform(&self) -> String {
        self.engine.platform()
    }

    fn block_size(&self, phase: Phase) -> usize {
        self.exe_for(phase).info.s
    }

    /// Refresh C^(n) = A^(n) B^(n) through the `compute_c` executable, in
    /// row chunks of the artifact's S.
    fn refresh_c(&mut self, model: &TuckerModel) -> Result<()> {
        if !self.uses_c_store() {
            return Ok(());
        }
        let exe = self
            .compute_c_exe
            .clone()
            .context("compute_c executable not loaded")?;
        let chunk = exe.info.s;
        let (j, r) = (self.cfg.j, self.cfg.r);
        let n = model.order();
        let mut a_chunk = vec![0f32; chunk * j];
        for m in 0..n {
            let rows = model.dims[m] as usize;
            let fm = &model.factors[m];
            let b = &model.cores[m];
            let cs = &mut self.c_store[m];
            let mut lo = 0usize;
            while lo < rows {
                let hi = (lo + chunk).min(rows);
                let len = hi - lo;
                a_chunk[..len * j].copy_from_slice(&fm[lo * j..hi * j]);
                a_chunk[len * j..].fill(0.0);
                let out = exe.run(&[&a_chunk, b])?;
                cs[lo * r..hi * r].copy_from_slice(&out[0][..len * r]);
                lo = hi;
            }
        }
        Ok(())
    }

    fn begin_pass(
        &mut self,
        model: &TuckerModel,
        phase: Phase,
        mode: Option<usize>,
    ) -> Result<()> {
        let s = self.exe_for(phase).info.s;
        let n = model.order();
        let (j, r) = (self.cfg.j, self.cfg.r);
        match (self.cfg.algo, mode) {
            (Algo::Plus, None) => {
                model.pack_cores(&mut self.buf_cores);
                self.buf_a.resize(n * s * j, 0.0);
                if self.storage_plus() {
                    self.buf_c.resize(n * s * r, 0.0);
                    self.pass_modes = (0..n).collect();
                }
            }
            (Algo::FastTucker, Some(m)) => {
                model.pack_cores_rotated(m, &mut self.buf_cores);
                self.buf_a.resize(n * s * j, 0.0);
            }
            (Algo::FasterTucker | Algo::FasterTuckerCoo, Some(m)) => {
                self.b0 = model.cores[m].clone();
                self.buf_a.resize(s * j, 0.0);
                self.buf_c.resize((n - 1) * s * r, 0.0);
                self.pass_modes = (1..n).map(|k| (m + k) % n).collect();
            }
            (algo, mode) => bail!("invalid pass schedule: {algo:?} with mode {mode:?}"),
        }
        Ok(())
    }

    fn run_factor_block(
        &mut self,
        model: &mut TuckerModel,
        block: &StagedBlock,
        mode: Option<usize>,
        st: &mut PhaseStats,
    ) -> Result<()> {
        let exe = self.factor_exe.clone();
        let hp = self.hp_factor();
        let n = model.order();
        let r = self.cfg.r;
        match (self.cfg.algo, mode) {
            (Algo::Plus, None) => {
                time_into(&mut st.gather, || {
                    model.gather_batch(&block.coords, block.valid, &mut self.buf_a);
                });
                let storage = self.storage_plus();
                let out = time_into(&mut st.exec, || {
                    if storage {
                        gather_c_rows(
                            &self.c_store,
                            r,
                            n,
                            &mut self.buf_c,
                            &block.coords,
                            block.valid,
                            block.s,
                            &self.pass_modes,
                        );
                        exe.run(&[
                            &self.buf_a,
                            &self.buf_c,
                            &self.buf_cores,
                            &block.values,
                            &hp,
                        ])
                    } else {
                        exe.run(&[&self.buf_a, &self.buf_cores, &block.values, &hp])
                    }
                })?;
                time_into(&mut st.scatter, || {
                    model.scatter_batch(&block.coords, block.valid, &out[0]);
                });
            }
            (Algo::FastTucker, Some(m)) => {
                time_into(&mut st.gather, || {
                    model.gather_batch_rotated(&block.coords, block.valid, m, &mut self.buf_a);
                });
                let out = time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_cores, &block.values, &hp])
                })?;
                time_into(&mut st.scatter, || {
                    model.scatter_mode_rows(m, &block.coords, block.valid, &out[0]);
                });
            }
            (Algo::FasterTucker | Algo::FasterTuckerCoo, Some(m)) => {
                time_into(&mut st.gather, || {
                    model.gather_mode_rows(m, &block.coords, block.valid, &mut self.buf_a);
                    gather_c_rows(
                        &self.c_store,
                        r,
                        n,
                        &mut self.buf_c,
                        &block.coords,
                        block.valid,
                        block.s,
                        &self.pass_modes,
                    );
                });
                let out = time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_c, &self.b0, &block.values, &hp])
                })?;
                time_into(&mut st.scatter, || {
                    model.scatter_mode_rows(m, &block.coords, block.valid, &out[0]);
                    // Alg. 2 line 13: refresh stored C rows of the updated mode.
                    let cs = &mut self.c_store[m];
                    for e in 0..block.valid {
                        let row = block.coords[e * n + m] as usize;
                        cs[row * r..(row + 1) * r].copy_from_slice(&out[1][e * r..(e + 1) * r]);
                    }
                });
            }
            (algo, mode) => bail!("invalid factor block: {algo:?} with mode {mode:?}"),
        }
        Ok(())
    }

    fn run_core_block(
        &mut self,
        model: &mut TuckerModel,
        block: &StagedBlock,
        mode: Option<usize>,
        acc: &mut CoreAccum,
        st: &mut PhaseStats,
    ) -> Result<()> {
        let exe = self.core_exe.clone();
        let n = model.order();
        let r = self.cfg.r;
        let out = match (self.cfg.algo, mode) {
            (Algo::Plus, None) => {
                time_into(&mut st.gather, || {
                    model.gather_batch(&block.coords, block.valid, &mut self.buf_a);
                });
                let storage = self.storage_plus();
                time_into(&mut st.exec, || {
                    if storage {
                        gather_c_rows(
                            &self.c_store,
                            r,
                            n,
                            &mut self.buf_c,
                            &block.coords,
                            block.valid,
                            block.s,
                            &self.pass_modes,
                        );
                        exe.run(&[&self.buf_a, &self.buf_c, &block.values])
                    } else {
                        exe.run(&[&self.buf_a, &self.buf_cores, &block.values])
                    }
                })?
            }
            (Algo::FastTucker, Some(m)) => {
                time_into(&mut st.gather, || {
                    model.gather_batch_rotated(&block.coords, block.valid, m, &mut self.buf_a);
                });
                time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_cores, &block.values])
                })?
            }
            (Algo::FasterTucker | Algo::FasterTuckerCoo, Some(m)) => {
                time_into(&mut st.gather, || {
                    model.gather_mode_rows(m, &block.coords, block.valid, &mut self.buf_a);
                    gather_c_rows(
                        &self.c_store,
                        r,
                        n,
                        &mut self.buf_c,
                        &block.coords,
                        block.valid,
                        block.s,
                        &self.pass_modes,
                    );
                });
                time_into(&mut st.exec, || {
                    exe.run(&[&self.buf_a, &self.buf_c, &self.b0, &block.values])
                })?
            }
            (algo, mode) => bail!("invalid core block: {algo:?} with mode {mode:?}"),
        };
        time_into(&mut st.scatter, || {
            for (g, &v) in acc.grad.iter_mut().zip(out[0].iter()) {
                *g += v;
            }
        });
        Ok(())
    }

    /// Batched evaluation through the `predict` artifact.
    fn predict_batch(
        &mut self,
        model: &TuckerModel,
        test: &SparseTensor,
    ) -> Result<Option<(f64, f64)>> {
        let exe = self.predict_exe.clone();
        let s = exe.info.s;
        let n = test.order();
        let j = self.cfg.j;
        model.pack_cores(&mut self.buf_cores);
        self.buf_a.resize(n * s * j, 0.0);
        let mut coords = vec![0u32; s * n];
        let mut values = vec![0f32; s];
        let mut sse = 0f64;
        let mut sae = 0f64;
        let mut lo = 0usize;
        while lo < test.nnz() {
            let valid = (test.nnz() - lo).min(s);
            for e in 0..valid {
                coords[e * n..(e + 1) * n].copy_from_slice(test.coords(lo + e));
                values[e] = test.values[lo + e];
            }
            coords[valid * n..].fill(0);
            values[valid..].fill(0.0);
            model.gather_batch(&coords, valid, &mut self.buf_a);
            let out = exe.run(&[&self.buf_a, &self.buf_cores])?;
            for e in 0..valid {
                let err = (values[e] - out[0][e]) as f64;
                sse += err * err;
                sae += err.abs();
            }
            lo += valid;
        }
        let cnt = test.nnz().max(1) as f64;
        Ok(Some(((sse / cnt).sqrt(), sae / cnt)))
    }

    fn set_hyper(&mut self, hyper: Hyper) {
        // the HLO kernels take lr/lam as runtime inputs read from the
        // config at block launch, so updating the captured copy is enough
        self.cfg.hyper = hyper;
    }
}

// ======================================================================
// CPU backend (tiled kernels; serial + Hogwild-parallel)
// ======================================================================

/// Block slot count for the CPU backends (multiple of the warp size; large
/// enough that the per-block scheduling overhead vanishes, small enough
/// that the streaming scheduler's double buffer keeps both stages busy).
pub const CPU_BLOCK_S: usize = 8192;

/// Block executor over the tiled CPU kernels ([`crate::kernel`]).
/// `workers = 1` reproduces the sequential `cpu_ref` semantics exactly;
/// `workers > 1` shards each block's valid slots across scoped threads with
/// Hogwild scatter through [`SharedFactors`].
///
/// The kernel configuration comes from the run config: `cpu_kernel`
/// selects tiled microkernels vs the scalar oracle, and the Table-9
/// `strategy` knob maps onto the [`InvariantPolicy`] of the storage-scheme
/// kernels (`calculation` → recompute per sample, `storage` → cache per
/// fiber).
pub struct CpuBackend {
    algo: Algo,
    hyper: Hyper,
    workers: usize,
    kernel: KernelCfg,
    /// Stored projection tables (FasterTucker-family only), refreshed per
    /// pass in `begin_pass`.
    c_store: Vec<Vec<f32>>,
}

impl CpuBackend {
    /// Build a CPU backend with `workers` Hogwild threads (1 = the serial
    /// CpuRef oracle).
    pub fn new(cfg: &TrainConfig, workers: usize) -> CpuBackend {
        let invariant = match cfg.strategy {
            Strategy::Calculation => InvariantPolicy::Recompute,
            Strategy::Storage => InvariantPolicy::CachePerFiber,
        };
        CpuBackend {
            algo: cfg.algo,
            hyper: cfg.hyper,
            workers: workers.max(1),
            kernel: KernelCfg {
                policy: cfg.cpu_kernel,
                invariant,
            },
            c_store: Vec::new(),
        }
    }

    fn uses_c_store(&self) -> bool {
        matches!(self.algo, Algo::FasterTucker | Algo::FasterTuckerCoo)
    }
}

impl StepBackend for CpuBackend {
    fn platform(&self) -> String {
        let base = if self.workers <= 1 {
            "cpu_ref".to_string()
        } else {
            format!("parallel_cpu({} threads)", self.workers)
        };
        if self.kernel.policy == KernelPolicy::Simd {
            format!("{base} [simd:{}]", kernel::simd::active().name())
        } else {
            base
        }
    }

    fn block_size(&self, _phase: Phase) -> usize {
        CPU_BLOCK_S
    }

    fn refresh_c(&mut self, _model: &TuckerModel) -> Result<()> {
        // the scalar path refreshes per pass (in `begin_pass`), matching
        // the per-mode-pass refresh of the sequential oracle
        Ok(())
    }

    fn begin_pass(
        &mut self,
        model: &TuckerModel,
        _phase: Phase,
        _mode: Option<usize>,
    ) -> Result<()> {
        if self.uses_c_store() {
            self.c_store = (0..model.order())
                .map(|m| cpu_ref::compute_c_full(model, m))
                .collect();
        }
        Ok(())
    }

    fn run_factor_block(
        &mut self,
        model: &mut TuckerModel,
        block: &StagedBlock,
        mode: Option<usize>,
        st: &mut PhaseStats,
    ) -> Result<()> {
        if block.valid == 0 {
            return Ok(());
        }
        let (n, j, r) = (model.order(), model.j, model.r);
        let (algo, hyper, workers) = (self.algo, self.hyper, self.workers.min(block.valid));
        let kcfg = self.kernel;
        let counters = time_into(&mut st.exec, || {
            let (factors, cores) = (&mut model.factors, &model.cores);
            let shared = SharedFactors::new(factors, j);
            let data = step::BlockData {
                cores,
                c_store: &self.c_store,
                coords: &block.coords,
                lanes: &block.lanes,
                values: &block.values,
                n,
                j,
                r,
                hyper,
            };
            if workers <= 1 {
                kernel::run_factor_range(algo, mode, &shared, &data, 0..block.valid, kcfg)
            } else {
                let hits = AtomicU64::new(0);
                let misses = AtomicU64::new(0);
                pool::parallel_chunks(block.valid, workers, |range| {
                    let c = kernel::run_factor_range(algo, mode, &shared, &data, range, kcfg);
                    hits.fetch_add(c.inv_hits, Ordering::Relaxed);
                    misses.fetch_add(c.inv_misses, Ordering::Relaxed);
                });
                KernelCounters {
                    inv_hits: hits.into_inner(),
                    inv_misses: misses.into_inner(),
                }
            }
        });
        st.add_counters(counters);
        Ok(())
    }

    fn run_core_block(
        &mut self,
        model: &mut TuckerModel,
        block: &StagedBlock,
        mode: Option<usize>,
        acc: &mut CoreAccum,
        st: &mut PhaseStats,
    ) -> Result<()> {
        if block.valid == 0 {
            return Ok(());
        }
        let (n, j, r) = (model.order(), model.j, model.r);
        let (algo, hyper, workers) = (self.algo, self.hyper, self.workers.min(block.valid));
        let kcfg = self.kernel;
        let glen = acc.grad.len();
        let counters = time_into(&mut st.exec, || {
            let (factors, cores) = (&mut model.factors, &model.cores);
            let shared = SharedFactors::new(factors, j);
            let data = step::BlockData {
                cores,
                c_store: &self.c_store,
                coords: &block.coords,
                lanes: &block.lanes,
                values: &block.values,
                n,
                j,
                r,
                hyper,
            };
            if workers <= 1 {
                let range = 0..block.valid;
                kernel::run_core_range(algo, mode, &shared, &data, range, &mut acc.grad, kcfg)
            } else {
                let hits = AtomicU64::new(0);
                let misses = AtomicU64::new(0);
                let partials = std::sync::Mutex::new(Vec::with_capacity(workers));
                pool::parallel_chunks(block.valid, workers, |range| {
                    let mut g = vec![0f32; glen];
                    let c = kernel::run_core_range(algo, mode, &shared, &data, range, &mut g, kcfg);
                    hits.fetch_add(c.inv_hits, Ordering::Relaxed);
                    misses.fetch_add(c.inv_misses, Ordering::Relaxed);
                    partials.lock().unwrap().push(g);
                });
                for g in partials.into_inner().unwrap() {
                    for (a, b) in acc.grad.iter_mut().zip(&g) {
                        *a += b;
                    }
                }
                KernelCounters {
                    inv_hits: hits.into_inner(),
                    inv_misses: misses.into_inner(),
                }
            }
        });
        st.add_counters(counters);
        Ok(())
    }

    fn predict_batch(
        &mut self,
        _model: &TuckerModel,
        _test: &SparseTensor,
    ) -> Result<Option<(f64, f64)>> {
        Ok(None) // scalar evaluator handles it
    }

    fn set_hyper(&mut self, hyper: Hyper) {
        self.hyper = hyper;
    }
}
