//! Published model snapshots and the versioned `FTCK` checkpoint format.
//!
//! A [`ModelSnapshot`] is the *serving* representation of a trained
//! decomposition: immutable, cheaply clonable (the payload sits behind one
//! `Arc`), tagged with the epoch and algorithm that produced it, and
//! carrying the precomputed projection tables `C^(n) = A^(n) B^(n)`
//! (`I_n x R` each) that make per-query scoring a pure product chain over
//! R-wide rows — the SGD_Tucker "compact serving representation" of the
//! Tucker factors.  By default the tables are built through the shared
//! exact primitive layer ([`crate::kernel::prim`]) the trainer's oracle
//! defines, so every value a snapshot serves is bit-identical to what the
//! trainer's evaluation path computes.  [`ModelSnapshot::from_model_policy`]
//! can opt a build into the runtime-dispatched SIMD layer instead
//! (tolerance-bounded, for bulk republish paths where throughput wins).
//!
//! The on-disk checkpoint (`FTCK` version 1) is the durable form of a
//! snapshot: a little-endian header (algo, epoch, order, J, R, dims),
//! the factor and core payload as lossless f32 bits, and a trailing
//! FNV-1a checksum over everything before it.  Serialization is a pure
//! function of the model, so save → load → save produces identical bytes
//! (pinned by `tests/serve.rs`).  [`ModelSnapshot::save`] writes to a
//! sibling `*.tmp` file and renames it into place, so a crash mid-write
//! never leaves a truncated checkpoint at the published path, and a
//! concurrent reader sees either the old file or the new one.

use std::fs;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::config::Algo;
use crate::kernel::{prim, simd, KernelPolicy};
use crate::model::TuckerModel;
use crate::util::fnv::fnv1a;

/// Magic bytes of the serve checkpoint format.
const MAGIC: &[u8; 4] = b"FTCK";
/// Current checkpoint format version.
const VERSION: u32 = 1;

/// Immutable, epoch-tagged, cheaply-clonable published model.
///
/// Cloning copies one `Arc`, so a server hot-swap is a pointer replace and
/// every in-flight batch keeps (and finishes on) the snapshot it started
/// with.
#[derive(Clone)]
pub struct ModelSnapshot {
    inner: Arc<Inner>,
}

struct Inner {
    dims: Vec<u32>,
    j: usize,
    r: usize,
    algo: Algo,
    epoch: u64,
    factors: Vec<Vec<f32>>,
    cores: Vec<Vec<f32>>,
    /// Projection tables `C^(n) = A^(n) B^(n)`, `I_n x R` row-major.
    c_tables: Vec<Vec<f32>>,
}

impl ModelSnapshot {
    /// Freeze a trained model into a snapshot, tagged with the algorithm
    /// and epoch that produced it.  Builds the `C^(n)` projection tables
    /// through the exact primitive layer ([`crate::kernel::prim`]) —
    /// bit-identical to the trainer's oracle projection.
    pub fn from_model(model: &TuckerModel, algo: Algo, epoch: u64) -> ModelSnapshot {
        ModelSnapshot::from_model_policy(model, algo, epoch, KernelPolicy::Tiled)
    }

    /// [`ModelSnapshot::from_model`] with an explicit kernel policy for the
    /// table build.  [`KernelPolicy::Simd`] routes the projections through
    /// the runtime-dispatched SIMD layer (tolerance-bounded against the
    /// oracle); every other policy takes the exact path.  The choice only
    /// affects table *construction* — serving arithmetic on the finished
    /// tables is governed by the engine's own policy.
    pub fn from_model_policy(
        model: &TuckerModel,
        algo: Algo,
        epoch: u64,
        policy: KernelPolicy,
    ) -> ModelSnapshot {
        let c_tables = (0..model.order())
            .map(|m| project_table(model, m, policy))
            .collect();
        ModelSnapshot {
            inner: Arc::new(Inner {
                dims: model.dims.clone(),
                j: model.j,
                r: model.r,
                algo,
                epoch,
                factors: model.factors.clone(),
                cores: model.cores.clone(),
                c_tables,
            }),
        }
    }

    /// Reconstruct a mutable [`TuckerModel`] (e.g. to resume training from
    /// a checkpoint).
    pub fn to_model(&self) -> TuckerModel {
        TuckerModel {
            dims: self.inner.dims.clone(),
            j: self.inner.j,
            r: self.inner.r,
            factors: self.inner.factors.clone(),
            cores: self.inner.cores.clone(),
        }
    }

    /// Dimension sizes `I_n` of the decomposed tensor.
    pub fn dims(&self) -> &[u32] {
        &self.inner.dims
    }

    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.inner.dims.len()
    }

    /// Factor rank J.
    pub fn j(&self) -> usize {
        self.inner.j
    }

    /// Kruskal rank R.
    pub fn r(&self) -> usize {
        self.inner.r
    }

    /// Algorithm that trained this model.
    pub fn algo(&self) -> Algo {
        self.inner.algo
    }

    /// Training epoch this snapshot was published at.
    pub fn epoch(&self) -> u64 {
        self.inner.epoch
    }

    /// Row `i` of the projection table `C^(mode)` (length R).
    #[inline]
    pub fn c_row(&self, mode: usize, i: usize) -> &[f32] {
        let r = self.inner.r;
        &self.inner.c_tables[mode][i * r..(i + 1) * r]
    }

    /// The full projection table `C^(mode)` (`I_mode x R` row-major).
    pub fn c_table(&self, mode: usize) -> &[f32] {
        &self.inner.c_tables[mode]
    }

    /// Total parameter count (factors + cores), for logs.
    pub fn param_count(&self) -> usize {
        let f: usize = self.inner.factors.iter().map(Vec::len).sum();
        let c: usize = self.inner.cores.iter().map(Vec::len).sum();
        f + c
    }

    /// Whether two handles point at the same published snapshot (used by
    /// serving workers to skip redundant engine swaps).
    pub fn ptr_eq(a: &ModelSnapshot, b: &ModelSnapshot) -> bool {
        Arc::ptr_eq(&a.inner, &b.inner)
    }

    // --- checkpoint I/O ---------------------------------------------------

    /// Serialize to the `FTCK` v1 byte format (header + f32 payload +
    /// trailing FNV-1a checksum).  Deterministic: the same model always
    /// produces the same bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let inner = &self.inner;
        let payload: usize = inner.factors.iter().map(Vec::len).sum::<usize>()
            + inner.cores.iter().map(Vec::len).sum::<usize>();
        let mut out = Vec::with_capacity(36 + 4 * inner.dims.len() + 4 * payload + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.algo().code().to_le_bytes());
        out.extend_from_slice(&inner.epoch.to_le_bytes());
        out.extend_from_slice(&(inner.dims.len() as u32).to_le_bytes());
        out.extend_from_slice(&(inner.j as u32).to_le_bytes());
        out.extend_from_slice(&(inner.r as u32).to_le_bytes());
        for &d in &inner.dims {
            out.extend_from_slice(&d.to_le_bytes());
        }
        for v in inner.factors.iter().flatten().chain(inner.cores.iter().flatten()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse the `FTCK` byte format (with checksum verification).
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelSnapshot> {
        ensure!(bytes.len() >= 36 + 8, "checkpoint truncated ({} bytes)", bytes.len());
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        ensure!(
            fnv1a(body) == stored,
            "checkpoint corrupt: checksum mismatch"
        );
        let mut cur = Cursor { buf: body, pos: 0 };
        let magic = cur.take(4)?;
        ensure!(magic == MAGIC, "not an FTCK checkpoint");
        let version = cur.u32()?;
        ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let algo = Algo::from_code(cur.u32()?).context("unknown algorithm code")?;
        let epoch = cur.u64()?;
        let order = cur.u32()? as usize;
        let j = cur.u32()? as usize;
        let r = cur.u32()? as usize;
        ensure!((1..=64).contains(&order), "implausible order {order}");
        // keep load() a total, error-returning parser: zero ranks would
        // panic downstream (division / zero-size chunks), huge ones would
        // abort on allocation before the payload-size check can reject
        ensure!((1..=4096).contains(&j), "implausible J {j}");
        ensure!((1..=4096).contains(&r), "implausible R {r}");
        let mut dims = Vec::with_capacity(order);
        for _ in 0..order {
            dims.push(cur.u32()?);
        }
        let payload: usize =
            dims.iter().map(|&d| d as usize * j).sum::<usize>() + order * j * r;
        ensure!(
            cur.remaining() == payload * 4,
            "checkpoint corrupt: payload is {} bytes, header implies {}",
            cur.remaining(),
            payload * 4
        );
        let mut factors = Vec::with_capacity(order);
        for &d in &dims {
            factors.push(cur.f32s(d as usize * j)?);
        }
        let mut cores = Vec::with_capacity(order);
        for _ in 0..order {
            cores.push(cur.f32s(j * r)?);
        }
        let model = TuckerModel {
            dims,
            j,
            r,
            factors,
            cores,
        };
        Ok(ModelSnapshot::from_model(&model, algo, epoch))
    }

    /// Atomically write the checkpoint: serialize, write a sibling
    /// `<name>.tmp`, fsync it, then rename into place.  The fsync before
    /// the rename is what makes the swap durable — without it a power
    /// loss can journal the rename ahead of the data and replace a good
    /// checkpoint with a truncated one.
    pub fn save(&self, path: &Path) -> Result<()> {
        use std::io::Write;
        let name = path
            .file_name()
            .with_context(|| format!("checkpoint path {path:?} has no file name"))?;
        let tmp = path.with_file_name(format!("{}.tmp", name.to_string_lossy()));
        {
            let mut f = fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
            f.write_all(&self.to_bytes())
                .with_context(|| format!("write {tmp:?}"))?;
            f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
        }
        fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
        Ok(())
    }

    /// Load and verify a checkpoint written by [`ModelSnapshot::save`].
    pub fn load(path: &Path) -> Result<ModelSnapshot> {
        let bytes = fs::read(path).with_context(|| format!("open {path:?}"))?;
        ModelSnapshot::from_bytes(&bytes).with_context(|| format!("load {path:?}"))
    }
}

impl std::fmt::Debug for ModelSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelSnapshot")
            .field("dims", &self.inner.dims)
            .field("j", &self.inner.j)
            .field("r", &self.inner.r)
            .field("algo", &self.inner.algo)
            .field("epoch", &self.inner.epoch)
            .finish()
    }
}

/// Little-endian reader over a checkpoint body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Project every row of mode `mode`'s factor matrix through its core:
/// `C[i, :] = A[i, :] B`.  The exact path is one call into the shared
/// primitive layer ([`prim::project_rows`] — the same accumulation-order
/// contract the trainer's oracle defines); the SIMD policy runs the
/// runtime-dispatched [`simd::project_row`] per table row instead.
fn project_table(model: &TuckerModel, mode: usize, policy: KernelPolicy) -> Vec<f32> {
    let (j, r) = (model.j, model.r);
    let factor = &model.factors[mode];
    let core = &model.cores[mode];
    let mut out = vec![0f32; (factor.len() / j) * r];
    if policy == KernelPolicy::Simd {
        for (row, dst) in factor.chunks_exact(j).zip(out.chunks_exact_mut(r)) {
            simd::project_row(row, core, dst);
        }
    } else {
        prim::project_rows(factor, core, j, r, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu_ref;

    fn model() -> TuckerModel {
        TuckerModel::init(&[10, 12, 14], 16, 16, 42)
    }

    #[test]
    fn snapshot_tables_match_oracle_projection() {
        let m = model();
        let snap = ModelSnapshot::from_model(&m, Algo::Plus, 3);
        for mode in 0..3 {
            let want = cpu_ref::compute_c_full(&m, mode);
            assert_eq!(snap.c_table(mode), &want[..], "mode {mode} C table diverged");
        }
    }

    #[test]
    fn odd_shapes_use_scalar_projection() {
        // (48, 16) has no monomorphized tile; the fallback must agree with
        // the oracle bit-for-bit.
        let m = TuckerModel::init(&[6, 7], 48, 16, 9);
        let snap = ModelSnapshot::from_model(&m, Algo::FastTucker, 0);
        for mode in 0..2 {
            let want = cpu_ref::compute_c_full(&m, mode);
            assert_eq!(snap.c_table(mode), &want[..]);
        }
    }

    #[test]
    fn simd_tables_track_oracle_within_tolerance() {
        let m = model();
        let snap = ModelSnapshot::from_model_policy(&m, Algo::Plus, 3, KernelPolicy::Simd);
        for mode in 0..3 {
            let want = cpu_ref::compute_c_full(&m, mode);
            for (i, (&got, &w)) in snap.c_table(mode).iter().zip(&want).enumerate() {
                assert!(
                    (got - w).abs() <= 1e-5 * (1.0 + w.abs()),
                    "mode {mode} [{i}]: simd {got} vs oracle {w}"
                );
            }
        }
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        let m = model();
        let snap = ModelSnapshot::from_model(&m, Algo::FasterTucker, 17);
        let bytes = snap.to_bytes();
        let back = ModelSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back.algo(), Algo::FasterTucker);
        assert_eq!(back.epoch(), 17);
        assert_eq!(back.to_model().factors, m.factors);
        assert_eq!(back.to_model().cores, m.cores);
        // save -> load -> save is byte-identical
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn corruption_is_detected() {
        let snap = ModelSnapshot::from_model(&model(), Algo::Plus, 1);
        let good = snap.to_bytes();
        for &at in &[5usize, 20, good.len() / 2, good.len() - 1] {
            let mut bad = good.clone();
            bad[at] ^= 0x40;
            assert!(
                ModelSnapshot::from_bytes(&bad).is_err(),
                "flip at {at} went undetected"
            );
        }
        assert!(ModelSnapshot::from_bytes(&good[..good.len() - 9]).is_err());
        assert!(ModelSnapshot::from_bytes(&good[..10]).is_err());
    }

    #[test]
    fn hostile_header_ranks_are_rejected_not_panicked() {
        // a crafted checkpoint can carry a *valid* checksum over a hostile
        // header — zero or absurd J/R must come back as Err, not a panic
        let good = ModelSnapshot::from_model(&model(), Algo::Plus, 1).to_bytes();
        for (offset, value) in [(24usize, 0u32), (24, u32::MAX), (28, 0), (28, u32::MAX)] {
            let mut bad = good[..good.len() - 8].to_vec();
            bad[offset..offset + 4].copy_from_slice(&value.to_le_bytes());
            let sum = fnv1a(&bad);
            bad.extend_from_slice(&sum.to_le_bytes());
            assert!(
                ModelSnapshot::from_bytes(&bad).is_err(),
                "rank {value} at offset {offset} was accepted"
            );
        }
    }

    #[test]
    fn atomic_save_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("ft_serve_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ftc");
        let snap = ModelSnapshot::from_model(&model(), Algo::Plus, 2);
        snap.save(&path).unwrap();
        assert!(!path.with_file_name("m.ftc.tmp").exists());
        let back = ModelSnapshot::load(&path).unwrap();
        assert_eq!(back.epoch(), 2);
        assert!(ModelSnapshot::ptr_eq(&snap, &snap.clone()));
        assert!(!ModelSnapshot::ptr_eq(&snap, &back));
    }
}
