//! Top-K selection over mode-completion scores — the recommender query.
//!
//! Selection is deterministic: candidates are ranked by score descending
//! with ties broken by index ascending (`f32::total_cmp`, so the order is
//! total even for pathological scores).  [`top_k`] uses an O(I) average
//! partial selection (`select_nth_unstable_by`) and only sorts the K
//! survivors, so scoring the free mode dominates the query cost, not the
//! selection.

use super::engine::Engine;

/// One ranked candidate of a top-K query.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    /// Candidate index along the completed mode.
    pub index: u32,
    /// Completion score (higher is better).
    pub score: f32,
}

/// The K best indices of `scores`, ranked score-descending with
/// index-ascending tie-breaks.  Returns fewer than `k` only when the
/// candidate set is smaller than `k`.
pub fn top_k(scores: &[f32], k: usize) -> Vec<Scored> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    let rank = |a: &u32, b: &u32| {
        scores[*b as usize]
            .total_cmp(&scores[*a as usize])
            .then_with(|| a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, rank);
        idx.truncate(k);
    }
    idx.sort_unstable_by(rank);
    idx.into_iter()
        .map(|i| Scored {
            index: i,
            score: scores[i as usize],
        })
        .collect()
}

/// Mode-completion top-K: score every index of `mode` (all other
/// coordinates fixed by `coords`; the slot at `mode` is ignored) and
/// return the K best.  The fiber invariant is computed once for the whole
/// sweep (see [`Engine::complete_mode`]).
pub fn mode_topk(engine: &mut Engine, coords: &[u32], mode: usize, k: usize) -> Vec<Scored> {
    let mut scores = Vec::new();
    engine.complete_mode(coords, mode, &mut scores);
    top_k(&scores, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_and_orders() {
        let scores = [0.5f32, 2.0, -1.0, 2.0, 0.0, 1.5];
        let top = top_k(&scores, 3);
        // ties (indices 1 and 3 at 2.0) break toward the lower index
        assert_eq!(
            top.iter().map(|s| s.index).collect::<Vec<_>>(),
            vec![1, 3, 5]
        );
        assert_eq!(top[0].score, 2.0);
    }

    #[test]
    fn k_larger_than_candidates() {
        let scores = [1.0f32, 3.0];
        let top = top_k(&scores, 10);
        assert_eq!(
            top.iter().map(|s| s.index).collect::<Vec<_>>(),
            vec![1, 0]
        );
        assert!(top_k(&scores, 0).is_empty());
        assert!(top_k(&[], 5).is_empty());
    }

    #[test]
    fn matches_full_sort() {
        // pseudo-random scores; compare against the brute-force full sort
        let scores: Vec<f32> = (0..257u32)
            .map(|i| ((i.wrapping_mul(2654435761) >> 7) % 1000) as f32 * 0.01 - 5.0)
            .collect();
        let mut brute: Vec<u32> = (0..scores.len() as u32).collect();
        brute.sort_by(|a, b| {
            scores[*b as usize]
                .total_cmp(&scores[*a as usize])
                .then_with(|| a.cmp(b))
        });
        let top = top_k(&scores, 17);
        assert_eq!(
            top.iter().map(|s| s.index).collect::<Vec<_>>(),
            brute[..17].to_vec()
        );
    }
}
