//! Threaded serving loop: request batching + snapshot hot-swap.
//!
//! A [`Server`] owns a pool of worker threads draining one shared request
//! queue.  Workers pull *batches* (up to `max_batch` requests per wakeup),
//! re-read the published snapshot once per batch and answer every request
//! in the batch against that one model — so a batch is internally
//! consistent by construction, and the per-request overhead (lock, queue
//! pop, snapshot read) is amortized the same way the trainer amortizes
//! per-block scheduling.
//!
//! Hot-swap: [`Server::publish`] (or `Trainer::publish`) replaces the
//! published [`ModelSnapshot`] under a write lock.  Because a snapshot is
//! one `Arc`, the swap is a pointer replace: batches already in flight
//! keep scoring against the snapshot they cloned, new batches pick up the
//! fresh one, and no request can ever observe a half-updated model
//! (pinned by the torn-read test in `tests/serve.rs`).  This is what lets
//! a trainer publish mid-training while the server keeps answering.
//!
//! Transport is out of scope on purpose: [`ServerHandle::call`] is a
//! blocking in-process request — examples and the CLI drive it directly,
//! and a network front-end would sit on top of the same handle.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::kernel::KernelPolicy;
use crate::obs::{Counter, Gauge, Hist, Metrics, MetricsSnapshot};

use super::engine::Engine;
use super::snapshot::ModelSnapshot;
use super::topk::{mode_topk, Scored};

/// One serving request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Predict the entry at `coords` (full coordinates, one per mode).
    Predict {
        /// Entry coordinates, length N.
        coords: Vec<u32>,
    },
    /// Mode-completion top-K: all coordinates fixed except `mode` (that
    /// slot of `coords` is ignored), return the K best candidate indices.
    TopK {
        /// Fixed coordinates, length N (slot `mode` ignored).
        coords: Vec<u32>,
        /// The free mode to complete over.
        mode: usize,
        /// How many candidates to return.
        k: usize,
    },
    /// Report the epoch tag of the snapshot answering this batch (lets
    /// clients observe hot-swaps).
    Epoch,
    /// Report the server's live telemetry — per-request latency
    /// histograms, queue depth, batch sizes, swap count — as a
    /// [`MetricsSnapshot`] over the same protocol as every other request.
    Stats,
}

/// The answer to one [`Request`].
#[derive(Clone, Debug)]
pub enum Response {
    /// Predicted entry value.
    Predict(f32),
    /// Ranked top-K candidates.
    TopK(Vec<Scored>),
    /// Epoch tag of the answering snapshot.
    Epoch(u64),
    /// Telemetry snapshot answering a [`Request::Stats`].
    Stats(MetricsSnapshot),
    /// Admission control shed this request before it entered the queue —
    /// the network tier's backpressure signal (the in-process queue never
    /// sheds).  Retry later; the request was *not* executed.
    Overloaded,
    /// The request's deadline expired before a worker reached it; it was
    /// *not* executed.  Only the network tier sets deadlines.
    DeadlineExceeded,
    /// The request was malformed or the server is stopping.
    Error(String),
}

/// Serving counters (monotonic since start).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStats {
    /// Requests answered.
    pub served: u64,
    /// Worker batch wakeups (served / batches = mean batch size).
    pub batches: u64,
    /// Snapshots published over the server's lifetime.
    pub swaps: u64,
}

type Job = (Request, mpsc::Sender<Response>);

/// Pre-registered instrument handles — resolved once at server start so
/// the request hot path records through plain `Arc`s, never touching the
/// registry's name table.
struct ObsHandles {
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    batches: Arc<Counter>,
    swaps: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    batch_size: Arc<Hist>,
    lat_predict: Arc<Hist>,
    lat_topk: Arc<Hist>,
    lat_epoch: Arc<Hist>,
    lat_stats: Arc<Hist>,
}

impl ObsHandles {
    fn new(m: &Metrics) -> ObsHandles {
        ObsHandles {
            requests: m.counter("serve.requests"),
            errors: m.counter("serve.errors"),
            batches: m.counter("serve.batches"),
            swaps: m.counter("serve.swaps"),
            queue_depth: m.gauge("serve.queue_depth"),
            batch_size: m.hist("serve.batch_size"),
            lat_predict: m.hist("serve.latency.predict"),
            lat_topk: m.hist("serve.latency.topk"),
            lat_epoch: m.hist("serve.latency.epoch"),
            lat_stats: m.hist("serve.latency.stats"),
        }
    }

    /// The latency histogram for a request's kind.
    fn latency(&self, req: &Request) -> &Hist {
        match req {
            Request::Predict { .. } => &self.lat_predict,
            Request::TopK { .. } => &self.lat_topk,
            Request::Epoch => &self.lat_epoch,
            Request::Stats => &self.lat_stats,
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    ready: Condvar,
    snapshot: RwLock<ModelSnapshot>,
    policy: KernelPolicy,
    stop: AtomicBool,
    served: AtomicU64,
    batches: AtomicU64,
    swaps: AtomicU64,
    metrics: Arc<Metrics>,
    obs: ObsHandles,
}

/// A running serving loop; dropping it without [`Server::shutdown`] leaks
/// the worker threads until process exit, so shut it down explicitly.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

/// Cheap, clonable client handle onto a [`Server`]'s queue.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl Server {
    /// Start `workers` threads serving `snapshot`, batching up to
    /// `max_batch` queued requests per worker wakeup.  Workers score with
    /// the exact kernel tier; see [`Server::start_with_policy`].
    pub fn start(snapshot: ModelSnapshot, workers: usize, max_batch: usize) -> Server {
        Server::start_with_policy(snapshot, workers, max_batch, KernelPolicy::Tiled)
    }

    /// [`Server::start`] with an explicit kernel policy for the workers'
    /// scoring engines.  [`KernelPolicy::Simd`] routes the top-K candidate
    /// sweeps through the runtime-dispatched SIMD layer
    /// (tolerance-bounded); predictions stay bit-exact under every policy.
    pub fn start_with_policy(
        snapshot: ModelSnapshot,
        workers: usize,
        max_batch: usize,
        policy: KernelPolicy,
    ) -> Server {
        let metrics = Metrics::shared();
        let obs = ObsHandles::new(&metrics);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            snapshot: RwLock::new(snapshot),
            policy,
            stop: AtomicBool::new(false),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            metrics,
            obs,
        });
        let max_batch = max_batch.max(1);
        let workers = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(&shared, max_batch))
            })
            .collect();
        Server { shared, workers }
    }

    /// A client handle (clone freely across threads).
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            shared: self.shared.clone(),
        }
    }

    /// Publish a new snapshot: atomic pointer swap under a write lock.
    /// In-flight batches finish on the snapshot they started with.
    pub fn publish(&self, snapshot: ModelSnapshot) {
        *self.shared.snapshot.write().unwrap() = snapshot;
        self.shared.swaps.fetch_add(1, Ordering::SeqCst);
        self.shared.obs.swaps.inc();
    }

    /// Epoch tag of the currently published snapshot.
    pub fn epoch(&self) -> u64 {
        self.shared.snapshot.read().unwrap().epoch()
    }

    /// Current serving counters.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            served: self.shared.served.load(Ordering::SeqCst),
            batches: self.shared.batches.load(Ordering::SeqCst),
            swaps: self.shared.swaps.load(Ordering::SeqCst),
        }
    }

    /// The server's telemetry registry (per-request latency histograms,
    /// queue depth, batch sizes) — shareable with an exporter.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Freeze the current telemetry (what [`Request::Stats`] answers
    /// with, without going through the queue).
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting work, drain queued requests, join the workers and
    /// fail any request that raced past the drain.  Returns final stats.
    pub fn shutdown(self) -> ServeStats {
        {
            // set stop under the queue lock: after this critical section no
            // handle can enqueue (call() checks stop under the same lock)
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::SeqCst);
        }
        self.shared.ready.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
        // workers only exit on an empty queue, but fail anything that
        // slipped in between their last check and the join
        for (_, reply) in self.shared.queue.lock().unwrap().drain(..) {
            let _ = reply.send(Response::Error("server stopped".to_string()));
        }
        ServeStats {
            served: self.shared.served.load(Ordering::SeqCst),
            batches: self.shared.batches.load(Ordering::SeqCst),
            swaps: self.shared.swaps.load(Ordering::SeqCst),
        }
    }
}

impl ServerHandle {
    /// Submit one request and block for its response.
    pub fn call(&self, req: Request) -> Response {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.stop.load(Ordering::SeqCst) {
                return Response::Error("server stopped".to_string());
            }
            q.push_back((req, tx));
            self.shared.obs.queue_depth.set(q.len() as i64);
        }
        self.shared.ready.notify_one();
        rx.recv()
            .unwrap_or_else(|_| Response::Error("server stopped".to_string()))
    }

    /// Convenience: blocking predict.
    pub fn predict(&self, coords: Vec<u32>) -> Result<f32, String> {
        match self.call(Request::Predict { coords }) {
            Response::Predict(v) => Ok(v),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Convenience: blocking top-K completion.
    pub fn topk(&self, coords: Vec<u32>, mode: usize, k: usize) -> Result<Vec<Scored>, String> {
        match self.call(Request::TopK { coords, mode, k }) {
            Response::TopK(v) => Ok(v),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Convenience: epoch tag of the snapshot that answers next.
    pub fn epoch(&self) -> Result<u64, String> {
        match self.call(Request::Epoch) {
            Response::Epoch(e) => Ok(e),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }

    /// Convenience: blocking telemetry snapshot.
    pub fn stats(&self) -> Result<MetricsSnapshot, String> {
        match self.call(Request::Stats) {
            Response::Stats(s) => Ok(s),
            Response::Error(e) => Err(e),
            other => Err(format!("unexpected response {other:?}")),
        }
    }
}

fn worker_loop(shared: &Shared, max_batch: usize) {
    let mut engine = Engine::with_policy(shared.snapshot.read().unwrap().clone(), shared.policy);
    let mut batch: Vec<Job> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready.wait(q).unwrap();
            }
            let take = q.len().min(max_batch);
            batch.extend(q.drain(..take));
            shared.obs.queue_depth.set(q.len() as i64);
        }
        // one snapshot per batch: internally consistent, O(1) refresh
        let current = shared.snapshot.read().unwrap().clone();
        if !ModelSnapshot::ptr_eq(engine.snapshot(), &current) {
            engine.swap(current);
        }
        shared.batches.fetch_add(1, Ordering::SeqCst);
        shared.obs.batches.inc();
        shared.obs.batch_size.record(batch.len() as u64);
        for (req, reply) in batch.drain(..) {
            let t0 = Instant::now();
            let resp = process(&mut engine, shared, &req);
            shared.obs.latency(&req).record_duration(t0.elapsed());
            shared.obs.requests.inc();
            if matches!(resp, Response::Error(_)) {
                shared.obs.errors.inc();
            }
            shared.served.fetch_add(1, Ordering::SeqCst);
            // a client that gave up on the call just drops its receiver
            let _ = reply.send(resp);
        }
    }
}

/// Validate `coords` against the snapshot shape; `free_mode` exempts one
/// slot from the bounds check (top-K ignores it).  Shared by the serving
/// workers and the CLI `query` path so validation can't drift.
pub fn check_coords(
    snap: &ModelSnapshot,
    coords: &[u32],
    free_mode: Option<usize>,
) -> Result<(), String> {
    if coords.len() != snap.order() {
        return Err(format!(
            "expected {} coordinates, got {}",
            snap.order(),
            coords.len()
        ));
    }
    for (m, (&c, &d)) in coords.iter().zip(snap.dims()).enumerate() {
        if Some(m) != free_mode && c >= d {
            return Err(format!(
                "coordinate {c} out of bounds for mode {m} (dim {d})"
            ));
        }
    }
    Ok(())
}

fn process(engine: &mut Engine, shared: &Shared, req: &Request) -> Response {
    match req {
        Request::Predict { coords } => match check_coords(engine.snapshot(), coords, None) {
            Ok(()) => Response::Predict(engine.predict(coords)),
            Err(e) => Response::Error(e),
        },
        Request::TopK { coords, mode, k } => {
            if *mode >= engine.snapshot().order() {
                return Response::Error(format!("mode {mode} out of range"));
            }
            match check_coords(engine.snapshot(), coords, Some(*mode)) {
                Ok(()) => Response::TopK(mode_topk(engine, coords, *mode, *k)),
                Err(e) => Response::Error(e),
            }
        }
        Request::Epoch => Response::Epoch(engine.snapshot().epoch()),
        Request::Stats => Response::Stats(shared.metrics.snapshot()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Algo;
    use crate::model::TuckerModel;

    fn snapshot(seed: u64, epoch: u64) -> ModelSnapshot {
        let m = TuckerModel::init(&[8, 10, 12], 16, 16, seed);
        ModelSnapshot::from_model(&m, Algo::Plus, epoch)
    }

    #[test]
    fn serves_and_validates() {
        let snap = snapshot(1, 0);
        let eng = Engine::new(snap.clone());
        let server = Server::start(snap, 2, 4);
        let h = server.handle();
        assert_eq!(h.predict(vec![1, 2, 3]).unwrap(), eng.predict(&[1, 2, 3]));
        assert!(h.predict(vec![1, 2]).is_err()); // wrong arity
        assert!(h.predict(vec![1, 99, 3]).is_err()); // out of bounds
        assert!(h.topk(vec![1, 0, 3], 7, 5).is_err()); // bad mode
        let top = h.topk(vec![1, 0, 3], 1, 5).unwrap();
        assert_eq!(top.len(), 5);
        assert_eq!(h.epoch().unwrap(), 0);
        let stats = server.shutdown();
        assert_eq!(stats.served, 6);
        assert!(stats.batches >= 1);
    }

    #[test]
    fn publish_is_visible_to_later_calls() {
        let server = Server::start(snapshot(1, 0), 1, 8);
        let h = server.handle();
        assert_eq!(h.epoch().unwrap(), 0);
        server.publish(snapshot(2, 7));
        assert_eq!(h.epoch().unwrap(), 7);
        assert_eq!(server.epoch(), 7);
        let stats = server.shutdown();
        assert_eq!(stats.swaps, 1);
    }

    #[test]
    fn simd_policy_server_predicts_exactly() {
        let snap = snapshot(4, 0);
        let eng = Engine::new(snap.clone());
        let server = Server::start_with_policy(snap, 1, 4, KernelPolicy::Simd);
        let h = server.handle();
        // predict is policy-independent: bit-identical to the exact engine
        assert_eq!(h.predict(vec![1, 2, 3]).unwrap(), eng.predict(&[1, 2, 3]));
        assert_eq!(h.topk(vec![1, 0, 3], 1, 5).unwrap().len(), 5);
        server.shutdown();
    }

    #[test]
    fn stats_request_reports_latency_histograms() {
        let server = Server::start(snapshot(5, 0), 2, 4);
        let h = server.handle();
        for i in 0..20u32 {
            h.predict(vec![i % 8, 0, 0]).unwrap();
        }
        h.topk(vec![1, 0, 3], 1, 3).unwrap();
        let snap = h.stats().unwrap();
        // every prior request was counted before its reply was sent
        assert_eq!(snap.counters["serve.requests"], 21);
        assert_eq!(snap.counters["serve.errors"], 0);
        let lat = &snap.hists["serve.latency.predict"];
        assert_eq!(lat.count(), 20);
        let (p50, p95, p99) = (lat.quantile(50.0), lat.quantile(95.0), lat.quantile(99.0));
        assert!(
            p50 > 0 && p50 <= p95 && p95 <= p99,
            "non-monotone latency quantiles: p50={p50} p95={p95} p99={p99}"
        );
        assert_eq!(snap.hists["serve.latency.topk"].count(), 1);
        assert!(snap.hists["serve.batch_size"].count() > 0);
        // the direct (no queue round-trip) snapshot sees at least as much
        let direct = server.metrics_snapshot();
        assert!(direct.counters["serve.requests"] >= snap.counters["serve.requests"]);
        // the Stats round-trips count toward the legacy served counter too
        let stats = server.shutdown();
        assert_eq!(stats.served, 22);
    }

    #[test]
    fn calls_after_shutdown_fail_cleanly() {
        let server = Server::start(snapshot(3, 0), 2, 4);
        let h = server.handle();
        assert!(h.predict(vec![0, 0, 0]).is_ok());
        server.shutdown();
        assert!(h.predict(vec![0, 0, 0]).is_err());
        assert!(h.epoch().is_err());
    }
}
