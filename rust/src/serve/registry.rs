//! Model registry: named, versioned snapshots with atomic promote/rollback.
//!
//! A [`Registry`] maps model *names* to ordered sets of *versions*, each an
//! immutable [`ModelSnapshot`].  Exactly one version per name is **active**
//! (the one that answers queries naming that model) and one name may be the
//! **default** (the one that answers queries naming no model).  The whole
//! table lives behind a single `RwLock`, and a snapshot is one `Arc`, so
//! [`Registry::resolve`] on the hot path is a read lock plus a pointer
//! clone — promote/rollback are short write-locked pointer swaps, and a
//! reader can never observe a half-updated model (the same torn-read-free
//! argument as [`super::Server::publish`], pinned by
//! `tests/serve_net.rs`).
//!
//! Every inserted version is stamped with a registry-wide monotonically
//! increasing **generation** id.  Generations — not `Arc` pointers, which
//! the allocator can reuse — key the cross-request
//! [`super::CompletionCache`], so promoting a new version implicitly
//! invalidates cached invariants without any flush protocol.
//!
//! Lifecycle (mirrored by the CLI `registry` subcommand and the wire
//! `promote`/`rollback`/`load`/`list` ops):
//!
//! ```text
//! insert "m" v1 ── first version auto-activates ──► active=v1
//! insert "m" v2 ── staged, not serving ──────────► active=v1
//! promote "m" (v2) ──────────────────────────────► active=v2, previous=v1
//! rollback "m" ──────────────────────────────────► active=v1, previous=v2
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::json::{arr, num, obj, s, Json};

use super::snapshot::ModelSnapshot;

/// One version slot: the snapshot plus its registry-wide generation tag.
struct Versioned {
    snap: ModelSnapshot,
    generation: u64,
}

/// All versions of one named model.
struct Entry {
    /// Version number → snapshot (BTreeMap keeps them ordered, so
    /// "latest" is `last_key_value`).
    versions: BTreeMap<u64, Versioned>,
    /// The version currently answering queries for this name.
    active: u64,
    /// The version `rollback` returns to (the previously active one).
    previous: Option<u64>,
}

#[derive(Default)]
struct State {
    models: BTreeMap<String, Entry>,
    /// The name `resolve(None)` routes to.
    default: Option<String>,
}

/// A concurrent name → versioned-snapshot table with atomic
/// promote/rollback; see the module docs for the lifecycle.
#[derive(Default)]
pub struct Registry {
    state: RwLock<State>,
    /// Next generation id (stamped onto every inserted version).
    generation: AtomicU64,
}

/// A point-in-time description of one registered model, as reported by
/// [`Registry::list`] and the wire `list` op.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelInfo {
    /// Model name.
    pub name: String,
    /// All registered version numbers, ascending.
    pub versions: Vec<u64>,
    /// The version currently answering queries.
    pub active: u64,
    /// The version `rollback` would restore, if any.
    pub previous: Option<u64>,
    /// Whether unnamed queries route here.
    pub is_default: bool,
    /// Epoch tag of the active snapshot.
    pub epoch: u64,
    /// Tensor dims of the active snapshot (needed by remote load
    /// generators to build valid coordinates).
    pub dims: Vec<u32>,
    /// Parameter count of the active snapshot.
    pub params: usize,
}

impl ModelInfo {
    /// JSON object form (crosses the wire in `list` replies).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            (
                "versions",
                arr(self.versions.iter().map(|&v| num(v as f64)).collect()),
            ),
            ("active", num(self.active as f64)),
            (
                "previous",
                match self.previous {
                    Some(v) => num(v as f64),
                    None => Json::Null,
                },
            ),
            ("default", Json::Bool(self.is_default)),
            ("epoch", num(self.epoch as f64)),
            (
                "dims",
                arr(self.dims.iter().map(|&d| num(d as f64)).collect()),
            ),
            ("params", num(self.params as f64)),
        ])
    }

    /// Decode the [`ModelInfo::to_json`] form.
    pub fn from_json(v: &Json) -> Result<ModelInfo, String> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or("model info missing name")?
            .to_string();
        let field_u64 = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Json::as_usize)
                .map(|u| u as u64)
                .ok_or_else(|| format!("model info {name:?}: bad field {key:?}"))
        };
        let versions = v
            .get("versions")
            .and_then(Json::as_arr)
            .ok_or("model info missing versions")?
            .iter()
            .map(|j| j.as_usize().map(|u| u as u64))
            .collect::<Option<Vec<u64>>>()
            .ok_or("model info: non-integer version")?;
        let previous = match v.get("previous") {
            None | Some(Json::Null) => None,
            Some(j) => Some(j.as_usize().ok_or("model info: bad previous")? as u64),
        };
        let dims = v
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or("model info missing dims")?
            .iter()
            .map(|j| j.as_usize().map(|u| u as u32))
            .collect::<Option<Vec<u32>>>()
            .ok_or("model info: non-integer dim")?;
        Ok(ModelInfo {
            versions,
            active: field_u64("active")?,
            previous,
            is_default: v.get("default").and_then(Json::as_bool).unwrap_or(false),
            epoch: field_u64("epoch")?,
            dims,
            params: field_u64("params")? as usize,
            name,
        })
    }
}

impl Registry {
    /// A fresh, empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// A fresh registry behind an `Arc`, ready to share with a server.
    pub fn shared() -> Arc<Registry> {
        Arc::new(Registry::new())
    }

    /// Register `snap` as the next version of `name` (1 for a new name)
    /// and return that version number.  The first version of a name
    /// auto-activates, and the first name registered becomes the default;
    /// later versions are *staged* — they serve only after
    /// [`Registry::promote`].
    pub fn insert(&self, name: &str, snap: ModelSnapshot) -> u64 {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let mut st = self.state.write().unwrap();
        if st.default.is_none() {
            st.default = Some(name.to_string());
        }
        let entry = st.models.entry(name.to_string()).or_insert_with(|| Entry {
            versions: BTreeMap::new(),
            active: 0,
            previous: None,
        });
        let version = entry.versions.last_key_value().map_or(1, |(&v, _)| v + 1);
        entry.versions.insert(version, Versioned { snap, generation });
        if entry.active == 0 {
            entry.active = version;
        }
        version
    }

    /// Insert *and* activate in one write-locked step — the live-training
    /// publish path ([`crate::session::Session::run_with_registry`]), where
    /// every snapshot should serve immediately.  Returns the new version.
    pub fn publish(&self, name: &str, snap: ModelSnapshot) -> u64 {
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let mut st = self.state.write().unwrap();
        if st.default.is_none() {
            st.default = Some(name.to_string());
        }
        let entry = st.models.entry(name.to_string()).or_insert_with(|| Entry {
            versions: BTreeMap::new(),
            active: 0,
            previous: None,
        });
        let version = entry.versions.last_key_value().map_or(1, |(&v, _)| v + 1);
        entry.versions.insert(version, Versioned { snap, generation });
        if entry.active != 0 && entry.active != version {
            entry.previous = Some(entry.active);
        }
        entry.active = version;
        version
    }

    /// Activate `version` of `name` (the latest version when `None`),
    /// remembering the outgoing active version for [`Registry::rollback`].
    /// Returns the now-active version.
    pub fn promote(&self, name: &str, version: Option<u64>) -> Result<u64, String> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .models
            .get_mut(name)
            .ok_or_else(|| format!("unknown model {name:?}"))?;
        let target = match version {
            Some(v) => {
                if !entry.versions.contains_key(&v) {
                    return Err(format!("model {name:?} has no version {v}"));
                }
                v
            }
            None => *entry.versions.last_key_value().unwrap().0,
        };
        if target != entry.active {
            entry.previous = Some(entry.active);
            entry.active = target;
        }
        Ok(target)
    }

    /// Swap the active version back to the previously active one (so a
    /// second rollback undoes the first).  Errors when nothing was ever
    /// promoted over the original version.
    pub fn rollback(&self, name: &str) -> Result<u64, String> {
        let mut st = self.state.write().unwrap();
        let entry = st
            .models
            .get_mut(name)
            .ok_or_else(|| format!("unknown model {name:?}"))?;
        let prev = entry
            .previous
            .ok_or_else(|| format!("model {name:?} has no previous version to roll back to"))?;
        entry.previous = Some(entry.active);
        entry.active = prev;
        Ok(prev)
    }

    /// Route unnamed queries to `name`.
    pub fn set_default(&self, name: &str) -> Result<(), String> {
        let mut st = self.state.write().unwrap();
        if !st.models.contains_key(name) {
            return Err(format!("unknown model {name:?}"));
        }
        st.default = Some(name.to_string());
        Ok(())
    }

    /// The active snapshot for `name` (or the default model when `None`),
    /// plus its generation tag for cache keying.  One read lock + one
    /// `Arc` clone: the returned snapshot is immutable, so concurrent
    /// promotes can never tear it.
    pub fn resolve(&self, name: Option<&str>) -> Result<(ModelSnapshot, u64), String> {
        let st = self.state.read().unwrap();
        let name = match name {
            Some(n) => n,
            None => st
                .default
                .as_deref()
                .ok_or("registry is empty (no default model)")?,
        };
        let entry = st
            .models
            .get(name)
            .ok_or_else(|| format!("unknown model {name:?}"))?;
        let v = &entry.versions[&entry.active];
        Ok((v.snap.clone(), v.generation))
    }

    /// Describe every registered model (sorted by name).
    pub fn list(&self) -> Vec<ModelInfo> {
        let st = self.state.read().unwrap();
        st.models
            .iter()
            .map(|(name, entry)| {
                let active = &entry.versions[&entry.active].snap;
                ModelInfo {
                    name: name.clone(),
                    versions: entry.versions.keys().copied().collect(),
                    active: entry.active,
                    previous: entry.previous,
                    is_default: st.default.as_deref() == Some(name),
                    epoch: active.epoch(),
                    dims: active.dims().to_vec(),
                    params: active.param_count(),
                }
            })
            .collect()
    }

    /// Number of registered model names.
    pub fn len(&self) -> usize {
        self.state.read().unwrap().models.len()
    }

    /// True when no model has been registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Algo;
    use crate::model::TuckerModel;

    fn snap(seed: u64, epoch: u64) -> ModelSnapshot {
        let m = TuckerModel::init(&[6, 7, 8], 8, 8, seed);
        ModelSnapshot::from_model(&m, Algo::Plus, epoch)
    }

    #[test]
    fn insert_promote_rollback_lifecycle() {
        let reg = Registry::new();
        assert!(reg.resolve(None).is_err());
        assert_eq!(reg.insert("m", snap(1, 10)), 1);
        assert_eq!(reg.resolve(None).unwrap().0.epoch(), 10); // auto-active + default
        assert_eq!(reg.insert("m", snap(2, 20)), 2);
        // staged: v2 does not serve until promoted
        assert_eq!(reg.resolve(Some("m")).unwrap().0.epoch(), 10);
        assert_eq!(reg.promote("m", None).unwrap(), 2);
        assert_eq!(reg.resolve(Some("m")).unwrap().0.epoch(), 20);
        assert_eq!(reg.rollback("m").unwrap(), 1);
        assert_eq!(reg.resolve(Some("m")).unwrap().0.epoch(), 10);
        // rollback is its own inverse
        assert_eq!(reg.rollback("m").unwrap(), 2);
        assert_eq!(reg.resolve(Some("m")).unwrap().0.epoch(), 20);
    }

    #[test]
    fn publish_activates_immediately() {
        let reg = Registry::new();
        reg.publish("live", snap(1, 1));
        reg.publish("live", snap(2, 2));
        assert_eq!(reg.resolve(Some("live")).unwrap().0.epoch(), 2);
        // and the outgoing version is the rollback target
        assert_eq!(reg.rollback("live").unwrap(), 1);
        assert_eq!(reg.resolve(Some("live")).unwrap().0.epoch(), 1);
    }

    #[test]
    fn generations_are_unique_across_names_and_versions() {
        let reg = Registry::new();
        reg.insert("a", snap(1, 0));
        reg.insert("b", snap(2, 0));
        reg.insert("a", snap(3, 0));
        reg.promote("a", Some(2)).unwrap();
        let ga = reg.resolve(Some("a")).unwrap().1;
        let gb = reg.resolve(Some("b")).unwrap().1;
        reg.rollback("a").unwrap();
        let ga1 = reg.resolve(Some("a")).unwrap().1;
        assert!(ga != gb && ga != ga1 && gb != ga1);
    }

    #[test]
    fn errors_are_explicit() {
        let reg = Registry::new();
        reg.insert("m", snap(1, 0));
        assert!(reg.promote("nope", None).is_err());
        assert!(reg.promote("m", Some(9)).is_err());
        assert!(reg.rollback("m").is_err()); // nothing ever promoted over v1
        assert!(reg.resolve(Some("nope")).is_err());
        assert!(reg.set_default("nope").is_err());
    }

    #[test]
    fn list_and_default_routing() {
        let reg = Registry::new();
        reg.insert("a", snap(1, 5));
        reg.insert("b", snap(2, 6));
        reg.insert("b", snap(3, 7));
        reg.promote("b", None).unwrap();
        let infos = reg.list();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "a");
        assert!(infos[0].is_default);
        assert_eq!(infos[1].versions, vec![1, 2]);
        assert_eq!(infos[1].active, 2);
        assert_eq!(infos[1].previous, Some(1));
        assert_eq!(infos[1].epoch, 7);
        assert_eq!(infos[1].dims, vec![6, 7, 8]);
        // JSON round-trip of the listing rows
        for info in &infos {
            assert_eq!(&ModelInfo::from_json(&info.to_json()).unwrap(), info);
        }
        reg.set_default("b").unwrap();
        assert_eq!(reg.resolve(None).unwrap().0.epoch(), 7);
    }
}
