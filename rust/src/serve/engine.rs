//! Batched prediction engine over a published [`ModelSnapshot`].
//!
//! Two scoring paths, mirroring the trainer's calc-vs-store split:
//!
//! * [`Engine::predict`] — the *full product chain*: for each Kruskal rank
//!   `r`, multiply the stored projection rows `C^(m)[i_m, r]` in ascending
//!   mode order and sum over `r`.  This is exactly the arithmetic sequence
//!   of the scalar oracle's `forward` (projection rows are built in the
//!   same accumulation order by [`crate::kernel::prim`], the chain is the
//!   oracle's prefix product, the sum is ascending), so serve predictions
//!   are **bit-identical** to the trainer's evaluation path — pinned by
//!   `tests/serve.rs` — under *every* kernel policy.
//! * [`Engine::complete_mode`] — the *mode-completion* (recommender)
//!   workload: given all-but-one coordinates, compute the exclusion
//!   product `d = Π_{m≠mode} C^(m)[i_m, :]` **once** (the
//!   `InvariantCache`-style fiber invariant: a batch of queries sharing a
//!   user fiber shares this product), then score every candidate index of
//!   the free mode with one R-wide dot against its stored row — the same
//!   per-sample math as the storage-scheme training kernels.
//!
//! The engine owns only scratch (one R-wide product) on top of the
//! snapshot handle, so serving workers build one per batch and swap
//! snapshots in O(1) on hot-swap.
//!
//! [`Engine::with_policy`] selects the arithmetic tier for the *bulk*
//! paths (`exclusion` / `complete_mode` candidate scoring):
//! [`KernelPolicy::Simd`] routes them through the runtime-dispatched SIMD
//! layer (the exclusion product stays bit-exact — elementwise multiplies
//! don't re-round — while candidate dots are tolerance-bounded); any other
//! policy takes the exact [`crate::kernel::prim`] path.  `predict` /
//! `rmse_mae` ignore the policy entirely, keeping the bit-identity
//! contract with the trainer's evaluation unconditional.

use crate::kernel::{prim, simd, KernelPolicy};
use crate::tensor::SparseTensor;

use super::snapshot::ModelSnapshot;

/// Widest Kruskal rank served by the stack-allocated accumulator in
/// [`Engine::predict`] (covers every monomorphized kernel shape).
const MAX_STACK_R: usize = 64;

/// Stateless-per-query scorer bound to one immutable snapshot.
pub struct Engine {
    snap: ModelSnapshot,
    /// Scratch for the fiber-shared exclusion product (length R).
    d: Vec<f32>,
    /// Arithmetic tier for the bulk paths (exclusion / candidate scoring).
    policy: KernelPolicy,
}

impl Engine {
    /// Bind an engine to a snapshot (allocates only the R-wide scratch).
    /// Uses the exact kernel tier; see [`Engine::with_policy`].
    pub fn new(snap: ModelSnapshot) -> Engine {
        Engine::with_policy(snap, KernelPolicy::Tiled)
    }

    /// Bind an engine with an explicit kernel policy for the bulk scoring
    /// paths.  [`KernelPolicy::Simd`] uses the runtime-dispatched SIMD
    /// layer for `exclusion` / `complete_mode`; `Tiled` and `Scalar` both
    /// take the exact path (they are bit-identical here).  `predict` is
    /// policy-independent.
    pub fn with_policy(snap: ModelSnapshot, policy: KernelPolicy) -> Engine {
        let r = snap.r();
        Engine {
            snap,
            d: vec![0f32; r],
            policy,
        }
    }

    /// The snapshot this engine currently scores against.
    pub fn snapshot(&self) -> &ModelSnapshot {
        &self.snap
    }

    /// The kernel policy governing the bulk scoring paths.
    pub fn policy(&self) -> KernelPolicy {
        self.policy
    }

    /// Swap in a newer snapshot (O(1): an `Arc` move; scratch is resized
    /// only if R changed).
    pub fn swap(&mut self, snap: ModelSnapshot) {
        self.d.resize(snap.r(), 0.0);
        self.snap = snap;
    }

    /// Predict one entry: `Σ_r Π_m C^(m)[i_m, r]`, ascending mode order,
    /// ascending rank sum — bit-identical to the trainer's scalar
    /// evaluation (`cpu_ref::forward`) and to [`crate::model::TuckerModel::predict_one`].
    ///
    /// Mode-outer with an R-wide accumulator (one contiguous row read per
    /// mode); per rank the multiply chain and the final sum are the exact
    /// sequences of the rank-outer formulation, so the layouts are
    /// interchangeable bit-for-bit and this one vectorizes.
    pub fn predict(&self, coords: &[u32]) -> f32 {
        let n = self.snap.order();
        let r = self.snap.r();
        // a real check, not debug_assert: this is a public API boundary,
        // and in release a short slice would silently read wrong factor
        // rows (the wire path validates earlier via check_coords, but
        // in-process callers land here directly)
        assert_eq!(
            coords.len(),
            n,
            "predict needs one coordinate per mode (got {}, model order {n})",
            coords.len()
        );
        if r <= MAX_STACK_R {
            let mut acc = [1.0f32; MAX_STACK_R];
            for (m, &c) in coords.iter().enumerate() {
                let row = self.snap.c_row(m, c as usize);
                for (a, &v) in acc[..r].iter_mut().zip(row) {
                    *a *= v;
                }
            }
            acc[..r].iter().sum()
        } else {
            // rank-outer fallback for ranks past the stack accumulator
            let mut acc = 0f32;
            for rr in 0..r {
                let mut p = 1f32;
                for m in 0..n {
                    p *= self.snap.c_row(m, coords[m] as usize)[rr];
                }
                acc += p;
            }
            acc
        }
    }

    /// Predict a flat batch (`[Q, N]` entry-major coordinates), appending
    /// into `out`.
    pub fn predict_batch(&self, coords: &[u32], out: &mut Vec<f32>) {
        let n = self.snap.order();
        assert_eq!(
            coords.len() % n,
            0,
            "batch coords length {} is not a multiple of the model order {n}",
            coords.len()
        );
        out.reserve(coords.len() / n);
        for q in coords.chunks_exact(n) {
            out.push(self.predict(q));
        }
    }

    /// Compute the fiber-shared exclusion product
    /// `d = Π_{m≠mode} C^(m)[i_m, :]` into the engine scratch (ascending
    /// mode order, exactly like the storage-scheme training kernels and
    /// [`crate::kernel::InvariantCache`]), and return it.
    pub fn exclusion(&mut self, coords: &[u32], mode: usize) -> &[f32] {
        let n = self.snap.order();
        let simd_on = self.policy == KernelPolicy::Simd;
        self.d.fill(1.0);
        for m in 0..n {
            if m == mode {
                continue;
            }
            let crow = self.snap.c_row(m, coords[m] as usize);
            // elementwise: the SIMD lane is bit-identical to the scalar one
            if simd_on {
                simd::mul_in(&mut self.d, crow);
            } else {
                prim::mul_in(&mut self.d, crow);
            }
        }
        &self.d
    }

    /// Mode-completion scoring: with every coordinate fixed except `mode`
    /// (the slot at `mode` is ignored), score **all** `I_mode` candidate
    /// indices.  The exclusion product is computed once for the whole
    /// candidate sweep — the shared-invariant reuse that makes batched
    /// per-user recommendation cheap.  Scores are appended to `scores`.
    pub fn complete_mode(&mut self, coords: &[u32], mode: usize, scores: &mut Vec<f32>) {
        self.exclusion(coords, mode);
        self.score_candidates(mode, &self.d, scores)
    }

    /// The candidate sweep half of [`Engine::complete_mode`]: score every
    /// candidate index of `mode` against an exclusion product `d` computed
    /// earlier (one R-wide dot per candidate, policy-tiered), appending to
    /// `scores`.  Split out so the serving tier's
    /// [`super::CompletionCache`] can replay a cached fiber invariant
    /// without recomputing it — a cached `d` is bit-identical to a fresh
    /// one, so hits and misses score identically.
    pub fn score_candidates(&self, mode: usize, d: &[f32], scores: &mut Vec<f32>) {
        let r = self.snap.r();
        debug_assert_eq!(d.len(), r);
        let rows = self.snap.dims()[mode] as usize;
        scores.reserve(rows);
        let table = self.snap.c_table(mode);
        if self.policy == KernelPolicy::Simd {
            for crow in table.chunks_exact(r) {
                scores.push(simd::dot(crow, d));
            }
        } else {
            for crow in table.chunks_exact(r) {
                scores.push(prim::dot(crow, d));
            }
        }
    }

    /// RMSE / MAE over a test tensor, accumulated in the same entry order
    /// and f64 arithmetic as `cpu_ref::evaluate` — exact-equality
    /// comparable against `Trainer::evaluate` on a CPU backend.
    pub fn rmse_mae(&self, test: &SparseTensor) -> (f64, f64) {
        let mut sse = 0f64;
        let mut sae = 0f64;
        for e in 0..test.nnz() {
            let xhat = self.predict(test.coords(e));
            let err = (test.values[e] - xhat) as f64;
            sse += err * err;
            sae += err.abs();
        }
        let n = test.nnz().max(1) as f64;
        ((sse / n).sqrt(), sae / n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Algo;
    use crate::model::TuckerModel;

    fn engine() -> (TuckerModel, Engine) {
        let m = TuckerModel::init(&[9, 11, 13], 16, 16, 77);
        let snap = ModelSnapshot::from_model(&m, Algo::Plus, 0);
        (m, Engine::new(snap))
    }

    #[test]
    fn predict_matches_model_predict_one() {
        let (m, eng) = engine();
        for coords in [[0u32, 0, 0], [8, 10, 12], [3, 7, 5], [1, 2, 3]] {
            assert_eq!(eng.predict(&coords), m.predict_one(&coords));
        }
    }

    #[test]
    fn predict_batch_matches_singles() {
        let (_, eng) = engine();
        let coords: Vec<u32> = vec![0, 1, 2, 3, 4, 5, 8, 10, 12];
        let mut out = Vec::new();
        eng.predict_batch(&coords, &mut out);
        assert_eq!(out.len(), 3);
        for (q, &got) in coords.chunks_exact(3).zip(&out) {
            assert_eq!(got, eng.predict(q));
        }
    }

    #[test]
    fn completion_scores_match_stored_scheme_prediction() {
        let (_, mut eng) = engine();
        let coords = [4u32, 0, 6]; // slot 1 is the free mode, value ignored
        let mut scores = Vec::new();
        eng.complete_mode(&coords, 1, &mut scores);
        assert_eq!(scores.len(), 11);
        // independent scalar scorer: d recomputed per candidate
        let snap = eng.snapshot().clone();
        let r = snap.r();
        for (i, &got) in scores.iter().enumerate() {
            let mut d = vec![1f32; r];
            for m in [0usize, 2] {
                let crow = snap.c_row(m, coords[m] as usize);
                for rr in 0..r {
                    d[rr] *= crow[rr];
                }
            }
            let want = prim::dot(snap.c_row(1, i), &d);
            assert_eq!(got, want, "candidate {i}");
        }
    }

    #[test]
    fn simd_policy_tracks_exact_completion_within_tolerance() {
        let m = TuckerModel::init(&[9, 11, 13], 16, 16, 77);
        let snap = ModelSnapshot::from_model(&m, Algo::Plus, 0);
        let mut exact = Engine::new(snap.clone());
        let mut simd_eng = Engine::with_policy(snap, KernelPolicy::Simd);
        assert_eq!(simd_eng.policy(), KernelPolicy::Simd);
        let coords = [4u32, 0, 6];
        // predict is policy-independent: bit-identical under Simd
        assert_eq!(simd_eng.predict(&coords), exact.predict(&coords));
        // the exclusion product is elementwise, hence bit-identical too
        let de: Vec<f32> = exact.exclusion(&coords, 1).to_vec();
        let ds: Vec<f32> = simd_eng.exclusion(&coords, 1).to_vec();
        assert_eq!(de, ds);
        // candidate dots re-associate: tolerance-bounded
        let (mut se, mut ss) = (Vec::new(), Vec::new());
        exact.complete_mode(&coords, 1, &mut se);
        simd_eng.complete_mode(&coords, 1, &mut ss);
        assert_eq!(se.len(), ss.len());
        for (i, (&a, &b)) in se.iter().zip(&ss).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs()),
                "candidate {i}: exact {a} vs simd {b}"
            );
        }
    }

    #[test]
    fn swap_rebinds_snapshot() {
        let (_, mut eng) = engine();
        let before = eng.predict(&[1, 1, 1]);
        let other = TuckerModel::init(&[9, 11, 13], 16, 16, 78);
        eng.swap(ModelSnapshot::from_model(&other, Algo::Plus, 5));
        assert_eq!(eng.snapshot().epoch(), 5);
        assert_ne!(eng.predict(&[1, 1, 1]), before);
    }
}
