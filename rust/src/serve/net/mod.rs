//! The network serving tier: TCP front end, wire protocol, client, and
//! SLO load harness.
//!
//! Five pieces, one wire:
//!
//! * [`wire`] — the newline-delimited JSON frame protocol (request /
//!   response grammar, error codes, bit-exact float encoding).
//! * [`frame`] — the shared socket framing discipline (poll-loop
//!   connection primitives, bounded blocking line reader, checksummed
//!   binary payload frames, single-writer frame writer); also the
//!   transport substrate for `crate::dist::net`.
//! * [`server`] — [`NetServer`]: a std-only non-blocking front end (one
//!   poll thread multiplexing every connection + N scoring workers) with
//!   bounded-queue admission control ([`Response::Overloaded`] sheds),
//!   per-request deadlines ([`Response::DeadlineExceeded`]), registry
//!   admin ops over the wire, and a graceful drain that answers every
//!   accepted request before exiting.
//! * [`client`] — [`NetClient`]: a blocking connection speaking the same
//!   frames, with strict call and pipelined send/recv APIs.
//! * [`slo`] — [`run_slo`]: the closed-loop load harness that walks an
//!   offered-QPS ladder against a live server and reports
//!   p50/p95/p99/shed per step (`fasttucker slo`, `benches/serve_slo`).
//!
//! [`Response::Overloaded`]: super::Response::Overloaded
//! [`Response::DeadlineExceeded`]: super::Response::DeadlineExceeded

pub mod client;
pub mod frame;
pub mod server;
pub mod slo;
pub mod wire;

pub use client::NetClient;
pub use server::{NetConfig, NetHandler, NetServer, NetServerHandle, NetStats, RegistryHandler};
pub use slo::{run_slo, slo_header, SloConfig, SloRow};
pub use wire::{NetRequest, NetResponse};
