//! A blocking client for the serving wire protocol.
//!
//! One [`NetClient`] owns one TCP connection.  The simple API
//! ([`NetClient::call`] and the admin helpers) is strictly
//! request/response; the split [`NetClient::send`] / [`NetClient::recv`]
//! pair pipelines — the SLO harness keeps a window of requests in flight
//! per connection and correlates replies by id, which the protocol
//! permits explicitly (responses may arrive out of order).
//!
//! Every connection is bounded by a socket read/write timeout
//! ([`DEFAULT_TIMEOUT`], 30 s) so a stalled or half-dead server errors
//! loudly instead of wedging the caller; `--timeout-ms` on the CLI and
//! [`NetClient::set_timeout`] tune it.  The distributed TCP worker
//! (`crate::dist::net`) applies the same mechanism to its coordinator
//! connection.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::super::registry::ModelInfo;
use super::super::server::{Request, Response};
use super::frame::{self, is_timeout};
use super::wire::{self, NetRequest, NetResponse};

/// Default socket read/write timeout.  A stalled or half-dead server
/// surfaces as a loud timeout error after this long instead of wedging
/// the caller forever; override per-call-site with
/// [`NetClient::connect_with_timeout`] or [`NetClient::set_timeout`]
/// (`--timeout-ms` on the CLI).
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// Response frames longer than this are a protocol violation.
const MAX_FRAME_BYTES: usize = 1 << 20;

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    next_id: u64,
}

impl NetClient {
    /// Connect to `addr` (e.g. `127.0.0.1:7171`) with the
    /// [`DEFAULT_TIMEOUT`] bounding every read and write.
    pub fn connect(addr: &str) -> Result<NetClient> {
        Self::connect_with_timeout(addr, Some(DEFAULT_TIMEOUT))
    }

    /// Connect with an explicit socket timeout (`None` blocks forever —
    /// only sensible for tests that control both ends).
    pub fn connect_with_timeout(addr: &str, timeout: Option<Duration>) -> Result<NetClient> {
        let writer = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let _ = writer.set_nodelay(true);
        let reader = BufReader::new(writer.try_clone().context("cloning the socket")?);
        let mut client = NetClient {
            writer,
            reader,
            next_id: 0,
        };
        client.set_timeout(timeout)?;
        Ok(client)
    }

    /// Bound every read *and* write with a timeout (`None` blocks
    /// forever).
    pub fn set_timeout(&mut self, dur: Option<Duration>) -> Result<()> {
        self.set_read_timeout(dur)?;
        self.writer
            .set_write_timeout(dur)
            .context("setting the write timeout")?;
        Ok(())
    }

    /// Bound every read with a timeout (`None` blocks forever).
    pub fn set_read_timeout(&mut self, dur: Option<Duration>) -> Result<()> {
        self.reader
            .get_ref()
            .set_read_timeout(dur)
            .context("setting the read timeout")?;
        Ok(())
    }

    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    fn write_frame(&mut self, frame: &str) -> Result<()> {
        self.writer
            .write_all(frame.as_bytes())
            .and_then(|()| self.writer.write_all(b"\n"))
            .map_err(|e| {
                if is_timeout(&e) {
                    anyhow::anyhow!("timed out writing a frame (server not reading?)")
                } else {
                    anyhow::Error::new(e).context("writing a frame")
                }
            })?;
        Ok(())
    }

    /// Send one request frame without waiting for its reply; returns the
    /// correlation id to match against [`NetClient::recv`] frames.
    pub fn send(
        &mut self,
        model: Option<&str>,
        deadline_ms: Option<u64>,
        req: Request,
    ) -> Result<u64> {
        let id = self.fresh_id();
        self.write_frame(&wire::encode_request(&NetRequest::Call {
            id,
            model: model.map(str::to_string),
            deadline_ms,
            req,
        }))?;
        Ok(id)
    }

    /// Read the next response frame (blocks; `Err` on EOF or timeout —
    /// a socket-timeout expiry surfaces as a distinct "timed out" error).
    pub fn recv(&mut self) -> Result<NetResponse> {
        match frame::read_line_bounded(&mut self.reader, MAX_FRAME_BYTES)? {
            None => bail!("server closed the connection"),
            Some(line) => wire::parse_response(&line).map_err(anyhow::Error::msg),
        }
    }

    /// One strict request/response round trip.  Shed (`Overloaded`) and
    /// expired (`DeadlineExceeded`) outcomes come back as their
    /// [`Response`] variants, not errors — callers decide how to treat
    /// them.
    pub fn call(
        &mut self,
        model: Option<&str>,
        deadline_ms: Option<u64>,
        req: Request,
    ) -> Result<Response> {
        let id = self.send(model, deadline_ms, req)?;
        let frame = self.recv()?;
        wire::into_response(frame, id).map_err(anyhow::Error::msg)
    }

    /// Predict one entry on the server's default (or named) model.
    pub fn predict(&mut self, model: Option<&str>, coords: &[u32]) -> Result<f32> {
        match self.call(
            model,
            None,
            Request::Predict {
                coords: coords.to_vec(),
            },
        )? {
            Response::Predict(v) => Ok(v),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    fn admin(&mut self, req: NetRequest) -> Result<Vec<ModelInfo>> {
        let id = req.id();
        self.write_frame(&wire::encode_request(&req))?;
        match self.recv()? {
            NetResponse::Listing { id: got, models } if got == id => Ok(models),
            NetResponse::Failure { message, code, .. } => bail!("{code}: {message}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Activate a version of `model` (latest when `None`); returns the
    /// post-op registry listing.
    pub fn promote(&mut self, model: &str, version: Option<u64>) -> Result<Vec<ModelInfo>> {
        let id = self.fresh_id();
        self.admin(NetRequest::Promote {
            id,
            model: model.to_string(),
            version,
        })
    }

    /// Swap `model` back to its previously active version.
    pub fn rollback(&mut self, model: &str) -> Result<Vec<ModelInfo>> {
        let id = self.fresh_id();
        self.admin(NetRequest::Rollback {
            id,
            model: model.to_string(),
        })
    }

    /// Load a server-local checkpoint as a new staged version of `model`.
    pub fn load(&mut self, model: &str, path: &str) -> Result<Vec<ModelInfo>> {
        let id = self.fresh_id();
        self.admin(NetRequest::Load {
            id,
            model: model.to_string(),
            path: path.to_string(),
        })
    }

    /// Describe every registered model.
    pub fn list(&mut self) -> Result<Vec<ModelInfo>> {
        let id = self.fresh_id();
        self.admin(NetRequest::List { id })
    }

    /// Send a `shutdown` frame without waiting for the ack; returns its
    /// correlation id.  Pairs with [`NetClient::recv`] when pipelined
    /// requests are still in flight — the drain answers them all, so the
    /// stopping ack may arrive before or after their responses.
    pub fn send_shutdown(&mut self) -> Result<u64> {
        let id = self.fresh_id();
        self.write_frame(&wire::encode_request(&NetRequest::Shutdown { id }))?;
        Ok(id)
    }

    /// Ask the server to drain and exit; returns once acknowledged.
    pub fn shutdown(&mut self) -> Result<()> {
        let id = self.send_shutdown()?;
        match self.recv()? {
            NetResponse::Stopping { id: got } if got == id => Ok(()),
            other => bail!("unexpected reply {other:?}"),
        }
    }
}
