//! Shared socket framing for every wire in the crate.
//!
//! Both network tiers — the serving front end ([`super::server`]) and the
//! distributed TCP transport (`crate::dist::net`) — speak
//! newline-delimited frames over TCP.  This module is the single home for
//! the framing discipline so the two wires cannot drift:
//!
//! * **Non-blocking side** ([`Conn`], [`read_conn`], [`flush_conn`]):
//!   the poll-loop primitives the serving front end multiplexes with.
//!   One buffered connection, split on `\n`, with an unterminated-frame
//!   length bound (hostile peers get dropped, not buffered forever).
//! * **Blocking side** ([`read_line_bounded`]): the same length-sane line
//!   reader for clients and workers that own one socket and can afford to
//!   block (with a socket timeout — see [`is_timeout`]).
//! * **Binary payloads** ([`write_payload`], [`read_payload`]): a
//!   length-prefixed, FNV-1a-checksummed byte frame that the distributed
//!   wire interleaves with its JSON control stream to ship FTM1 model
//!   bytes at barriers without base64 bloat.
//! * **Shared-socket writes** ([`FrameWriter`]): whole-frame writes
//!   serialized behind one lock, preserving the single-writer-per-socket
//!   invariant when more than one thread (heartbeat + round loop) must
//!   speak on a connection.

use std::collections::VecDeque;
use std::io::{self, BufRead, ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::util::fnv::fnv1a;

// -- non-blocking (poll loop) primitives --------------------------------

/// One multiplexed connection: the socket plus its partial-frame input
/// buffer and unflushed output bytes.
pub struct Conn {
    /// The non-blocking socket.
    pub stream: TcpStream,
    /// Bytes read but not yet terminated by `\n`.
    pub inbuf: Vec<u8>,
    /// Bytes queued for the poll thread to flush.
    pub out: VecDeque<u8>,
    /// Peer closed its write side; keep until the outbox flushes.
    pub eof: bool,
}

impl Conn {
    /// Wrap a freshly accepted (already non-blocking) socket.
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            inbuf: Vec::new(),
            out: VecDeque::new(),
            eof: false,
        }
    }

    /// Queue one newline-terminated frame on the outbox.
    pub fn push_frame(&mut self, frame: &str) {
        self.out.extend(frame.as_bytes());
        self.out.push_back(b'\n');
    }
}

/// One poll-loop pass outcome for a connection.
pub enum ConnIo {
    /// Connection is healthy (possibly idle).
    Ok,
    /// Protocol/socket failure: drop the connection now.
    Drop,
}

/// Drain readable bytes from `conn` and split complete `\n`-terminated
/// frames into `frames` (tagged with `cid`).  An unterminated frame
/// longer than `max_frame` bytes is hostile or broken input and drops
/// the connection.
pub fn read_conn(
    conn: &mut Conn,
    max_frame: usize,
    frames: &mut Vec<(u64, String)>,
    cid: u64,
) -> ConnIo {
    let mut buf = [0u8; 4096];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.eof = true;
                break;
            }
            Ok(n) => {
                conn.inbuf.extend_from_slice(&buf[..n]);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return ConnIo::Drop,
        }
    }
    while let Some(pos) = conn.inbuf.iter().position(|&b| b == b'\n') {
        let raw: Vec<u8> = conn.inbuf.drain(..=pos).collect();
        let line = String::from_utf8_lossy(&raw[..raw.len() - 1]).into_owned();
        if !line.trim().is_empty() {
            frames.push((cid, line));
        }
    }
    if conn.inbuf.len() > max_frame {
        // unterminated oversize frame: hostile or broken peer
        return ConnIo::Drop;
    }
    ConnIo::Ok
}

/// Write as much of the outbox as the socket will take without blocking.
pub fn flush_conn(conn: &mut Conn) -> ConnIo {
    while !conn.out.is_empty() {
        let (head, _) = conn.out.as_slices();
        match conn.stream.write(head) {
            Ok(0) => return ConnIo::Drop,
            Ok(n) => {
                conn.out.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return ConnIo::Drop,
        }
    }
    ConnIo::Ok
}

// -- blocking primitives ------------------------------------------------

/// True when an I/O error is a socket-timeout expiry.  Unix reports a
/// timed-out blocking read as `WouldBlock`, Windows as `TimedOut`; both
/// mean the same thing to a caller holding a deadline.
pub fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Read one non-blank `\n`-terminated line, bounding the frame at
/// `max_frame` bytes.  Returns `Ok(None)` on a clean EOF between frames;
/// errors on EOF mid-frame, an oversize frame, or a socket timeout (the
/// timeout surfaces as a distinct, self-explanatory message).
pub fn read_line_bounded<R: BufRead>(r: &mut R, max_frame: usize) -> Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (consumed, done) = {
            let buf = match r.fill_buf() {
                Ok(b) => b,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if is_timeout(&e) => {
                    bail!("timed out waiting for a frame (socket read timeout)")
                }
                Err(e) => return Err(e).context("reading a frame"),
            };
            if buf.is_empty() {
                if line.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-frame");
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    line.extend_from_slice(&buf[..pos]);
                    (pos + 1, true)
                }
                None => {
                    line.extend_from_slice(buf);
                    (buf.len(), false)
                }
            }
        };
        r.consume(consumed);
        if line.len() > max_frame {
            bail!(
                "oversize frame ({} bytes exceeds the {max_frame} byte bound)",
                line.len()
            );
        }
        if done {
            let text = String::from_utf8_lossy(&line).into_owned();
            if text.trim().is_empty() {
                line.clear();
                continue;
            }
            return Ok(Some(text));
        }
    }
}

// -- binary payload frames ----------------------------------------------

/// Byte length of the payload-frame header: `u64` LE payload length then
/// `u64` LE FNV-1a checksum of the payload bytes.
pub const PAYLOAD_HEADER_BYTES: usize = 16;

/// Write one length-prefixed, checksummed binary payload frame.
pub fn write_payload<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(&fnv1a(payload).to_le_bytes())?;
    w.write_all(payload)
}

/// Read one binary payload frame written by [`write_payload`], bounding
/// the payload at `max_bytes` and verifying the FNV-1a checksum.
pub fn read_payload<R: Read>(r: &mut R, max_bytes: usize) -> Result<Vec<u8>> {
    let mut header = [0u8; PAYLOAD_HEADER_BYTES];
    r.read_exact(&mut header).map_err(|e| {
        if is_timeout(&e) {
            anyhow::anyhow!("timed out waiting for a payload frame (socket read timeout)")
        } else {
            anyhow::Error::new(e).context("reading a payload header")
        }
    })?;
    let len = u64::from_le_bytes(header[..8].try_into().unwrap());
    let sum = u64::from_le_bytes(header[8..].try_into().unwrap());
    if len as usize > max_bytes {
        bail!("payload frame of {len} bytes exceeds the {max_bytes} byte bound");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).context("reading a payload")?;
    if fnv1a(&payload) != sum {
        bail!("payload checksum mismatch (corrupt or desynchronized stream)");
    }
    Ok(payload)
}

// -- shared-socket writer ------------------------------------------------

/// A cloneable handle that serializes whole-frame writes on one socket.
///
/// The framing invariant everywhere in this crate is *single writer per
/// socket*: two frames must never interleave mid-line.  Where one thread
/// owns the socket that is free; where two threads must write (a
/// worker's heartbeat thread and its round loop), every frame goes
/// through this lock as one atomic `write_all`.
#[derive(Clone)]
pub struct FrameWriter {
    inner: Arc<Mutex<TcpStream>>,
}

impl FrameWriter {
    /// Wrap a connected stream.
    pub fn new(stream: TcpStream) -> FrameWriter {
        FrameWriter {
            inner: Arc::new(Mutex::new(stream)),
        }
    }

    /// Write `frame` + `\n` as one locked write.
    pub fn send_line(&self, frame: &str) -> Result<()> {
        let mut buf = Vec::with_capacity(frame.len() + 1);
        buf.extend_from_slice(frame.as_bytes());
        buf.push(b'\n');
        let mut stream = self.inner.lock().unwrap();
        stream.write_all(&buf).context("writing a frame")?;
        Ok(())
    }

    /// Write a control line immediately followed by its binary payload
    /// frame, under one lock so no other frame can split them.
    pub fn send_line_with_payload(&self, frame: &str, payload: &[u8]) -> Result<()> {
        let mut stream = self.inner.lock().unwrap();
        stream
            .write_all(frame.as_bytes())
            .and_then(|()| stream.write_all(b"\n"))
            .and_then(|()| write_payload(&mut *stream, payload))
            .context("writing a payload frame")?;
        Ok(())
    }

    /// Tear the connection down (both directions); any thread blocked
    /// reading the peer half returns immediately.  Errors are ignored —
    /// the socket may already be gone.
    pub fn shutdown(&self) {
        let _ = self.inner.lock().unwrap().shutdown(std::net::Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn line_reader_bounds_and_splits() {
        let mut r = BufReader::new(&b"alpha\n\n  \nbeta\n"[..]);
        assert_eq!(read_line_bounded(&mut r, 64).unwrap().as_deref(), Some("alpha"));
        assert_eq!(read_line_bounded(&mut r, 64).unwrap().as_deref(), Some("beta"));
        assert!(read_line_bounded(&mut r, 64).unwrap().is_none());

        let mut r = BufReader::new(&b"0123456789\n"[..]);
        assert!(read_line_bounded(&mut r, 4).is_err());

        let mut r = BufReader::new(&b"partial"[..]);
        assert!(read_line_bounded(&mut r, 64).is_err());
    }

    #[test]
    fn payload_roundtrip_and_corruption() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let mut buf = Vec::new();
        write_payload(&mut buf, &payload).unwrap();
        assert_eq!(buf.len(), PAYLOAD_HEADER_BYTES + payload.len());
        assert_eq!(read_payload(&mut &buf[..], 1 << 10).unwrap(), payload);

        // checksum catches a flipped byte
        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(read_payload(&mut &bad[..], 1 << 10).is_err());

        // length bound rejects before allocating
        assert!(read_payload(&mut &buf[..], 16).is_err());
    }
}
