//! The non-blocking TCP front end: one poll thread, N scoring workers.
//!
//! ```text
//!             ┌────────────────────────────────────────────────┐
//!  clients ──►│ poll thread: accept / read / parse / admit /   │
//!             │ write  (nonblocking sockets, one loop)         │
//!             └───────┬───────────────────────────▲────────────┘
//!                     │ bounded job queue         │ completion channel
//!             ┌───────▼───────────────────────────┴────────────┐
//!             │ worker threads: deadline check → NetHandler    │
//!             │ (RegistryHandler: resolve → Engine → cache)    │
//!             └────────────────────────────────────────────────┘
//! ```
//!
//! **Single-writer framing invariant:** only the poll thread ever writes
//! a socket.  Workers hand finished frames back over a channel and the
//! poll thread appends them to the connection's outbox, so two responses
//! can never interleave mid-frame no matter how many workers raced —
//! shed responses and slow completions share one connection safely
//! (pinned by `tests/serve_net.rs`).
//!
//! **Admission control:** the job queue is bounded at
//! [`NetConfig::max_pending`].  A frame that arrives to a full queue is
//! answered [`Response::Overloaded`] *immediately* — it never queues, so
//! the queue depth (and therefore queuing latency) is bounded by
//! construction and overload degrades p99 into explicit sheds instead of
//! unbounded waiting.  A per-request deadline (frame field or
//! [`NetConfig::default_deadline_ms`]) is checked when a worker pops the
//! job: expired jobs answer [`Response::DeadlineExceeded`] without
//! touching the model.
//!
//! **Drain:** a `shutdown` frame, [`NetServerHandle::stop`], or SIGTERM
//! (CLI path) flips `stopping`.  From that point new frames get
//! `shutdown` errors, but everything already admitted is executed,
//! routed, and flushed before the poll thread exits — no accepted
//! request is ever dropped (regression-pinned).  Admin ops
//! (`promote`/`rollback`/`list`/`load`) run inline on the poll thread
//! against the attached [`Registry`]; they are rare, registry ops are
//! short write-locked pointer swaps, and inlining them keeps their reply
//! ordered after every earlier frame on the same connection.

use std::collections::{HashMap, VecDeque};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::kernel::KernelPolicy;
use crate::obs::{Counter, Gauge, Hist, Metrics, MetricsSnapshot};

use super::super::cache::CompletionCache;
use super::super::engine::Engine;
use super::super::registry::Registry;
use super::super::server::{check_coords, Request, Response};
use super::super::snapshot::ModelSnapshot;
use super::super::topk::top_k;
use super::frame::{flush_conn, read_conn, Conn, ConnIo};
use super::wire::{self, NetRequest};

/// How long the poll thread keeps flushing outboxes after the drain
/// completes logically, before giving up on clients that stopped reading.
const DRAIN_FLUSH_TIMEOUT: Duration = Duration::from_secs(10);

/// Idle sleep between poll iterations that made no progress.
const IDLE_POLL: Duration = Duration::from_micros(500);

/// Front-end tuning knobs (all bounded-resource limits have defaults
/// sized for the test/CI tier; production would raise them).
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Scoring worker threads.
    pub workers: usize,
    /// Admission bound: frames arriving to a queue this deep are shed
    /// with [`Response::Overloaded`].
    pub max_pending: usize,
    /// Deadline applied to frames that don't carry their own
    /// `deadline_ms` (0 = no default deadline).
    pub default_deadline_ms: u64,
    /// Kernel tier for the workers' scoring engines.
    pub policy: KernelPolicy,
    /// Capacity of the cross-request completion cache (fibers).
    pub cache_fibers: usize,
    /// A connection whose unterminated frame exceeds this many bytes is
    /// dropped (malformed or hostile input).
    pub max_frame_bytes: usize,
    /// A connection whose unread responses exceed this many bytes is
    /// dropped (client stopped reading).
    pub max_outbox_bytes: usize,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            workers: 2,
            max_pending: 256,
            default_deadline_ms: 0,
            policy: KernelPolicy::Tiled,
            cache_fibers: 1024,
            max_frame_bytes: 1 << 20,
            max_outbox_bytes: 8 << 20,
        }
    }
}

/// Final counters reported by [`NetServer::shutdown`].
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
    /// Frames parsed (requests + admin + malformed).
    pub frames: u64,
    /// Query requests admitted to the queue (every one was answered).
    pub requests: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests whose deadline expired in the queue.
    pub deadline_missed: u64,
    /// Error responses (malformed frames, validation failures).
    pub errors: u64,
}

/// What a worker executes: one admitted query frame.
struct NetJob {
    conn: u64,
    id: u64,
    model: Option<String>,
    req: Request,
    deadline: Option<Instant>,
    enqueued: Instant,
}

/// Pre-registered instrument handles (the [`super::super::Server`]
/// pattern): the hot path records through `Arc`s, never the name table.
struct NetObs {
    connections: Arc<Counter>,
    active_connections: Arc<Gauge>,
    frames: Arc<Counter>,
    requests: Arc<Counter>,
    shed: Arc<Counter>,
    deadline_misses: Arc<Counter>,
    errors: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    lat_predict: Arc<Hist>,
    lat_topk: Arc<Hist>,
    lat_epoch: Arc<Hist>,
    lat_stats: Arc<Hist>,
}

impl NetObs {
    fn new(m: &Metrics) -> NetObs {
        NetObs {
            connections: m.counter("serve.net.connections"),
            active_connections: m.gauge("serve.net.active_connections"),
            frames: m.counter("serve.net.frames"),
            requests: m.counter("serve.net.requests"),
            shed: m.counter("serve.net.shed"),
            deadline_misses: m.counter("serve.net.deadline_misses"),
            errors: m.counter("serve.net.errors"),
            queue_depth: m.gauge("serve.net.queue_depth"),
            lat_predict: m.hist("serve.net.latency.predict"),
            lat_topk: m.hist("serve.net.latency.topk"),
            lat_epoch: m.hist("serve.net.latency.epoch"),
            lat_stats: m.hist("serve.net.latency.stats"),
        }
    }

    fn latency(&self, req: &Request) -> &Hist {
        match req {
            Request::Predict { .. } => &self.lat_predict,
            Request::TopK { .. } => &self.lat_topk,
            Request::Epoch => &self.lat_epoch,
            Request::Stats => &self.lat_stats,
        }
    }
}

struct NetShared {
    queue: Mutex<VecDeque<NetJob>>,
    ready: Condvar,
    /// Drain began: no new frames admitted; everything accepted finishes.
    stopping: AtomicBool,
    /// Workers may exit (set by the poll thread once the queue is dry).
    workers_stop: AtomicBool,
    /// The poll thread has exited (sockets closed, outboxes flushed).
    drained: AtomicBool,
    /// Jobs admitted whose response frame has not yet reached an outbox.
    outstanding: AtomicU64,
    registry: Option<Arc<Registry>>,
    metrics: Arc<Metrics>,
    obs: NetObs,
    max_pending: usize,
    default_deadline_ms: u64,
    max_frame_bytes: usize,
    max_outbox_bytes: usize,
}

impl NetShared {
    fn stats(&self) -> NetStats {
        NetStats {
            connections: self.obs.connections.get(),
            frames: self.obs.frames.get(),
            requests: self.obs.requests.get(),
            shed: self.obs.shed.get(),
            deadline_missed: self.obs.deadline_misses.get(),
            errors: self.obs.errors.get(),
        }
    }
}

/// What a worker does with one admitted request.  The production
/// implementation is [`RegistryHandler`]; tests inject slow or failing
/// fakes through [`NetServer::bind_with_handler`] to pin the admission,
/// deadline, and framing behavior without a model in the loop.
pub trait NetHandler: Send {
    /// Answer one request routed to `model` (registry default if `None`).
    fn call(&mut self, model: Option<&str>, req: &Request) -> Response;
}

/// The production [`NetHandler`]: resolve the named model in the
/// [`Registry`], keep an [`Engine`] bound to the resolved snapshot
/// (rebinding when the generation moves, i.e. after promote/rollback),
/// and serve top-K sweeps through the shared [`CompletionCache`].
pub struct RegistryHandler {
    registry: Arc<Registry>,
    cache: Arc<CompletionCache>,
    policy: KernelPolicy,
    /// The engine bound to the last resolved (generation, snapshot).
    bound: Option<(u64, Engine)>,
}

impl RegistryHandler {
    /// Build a handler over a shared registry and completion cache.
    pub fn new(
        registry: Arc<Registry>,
        cache: Arc<CompletionCache>,
        policy: KernelPolicy,
    ) -> RegistryHandler {
        RegistryHandler {
            registry,
            cache,
            policy,
            bound: None,
        }
    }
}

impl NetHandler for RegistryHandler {
    fn call(&mut self, model: Option<&str>, req: &Request) -> Response {
        let (snap, generation) = match self.registry.resolve(model) {
            Ok(resolved) => resolved,
            Err(e) => return Response::Error(e),
        };
        // rebind on generation change (promote/rollback/publish), never on
        // pointer identity — generations are unique forever
        if !matches!(&self.bound, Some((g, _)) if *g == generation) {
            self.bound = Some((generation, Engine::with_policy(snap, self.policy)));
        }
        let (_, engine) = self.bound.as_mut().unwrap();
        match req {
            Request::Predict { coords } => match check_coords(engine.snapshot(), coords, None) {
                Ok(()) => Response::Predict(engine.predict(coords)),
                Err(e) => Response::Error(e),
            },
            Request::TopK { coords, mode, k } => {
                if *mode >= engine.snapshot().order() {
                    return Response::Error(format!("mode {mode} out of range"));
                }
                if let Err(e) = check_coords(engine.snapshot(), coords, Some(*mode)) {
                    return Response::Error(e);
                }
                // the calc-vs-store knob across requests: replay the fiber
                // invariant when cached (bit-identical to recomputing it)
                let key = CompletionCache::key(generation, *mode, coords);
                let mut scores = Vec::new();
                match self.cache.get(&key) {
                    Some(d) => engine.score_candidates(*mode, &d, &mut scores),
                    None => {
                        let d = engine.exclusion(coords, *mode).to_vec();
                        engine.score_candidates(*mode, &d, &mut scores);
                        self.cache.insert(key, d);
                    }
                }
                Response::TopK(top_k(&scores, *k))
            }
            Request::Epoch => Response::Epoch(engine.snapshot().epoch()),
            // Stats never reaches a handler — workers answer it from the
            // server's own registry (see worker_loop)
            Request::Stats => Response::Error("stats is answered by the front end".to_string()),
        }
    }
}

/// The running front end; see the module docs for the thread layout.
pub struct NetServer {
    shared: Arc<NetShared>,
    poll: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    local_addr: SocketAddr,
}

/// Cheap, clonable control handle onto a [`NetServer`].
#[derive(Clone)]
pub struct NetServerHandle {
    shared: Arc<NetShared>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:7171`, port 0 picks a free port) and
    /// serve every model in `registry` with [`RegistryHandler`] workers
    /// sharing one completion cache.
    pub fn bind(addr: &str, registry: Arc<Registry>, cfg: NetConfig) -> Result<NetServer> {
        let metrics = Metrics::shared();
        let cache = Arc::new(CompletionCache::new(cfg.cache_fibers, &metrics));
        let policy = cfg.policy;
        let handler_registry = registry.clone();
        NetServer::bind_inner(addr, Some(registry), cfg, metrics, move || {
            Box::new(RegistryHandler::new(
                handler_registry.clone(),
                cache.clone(),
                policy,
            ))
        })
    }

    /// [`NetServer::bind`] with an injected [`NetHandler`] factory (one
    /// handler per worker) and no registry — the test seam for admission
    /// control, deadlines, and drain behavior.  Admin ops answer
    /// `bad_request` when no registry is attached.
    pub fn bind_with_handler<F>(addr: &str, cfg: NetConfig, factory: F) -> Result<NetServer>
    where
        F: FnMut() -> Box<dyn NetHandler>,
    {
        NetServer::bind_inner(addr, None, cfg, Metrics::shared(), factory)
    }

    fn bind_inner<F>(
        addr: &str,
        registry: Option<Arc<Registry>>,
        cfg: NetConfig,
        metrics: Arc<Metrics>,
        mut factory: F,
    ) -> Result<NetServer>
    where
        F: FnMut() -> Box<dyn NetHandler>,
    {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        let local_addr = listener.local_addr().context("reading the bound address")?;
        let obs = NetObs::new(&metrics);
        let shared = Arc::new(NetShared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            stopping: AtomicBool::new(false),
            workers_stop: AtomicBool::new(false),
            drained: AtomicBool::new(false),
            outstanding: AtomicU64::new(0),
            registry,
            metrics,
            obs,
            max_pending: cfg.max_pending.max(1),
            default_deadline_ms: cfg.default_deadline_ms,
            max_frame_bytes: cfg.max_frame_bytes.max(1024),
            max_outbox_bytes: cfg.max_outbox_bytes.max(4096),
        });
        let (tx, rx) = mpsc::channel::<(u64, String)>();
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                let tx = tx.clone();
                let handler = factory();
                std::thread::spawn(move || worker_loop(&shared, &tx, handler))
            })
            .collect();
        drop(tx);
        let poll = {
            let shared = shared.clone();
            std::thread::spawn(move || poll_loop(&shared, &listener, &rx))
        };
        Ok(NetServer {
            shared,
            poll: Some(poll),
            workers,
            local_addr,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A control handle (clone freely across threads).
    pub fn handle(&self) -> NetServerHandle {
        NetServerHandle {
            shared: self.shared.clone(),
        }
    }

    /// The front end's telemetry registry.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Freeze the current telemetry without a queue round-trip.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// True once the poll thread has finished draining and exited
    /// (after a wire `shutdown`, [`NetServerHandle::stop`], or SIGTERM).
    pub fn drained(&self) -> bool {
        self.shared.drained.load(Ordering::SeqCst)
    }

    /// Begin the drain (idempotent), wait for every accepted request to
    /// be answered and flushed, join all threads, and report final
    /// counters.
    pub fn shutdown(mut self) -> NetStats {
        self.shared.stopping.store(true, Ordering::SeqCst);
        if let Some(poll) = self.poll.take() {
            let _ = poll.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shared.stats()
    }
}

impl NetServerHandle {
    /// Begin a graceful drain: stop admitting, finish everything
    /// accepted, flush, exit.  Returns immediately; observe completion
    /// via [`NetServer::drained`] or [`NetServer::shutdown`].
    pub fn stop(&self) {
        self.shared.stopping.store(true, Ordering::SeqCst);
    }

    /// Freeze the current telemetry.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Current counters (live, monotonic).
    pub fn stats(&self) -> NetStats {
        self.shared.stats()
    }
}

// -- worker side --------------------------------------------------------

fn worker_loop(shared: &NetShared, tx: &mpsc::Sender<(u64, String)>, mut handler: Box<dyn NetHandler>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    shared.obs.queue_depth.set(q.len() as i64);
                    break job;
                }
                if shared.workers_stop.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let resp = if job.deadline.is_some_and(|d| Instant::now() >= d) {
            shared.obs.deadline_misses.inc();
            Response::DeadlineExceeded
        } else if matches!(job.req, Request::Stats) {
            // answered from the server's own registry so remote operators
            // see the serve.net.* / serve.cache.* instruments
            Response::Stats(shared.metrics.snapshot())
        } else {
            handler.call(job.model.as_deref(), &job.req)
        };
        // latency includes queueing (what a client experiences)
        shared
            .obs
            .latency(&job.req)
            .record_duration(job.enqueued.elapsed());
        if matches!(resp, Response::Error(_)) {
            shared.obs.errors.inc();
        }
        // the poll thread owns all socket writes: hand the frame back
        let _ = tx.send((job.conn, wire::response_frame(job.id, &resp)));
    }
}

// -- poll side ----------------------------------------------------------
//
// The connection/framing primitives (`Conn`, `read_conn`, `flush_conn`)
// live in [`super::frame`] — they are shared with the distributed TCP
// transport so the two wires keep one framing discipline.

/// Run a registry admin op and encode its reply: success answers with
/// the full post-op listing so operators always see the resulting state.
fn admin_frame<F>(shared: &NetShared, id: u64, op: F) -> String
where
    F: FnOnce(&Registry) -> Result<(), String>,
{
    match &shared.registry {
        None => {
            shared.obs.errors.inc();
            wire::error_frame(id, "bad_request", "no registry attached to this server")
        }
        Some(reg) => match op(reg) {
            Ok(()) => wire::listing_frame(id, &reg.list()),
            Err(e) => {
                shared.obs.errors.inc();
                wire::error_frame(id, "bad_request", &e)
            }
        },
    }
}

/// Decide the reply (if any) for one parsed frame.  `None` means the
/// frame was admitted to the queue and a worker will answer it.
fn dispatch_frame(shared: &NetShared, cid: u64, line: &str) -> Option<String> {
    shared.obs.frames.inc();
    let req = match wire::parse_request(line) {
        Ok(req) => req,
        Err(e) => {
            shared.obs.errors.inc();
            return Some(wire::error_frame(0, "bad_request", &e));
        }
    };
    if shared.stopping.load(Ordering::SeqCst) {
        return Some(wire::error_frame(
            req.id(),
            "shutdown",
            "server is draining",
        ));
    }
    match req {
        NetRequest::Call {
            id,
            model,
            deadline_ms,
            req,
        } => {
            let deadline_ms = deadline_ms.or(match shared.default_deadline_ms {
                0 => None,
                ms => Some(ms),
            });
            let deadline = deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
            let mut q = shared.queue.lock().unwrap();
            if q.len() >= shared.max_pending {
                drop(q);
                shared.obs.shed.inc();
                return Some(wire::response_frame(id, &Response::Overloaded));
            }
            q.push_back(NetJob {
                conn: cid,
                id,
                model,
                req,
                deadline,
                enqueued: Instant::now(),
            });
            shared.obs.queue_depth.set(q.len() as i64);
            // increment before releasing the lock: a worker may finish the
            // job (and this thread route its completion) any time after
            shared.outstanding.fetch_add(1, Ordering::SeqCst);
            drop(q);
            shared.obs.requests.inc();
            shared.ready.notify_one();
            None
        }
        NetRequest::Promote { id, model, version } => Some(admin_frame(shared, id, |reg| {
            reg.promote(&model, version).map(|_| ())
        })),
        NetRequest::Rollback { id, model } => {
            Some(admin_frame(shared, id, |reg| reg.rollback(&model).map(|_| ())))
        }
        NetRequest::Load { id, model, path } => Some(admin_frame(shared, id, |reg| {
            let snap = ModelSnapshot::load(Path::new(&path)).map_err(|e| format!("{e:#}"))?;
            reg.insert(&model, snap);
            Ok(())
        })),
        NetRequest::List { id } => Some(admin_frame(shared, id, |_| Ok(()))),
        NetRequest::Shutdown { id } => {
            shared.stopping.store(true, Ordering::SeqCst);
            Some(wire::stopping_frame(id))
        }
    }
}

fn poll_loop(shared: &NetShared, listener: &TcpListener, rx: &mpsc::Receiver<(u64, String)>) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn: u64 = 1;
    let mut frames: Vec<(u64, String)> = Vec::new();
    let mut dead: Vec<u64> = Vec::new();
    let mut drain_started: Option<Instant> = None;
    loop {
        let mut progress = false;

        // 1. accept (until the drain begins)
        if !shared.stopping.load(Ordering::SeqCst) {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        conns.insert(next_conn, Conn::new(stream));
                        next_conn += 1;
                        shared.obs.connections.inc();
                        shared.obs.active_connections.set(conns.len() as i64);
                        progress = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
        }

        // 2. read + frame
        frames.clear();
        dead.clear();
        for (&cid, conn) in conns.iter_mut() {
            if matches!(
                read_conn(conn, shared.max_frame_bytes, &mut frames, cid),
                ConnIo::Drop
            ) {
                dead.push(cid);
            }
        }
        for cid in dead.drain(..) {
            conns.remove(&cid);
            shared.obs.active_connections.set(conns.len() as i64);
        }
        progress |= !frames.is_empty();

        // 3. dispatch (admission, admin ops, immediate errors)
        for (cid, line) in frames.drain(..) {
            if let Some(reply) = dispatch_frame(shared, cid, &line) {
                if let Some(conn) = conns.get_mut(&cid) {
                    conn.push_frame(&reply);
                }
            }
        }

        // 4. route worker completions into outboxes
        while let Ok((cid, frame)) = rx.try_recv() {
            shared.outstanding.fetch_sub(1, Ordering::SeqCst);
            if let Some(conn) = conns.get_mut(&cid) {
                conn.push_frame(&frame);
            }
            progress = true;
        }

        // 5. flush
        for (&cid, conn) in conns.iter_mut() {
            let before = conn.out.len();
            if matches!(flush_conn(conn), ConnIo::Drop) || conn.out.len() > shared.max_outbox_bytes
            {
                dead.push(cid);
                continue;
            }
            progress |= conn.out.len() != before;
            if conn.eof && conn.out.is_empty() {
                dead.push(cid);
            }
        }
        for cid in dead.drain(..) {
            conns.remove(&cid);
            shared.obs.active_connections.set(conns.len() as i64);
        }

        // 6. drain exit: everything admitted answered, everything flushed
        if shared.stopping.load(Ordering::SeqCst) {
            let started = *drain_started.get_or_insert_with(Instant::now);
            let logically_done = shared.outstanding.load(Ordering::SeqCst) == 0
                && shared.queue.lock().unwrap().is_empty();
            let flushed = conns.values().all(|c| c.out.is_empty());
            if (logically_done && flushed) || started.elapsed() > DRAIN_FLUSH_TIMEOUT {
                break;
            }
        }

        if !progress {
            std::thread::sleep(IDLE_POLL);
        }
    }
    // release the workers (queue is dry by construction) and mark done
    {
        let _q = shared.queue.lock().unwrap();
        shared.workers_stop.store(true, Ordering::SeqCst);
    }
    shared.ready.notify_all();
    shared.drained.store(true, Ordering::SeqCst);
}
