//! The serving wire protocol: newline-delimited JSON frames.
//!
//! One frame is one JSON object on one line (`\n`-terminated, no
//! newlines inside a frame — [`crate::util::json`] escapes them).  A
//! client writes request frames and reads response frames; the `id`
//! field (client-chosen, `0 <= id < 2^53`) correlates them, so responses
//! may legally arrive out of order and a client may pipeline.
//!
//! Request frames (`op` selects the shape):
//!
//! ```text
//! {"id":1,"op":"predict","coords":[4,9,6]}
//! {"id":2,"op":"topk","coords":[4,0,6],"mode":1,"k":10}
//! {"id":3,"op":"epoch","model":"main"}
//! {"id":4,"op":"stats"}
//! {"id":5,"op":"list"}
//! {"id":6,"op":"promote","model":"main","version":2}
//! {"id":7,"op":"rollback","model":"main"}
//! {"id":8,"op":"load","model":"main","path":"ckpt.ftck"}
//! {"id":9,"op":"shutdown"}
//! ```
//!
//! `model` (optional on query ops: the registry default answers when
//! absent) and `deadline_ms` (optional: admission deadline relative to
//! frame arrival) apply to `predict` / `topk` / `epoch` / `stats`.
//!
//! Response frames echo `id` and carry one of:
//!
//! ```text
//! {"id":1,"op":"predict","value":0.734127}
//! {"id":2,"op":"topk","top":[{"index":3,"score":1.25},...]}
//! {"id":3,"op":"epoch","epoch":12}
//! {"id":4,"op":"stats","stats":{"counters":...,"gauges":...,"hists":...}}
//! {"id":5,"op":"registry","models":[{"name":...,"versions":[...],...}]}
//! {"id":9,"op":"shutdown","stopping":true}
//! {"id":2,"op":"error","code":"overloaded","error":"queue full"}
//! ```
//!
//! Error codes: `bad_request` (malformed frame / validation failure /
//! unknown model), `overloaded` (admission control shed the request —
//! maps to [`Response::Overloaded`]), `deadline` (the deadline expired
//! queued — maps to [`Response::DeadlineExceeded`]), `shutdown` (the
//! frame arrived after drain began).
//!
//! Float values (`value`, `score`) are emitted by widening `f32 → f64`
//! and printing the shortest round-tripping decimal, so a prediction
//! crosses the wire **bit-identically** — the acceptance criterion
//! pinned by `tests/serve_net.rs`.  Non-finite floats (impossible for a
//! trained model, but defended anyway) encode as `null` and fail
//! decoding loudly rather than emitting invalid JSON.

use crate::obs::MetricsSnapshot;
use crate::util::json::{arr, num, obj, s, Json};

use super::super::registry::ModelInfo;
use super::super::server::{Request, Response};
use super::super::topk::Scored;

/// One decoded request frame.
#[derive(Clone, Debug)]
pub enum NetRequest {
    /// A query op (`predict` / `topk` / `epoch` / `stats`) routed to a
    /// model by name (registry default when `None`).
    Call {
        /// Correlation id echoed in the response.
        id: u64,
        /// Target model name; the registry default answers when absent.
        model: Option<String>,
        /// Milliseconds (from frame arrival) before the request is
        /// answered `deadline` instead of executed.
        deadline_ms: Option<u64>,
        /// The in-process request this frame wraps.
        req: Request,
    },
    /// Activate a version (latest when `None`) of `model`.
    Promote {
        /// Correlation id echoed in the response.
        id: u64,
        /// Target model name.
        model: String,
        /// Version to activate; latest when absent.
        version: Option<u64>,
    },
    /// Swap `model` back to its previously active version.
    Rollback {
        /// Correlation id echoed in the response.
        id: u64,
        /// Target model name.
        model: String,
    },
    /// Load a checkpoint from a server-local path as a new staged version
    /// of `model`.
    Load {
        /// Correlation id echoed in the response.
        id: u64,
        /// Target model name.
        model: String,
        /// Server-local FTCK checkpoint path.
        path: String,
    },
    /// Describe every registered model.
    List {
        /// Correlation id echoed in the response.
        id: u64,
    },
    /// Begin a graceful drain: answer everything accepted so far, then
    /// exit the poll loop.
    Shutdown {
        /// Correlation id echoed in the response.
        id: u64,
    },
}

impl NetRequest {
    /// The frame's correlation id.
    pub fn id(&self) -> u64 {
        match self {
            NetRequest::Call { id, .. }
            | NetRequest::Promote { id, .. }
            | NetRequest::Rollback { id, .. }
            | NetRequest::Load { id, .. }
            | NetRequest::List { id }
            | NetRequest::Shutdown { id } => *id,
        }
    }
}

/// One decoded response frame (client side).
#[derive(Clone, Debug)]
pub enum NetResponse {
    /// A successful query reply.
    Call {
        /// Correlation id of the request this answers.
        id: u64,
        /// The wrapped in-process response.
        resp: Response,
    },
    /// A registry listing (reply to `list` / `promote` / `rollback` /
    /// `load`, so admin callers always see the resulting state).
    Listing {
        /// Correlation id of the request this answers.
        id: u64,
        /// Post-op registry contents.
        models: Vec<ModelInfo>,
    },
    /// Acknowledgement that the server began draining.
    Stopping {
        /// Correlation id of the request this answers.
        id: u64,
    },
    /// Any error frame; `code` distinguishes shed / expired / malformed.
    Failure {
        /// Correlation id of the request this answers (0 when the frame
        /// was too malformed to carry one).
        id: u64,
        /// Machine-readable error class (see the module docs).
        code: String,
        /// Human-readable detail.
        message: String,
    },
}

// -- shared JSON helpers (the dist/event.rs idiom) ---------------------

fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Json::as_usize)
        .map(|u| u as u64)
        .ok_or_else(|| format!("missing or non-integer field {key:?}"))
}

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(j) => j
            .as_usize()
            .map(|u| Some(u as u64))
            .ok_or_else(|| format!("non-integer field {key:?}")),
    }
}

/// A numeric field bounded by the u32 candidate space (dimension sizes
/// are u32, so any larger value is unsatisfiable and rejected at decode
/// with the same discipline as the coord guard below).
fn get_u32_sized(v: &Json, key: &str) -> Result<usize, String> {
    match get_u64(v, key)? {
        u if u <= u32::MAX as u64 => Ok(u as usize),
        _ => Err(format!("field {key:?} is not a u32")),
    }
}

fn get_str(v: &Json, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing or non-string field {key:?}"))
}

fn get_coords(v: &Json) -> Result<Vec<u32>, String> {
    v.get("coords")
        .and_then(Json::as_arr)
        .ok_or("missing coords array")?
        .iter()
        .map(|j| match j.as_usize() {
            Some(u) if u <= u32::MAX as usize => Ok(u as u32),
            _ => Err("coordinate is not a u32".to_string()),
        })
        .collect()
}

/// Encode an `f32` for the wire: widen to `f64` (exact) and let the
/// emitter print the shortest round-tripping decimal.  Non-finite values
/// become `null` so the frame stays valid JSON.
fn f32_json(v: f32) -> Json {
    if v.is_finite() {
        num(v as f64)
    } else {
        Json::Null
    }
}

fn f32_field(v: &Json, key: &str) -> Result<f32, String> {
    match v.get(key) {
        Some(Json::Num(n)) => Ok(*n as f32),
        Some(Json::Null) => Err(format!("field {key:?} is non-finite")),
        _ => Err(format!("missing numeric field {key:?}")),
    }
}

// -- request frames ----------------------------------------------------

/// Encode a request frame (one line, no trailing newline).
pub fn encode_request(req: &NetRequest) -> String {
    let mut fields: Vec<(&str, Json)> = vec![("id", num(req.id() as f64))];
    match req {
        NetRequest::Call {
            model,
            deadline_ms,
            req,
            ..
        } => {
            if let Some(m) = model {
                fields.push(("model", s(m)));
            }
            if let Some(d) = deadline_ms {
                fields.push(("deadline_ms", num(*d as f64)));
            }
            match req {
                Request::Predict { coords } => {
                    fields.push(("op", s("predict")));
                    fields.push((
                        "coords",
                        arr(coords.iter().map(|&c| num(c as f64)).collect()),
                    ));
                }
                Request::TopK { coords, mode, k } => {
                    fields.push(("op", s("topk")));
                    fields.push((
                        "coords",
                        arr(coords.iter().map(|&c| num(c as f64)).collect()),
                    ));
                    fields.push(("mode", num(*mode as f64)));
                    fields.push(("k", num(*k as f64)));
                }
                Request::Epoch => fields.push(("op", s("epoch"))),
                Request::Stats => fields.push(("op", s("stats"))),
            }
        }
        NetRequest::Promote { model, version, .. } => {
            fields.push(("op", s("promote")));
            fields.push(("model", s(model)));
            if let Some(v) = version {
                fields.push(("version", num(*v as f64)));
            }
        }
        NetRequest::Rollback { model, .. } => {
            fields.push(("op", s("rollback")));
            fields.push(("model", s(model)));
        }
        NetRequest::Load { model, path, .. } => {
            fields.push(("op", s("load")));
            fields.push(("model", s(model)));
            fields.push(("path", s(path)));
        }
        NetRequest::List { .. } => fields.push(("op", s("list"))),
        NetRequest::Shutdown { .. } => fields.push(("op", s("shutdown"))),
    }
    obj(fields).dump()
}

/// Decode one request frame.
pub fn parse_request(line: &str) -> Result<NetRequest, String> {
    let v = Json::parse(line.trim()).map_err(|e| format!("bad frame: {e}"))?;
    let id = get_u64(&v, "id")?;
    let op = get_str(&v, "op")?;
    let model = match v.get("model") {
        None | Some(Json::Null) => None,
        Some(j) => Some(
            j.as_str()
                .ok_or("field \"model\" is not a string")?
                .to_string(),
        ),
    };
    let deadline_ms = opt_u64(&v, "deadline_ms")?;
    let call = |req: Request| NetRequest::Call {
        id,
        model: model.clone(),
        deadline_ms,
        req,
    };
    match op.as_str() {
        "predict" => Ok(call(Request::Predict {
            coords: get_coords(&v)?,
        })),
        "topk" => Ok(call(Request::TopK {
            coords: get_coords(&v)?,
            // candidate spaces are u32-dimensioned (like coords, guarded
            // in get_coords), so a mode or k beyond u32 can never be
            // satisfied — reject it at decode instead of carrying an
            // unbounded usize into the scoring path
            mode: get_u32_sized(&v, "mode")?,
            k: get_u32_sized(&v, "k")?,
        })),
        "epoch" => Ok(call(Request::Epoch)),
        "stats" => Ok(call(Request::Stats)),
        "promote" => Ok(NetRequest::Promote {
            id,
            model: get_str(&v, "model")?,
            version: opt_u64(&v, "version")?,
        }),
        "rollback" => Ok(NetRequest::Rollback {
            id,
            model: get_str(&v, "model")?,
        }),
        "load" => Ok(NetRequest::Load {
            id,
            model: get_str(&v, "model")?,
            path: get_str(&v, "path")?,
        }),
        "list" => Ok(NetRequest::List { id }),
        "shutdown" => Ok(NetRequest::Shutdown { id }),
        other => Err(format!("unknown op {other:?}")),
    }
}

// -- response frames ---------------------------------------------------

/// Encode a query reply.  [`Response::Error`] / [`Response::Overloaded`]
/// / [`Response::DeadlineExceeded`] become `error` frames with the
/// matching code, so one encoder covers the success and shed paths.
pub fn response_frame(id: u64, resp: &Response) -> String {
    match resp {
        Response::Predict(v) => obj(vec![
            ("id", num(id as f64)),
            ("op", s("predict")),
            ("value", f32_json(*v)),
        ])
        .dump(),
        Response::TopK(top) => obj(vec![
            ("id", num(id as f64)),
            ("op", s("topk")),
            (
                "top",
                arr(top
                    .iter()
                    .map(|sc| {
                        obj(vec![
                            ("index", num(sc.index as f64)),
                            ("score", f32_json(sc.score)),
                        ])
                    })
                    .collect()),
            ),
        ])
        .dump(),
        Response::Epoch(e) => obj(vec![
            ("id", num(id as f64)),
            ("op", s("epoch")),
            ("epoch", num(*e as f64)),
        ])
        .dump(),
        Response::Stats(snap) => obj(vec![
            ("id", num(id as f64)),
            ("op", s("stats")),
            ("stats", snap.to_json()),
        ])
        .dump(),
        Response::Overloaded => error_frame(id, "overloaded", "queue full, request shed"),
        Response::DeadlineExceeded => error_frame(id, "deadline", "deadline expired in queue"),
        Response::Error(e) => error_frame(id, "bad_request", e),
    }
}

/// Encode a registry listing reply.
pub fn listing_frame(id: u64, models: &[ModelInfo]) -> String {
    obj(vec![
        ("id", num(id as f64)),
        ("op", s("registry")),
        ("models", arr(models.iter().map(ModelInfo::to_json).collect())),
    ])
    .dump()
}

/// Encode the drain acknowledgement.
pub fn stopping_frame(id: u64) -> String {
    obj(vec![
        ("id", num(id as f64)),
        ("op", s("shutdown")),
        ("stopping", Json::Bool(true)),
    ])
    .dump()
}

/// Encode an error frame (see the module docs for codes).
pub fn error_frame(id: u64, code: &str, message: &str) -> String {
    obj(vec![
        ("id", num(id as f64)),
        ("op", s("error")),
        ("code", s(code)),
        ("error", s(message)),
    ])
    .dump()
}

/// Decode one response frame (client side).
pub fn parse_response(line: &str) -> Result<NetResponse, String> {
    let v = Json::parse(line.trim()).map_err(|e| format!("bad frame: {e}"))?;
    let id = get_u64(&v, "id")?;
    match get_str(&v, "op")?.as_str() {
        "predict" => Ok(NetResponse::Call {
            id,
            resp: Response::Predict(f32_field(&v, "value")?),
        }),
        "topk" => {
            let top = v
                .get("top")
                .and_then(Json::as_arr)
                .ok_or("missing top array")?
                .iter()
                .map(|j| {
                    Ok(Scored {
                        index: get_u64(j, "index")? as u32,
                        score: f32_field(j, "score")?,
                    })
                })
                .collect::<Result<Vec<Scored>, String>>()?;
            Ok(NetResponse::Call {
                id,
                resp: Response::TopK(top),
            })
        }
        "epoch" => Ok(NetResponse::Call {
            id,
            resp: Response::Epoch(get_u64(&v, "epoch")?),
        }),
        "stats" => {
            let snap = v.get("stats").ok_or("missing stats object")?;
            Ok(NetResponse::Call {
                id,
                resp: Response::Stats(MetricsSnapshot::from_json(snap)?),
            })
        }
        "registry" => {
            let models = v
                .get("models")
                .and_then(Json::as_arr)
                .ok_or("missing models array")?
                .iter()
                .map(ModelInfo::from_json)
                .collect::<Result<Vec<ModelInfo>, String>>()?;
            Ok(NetResponse::Listing { id, models })
        }
        "shutdown" => Ok(NetResponse::Stopping { id }),
        "error" => Ok(NetResponse::Failure {
            id,
            code: get_str(&v, "code")?,
            message: get_str(&v, "error")?,
        }),
        other => Err(format!("unknown response op {other:?}")),
    }
}

/// Map a decoded response frame for request `id` back into the
/// in-process [`Response`] a [`super::super::ServerHandle`] would have
/// returned — `overloaded` / `deadline` codes become their dedicated
/// variants, other failures become [`Response::Error`].
pub fn into_response(frame: NetResponse, id: u64) -> Result<Response, String> {
    match frame {
        NetResponse::Call { id: got, resp } if got == id => Ok(resp),
        NetResponse::Failure {
            id: got,
            code,
            message,
        } if got == id => Ok(match code.as_str() {
            "overloaded" => Response::Overloaded,
            "deadline" => Response::DeadlineExceeded,
            _ => Response::Error(message),
        }),
        other => Err(format!("response for the wrong request: {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: NetRequest) -> NetRequest {
        parse_request(&encode_request(&req)).unwrap()
    }

    #[test]
    fn request_frames_roundtrip() {
        let call = roundtrip_req(NetRequest::Call {
            id: 7,
            model: Some("main".into()),
            deadline_ms: Some(250),
            req: Request::Predict {
                coords: vec![4, 9, 6],
            },
        });
        match call {
            NetRequest::Call {
                id,
                model,
                deadline_ms,
                req: Request::Predict { coords },
            } => {
                assert_eq!((id, deadline_ms), (7, Some(250)));
                assert_eq!(model.as_deref(), Some("main"));
                assert_eq!(coords, vec![4, 9, 6]);
            }
            other => panic!("wrong decode: {other:?}"),
        }
        match roundtrip_req(NetRequest::Call {
            id: 8,
            model: None,
            deadline_ms: None,
            req: Request::TopK {
                coords: vec![1, 0, 2],
                mode: 1,
                k: 10,
            },
        }) {
            NetRequest::Call {
                model: None,
                deadline_ms: None,
                req: Request::TopK { coords, mode, k },
                ..
            } => assert_eq!((coords, mode, k), (vec![1, 0, 2], 1, 10)),
            other => panic!("wrong decode: {other:?}"),
        }
        assert!(matches!(
            roundtrip_req(NetRequest::Call {
                id: 1,
                model: None,
                deadline_ms: None,
                req: Request::Stats,
            }),
            NetRequest::Call {
                req: Request::Stats,
                ..
            }
        ));
        match roundtrip_req(NetRequest::Promote {
            id: 2,
            model: "m".into(),
            version: Some(3),
        }) {
            NetRequest::Promote { id, model, version } => {
                assert_eq!((id, model.as_str(), version), (2, "m", Some(3)))
            }
            other => panic!("wrong decode: {other:?}"),
        }
        match roundtrip_req(NetRequest::Load {
            id: 3,
            model: "m".into(),
            path: "a/b.ftck".into(),
        }) {
            NetRequest::Load { model, path, .. } => {
                assert_eq!((model.as_str(), path.as_str()), ("m", "a/b.ftck"))
            }
            other => panic!("wrong decode: {other:?}"),
        }
        assert!(matches!(
            roundtrip_req(NetRequest::Rollback {
                id: 4,
                model: "m".into()
            }),
            NetRequest::Rollback { id: 4, .. }
        ));
        assert!(matches!(
            roundtrip_req(NetRequest::List { id: 5 }),
            NetRequest::List { id: 5 }
        ));
        assert!(matches!(
            roundtrip_req(NetRequest::Shutdown { id: 6 }),
            NetRequest::Shutdown { id: 6 }
        ));
    }

    #[test]
    fn malformed_requests_are_rejected() {
        for bad in [
            "",                                       // empty line
            "{",                                      // truncated JSON
            r#"{"op":"predict","coords":[1]}"#,       // missing id
            r#"{"id":1,"op":"warp"}"#,                // unknown op
            r#"{"id":1,"op":"predict"}"#,             // missing coords
            r#"{"id":1,"op":"predict","coords":[-1]}"#, // negative coord
            r#"{"id":1,"op":"topk","coords":[1]}"#,   // missing mode/k
            r#"{"id":1,"op":"promote"}"#,             // missing model
            r#"{"id":1.5,"op":"list"}"#,              // fractional id
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn float_values_cross_the_wire_bit_identically() {
        // shortest-decimal f64 printing round-trips any finite f32 widened
        // to f64 — sweep awkward values plus a pseudo-random pile
        let mut awkward = vec![
            0.0f32,
            -0.0,
            1.0,
            f32::MIN_POSITIVE,
            f32::MAX,
            -f32::MAX,
            1.0 + f32::EPSILON,
            0.1,
            1.0 / 3.0,
            core::f32::consts::PI,
        ];
        let mut state = 0x9E3779B97F4A7C15u64;
        for _ in 0..200 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let bits = (state >> 32) as u32;
            let v = f32::from_bits(bits);
            if v.is_finite() {
                awkward.push(v);
            }
        }
        for v in awkward {
            let line = response_frame(9, &Response::Predict(v));
            match parse_response(&line).unwrap() {
                NetResponse::Call {
                    id: 9,
                    resp: Response::Predict(got),
                } => assert_eq!(got.to_bits(), v.to_bits(), "value {v:?} via {line}"),
                other => panic!("wrong decode: {other:?}"),
            }
        }
        // non-finite defends as null, and decoding fails loudly
        let line = response_frame(1, &Response::Predict(f32::NAN));
        assert!(Json::parse(&line).is_ok(), "frame must stay valid JSON");
        assert!(parse_response(&line).is_err());
    }

    #[test]
    fn response_frames_roundtrip() {
        let top = Response::TopK(vec![
            Scored {
                index: 3,
                score: 1.25,
            },
            Scored {
                index: 0,
                score: -0.5,
            },
        ]);
        match parse_response(&response_frame(2, &top)).unwrap() {
            NetResponse::Call {
                id: 2,
                resp: Response::TopK(got),
            } => {
                assert_eq!(got.len(), 2);
                assert_eq!((got[0].index, got[0].score), (3, 1.25));
                assert_eq!((got[1].index, got[1].score), (0, -0.5));
            }
            other => panic!("wrong decode: {other:?}"),
        }
        assert!(matches!(
            parse_response(&response_frame(3, &Response::Epoch(12))).unwrap(),
            NetResponse::Call {
                id: 3,
                resp: Response::Epoch(12)
            }
        ));
        assert!(matches!(
            parse_response(&stopping_frame(4)).unwrap(),
            NetResponse::Stopping { id: 4 }
        ));
        // shed / expired / failed map back through into_response
        for (resp, want) in [
            (Response::Overloaded, "overloaded"),
            (Response::DeadlineExceeded, "deadline"),
            (Response::Error("boom".into()), "bad_request"),
        ] {
            let line = response_frame(5, &resp);
            assert!(line.contains(want), "{line} should carry code {want}");
            let back = into_response(parse_response(&line).unwrap(), 5).unwrap();
            assert_eq!(
                std::mem::discriminant(&back),
                std::mem::discriminant(&resp)
            );
        }
        // a reply for a different id is an error, not a silent mismatch
        let frame = parse_response(&response_frame(5, &Response::Epoch(1))).unwrap();
        assert!(into_response(frame, 6).is_err());
    }

    #[test]
    fn stats_frame_carries_a_full_snapshot() {
        let m = crate::obs::Metrics::new();
        m.counter("serve.net.requests").add(5);
        m.hist("serve.net.latency.predict").record(1500);
        let snap = m.snapshot();
        let line = response_frame(11, &Response::Stats(snap.clone()));
        match parse_response(&line).unwrap() {
            NetResponse::Call {
                id: 11,
                resp: Response::Stats(got),
            } => assert_eq!(got, snap),
            other => panic!("wrong decode: {other:?}"),
        }
    }
}
