//! Closed-loop SLO load harness for the TCP serving tier.
//!
//! Drives a running [`super::NetServer`] over real sockets at a ladder of
//! offered QPS steps and reports, per step, what a latency SLO review
//! needs: offered vs achieved throughput, p50/p95/p99 client-observed
//! latency, and how much load admission control shed.  Pacing is
//! *closed-loop per connection, open-loop in aggregate*: each connection
//! thread schedules request `i` at `start + i/rate` and never sends
//! early, but a slow server pushes sends late — the achieved column then
//! falls below the offered one instead of the harness silently
//! self-throttling, which is exactly the signal the SLO curve needs at
//! the saturation knee.
//!
//! Request mix: predictions with every `topk_every`-th request a top-K
//! completion (the expensive op that exercises the
//! [`super::super::CompletionCache`]).  Coordinates are drawn uniformly
//! from the model's dims (fetched over the wire via `list`, so the
//! harness needs nothing but an address), from a seeded
//! [`Pcg32`](crate::util::rng::Pcg32) stream per connection —
//! deterministic traffic for a fixed config.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::bench::percentile;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg32;

use super::super::server::{Request, Response};
use super::client::NetClient;

/// One load step's configuration ladder and traffic shape.
#[derive(Clone, Debug)]
pub struct SloConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Model routed to (server default when `None`).
    pub model: Option<String>,
    /// Concurrent client connections (each paced independently).
    pub connections: usize,
    /// Offered-QPS ladder, one measurement step per entry.
    pub steps: Vec<u64>,
    /// Wall-clock duration of each step.
    pub step_duration: Duration,
    /// Per-request deadline forwarded to the server (`None` = none).
    pub deadline_ms: Option<u64>,
    /// Every `topk_every`-th request is a top-K completion (0 = never).
    pub topk_every: usize,
    /// Free mode for top-K requests.
    pub mode: usize,
    /// Candidates returned per top-K request.
    pub k: usize,
    /// Traffic seed (deterministic coordinates per connection).
    pub seed: u64,
}

impl Default for SloConfig {
    fn default() -> SloConfig {
        SloConfig {
            addr: String::new(),
            model: None,
            connections: 4,
            steps: vec![100, 400, 1600],
            step_duration: Duration::from_secs(2),
            deadline_ms: None,
            topk_every: 8,
            mode: 0,
            k: 10,
            seed: 42,
        }
    }
}

/// One measured step of the SLO curve.
#[derive(Clone, Debug)]
pub struct SloRow {
    /// QPS the harness tried to offer.
    pub offered_qps: f64,
    /// Successful answers per second actually achieved.
    pub achieved_qps: f64,
    /// Requests sent.
    pub sent: u64,
    /// Successful answers.
    pub ok: u64,
    /// Requests shed by admission control (`overloaded`).
    pub shed: u64,
    /// Requests expired in the queue (`deadline`).
    pub deadline_missed: u64,
    /// Transport or server errors.
    pub errors: u64,
    /// Client-observed latency percentiles, milliseconds.
    pub p50_ms: f64,
    /// 95th percentile latency, milliseconds.
    pub p95_ms: f64,
    /// 99th percentile latency, milliseconds.
    pub p99_ms: f64,
}

impl SloRow {
    /// The JSON row shape consumed by `scripts/bench_json.sh` and
    /// `BENCH_serve_slo.json`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("offered_qps", num(self.offered_qps)),
            ("achieved_qps", num(self.achieved_qps)),
            ("sent", num(self.sent as f64)),
            ("ok", num(self.ok as f64)),
            ("shed", num(self.shed as f64)),
            ("deadline_missed", num(self.deadline_missed as f64)),
            ("errors", num(self.errors as f64)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
        ])
    }

    /// One aligned human-readable table line (pairs with [`slo_header`]).
    pub fn render(&self) -> String {
        format!(
            "{:>10.0} {:>10.1} {:>8} {:>8} {:>6} {:>9} {:>7} {:>9.3} {:>9.3} {:>9.3}",
            self.offered_qps,
            self.achieved_qps,
            self.sent,
            self.ok,
            self.shed,
            self.deadline_missed,
            self.errors,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
        )
    }
}

/// Column header matching [`SloRow::render`].
pub fn slo_header() -> String {
    format!(
        "{:>10} {:>10} {:>8} {:>8} {:>6} {:>9} {:>7} {:>9} {:>9} {:>9}",
        "offered", "achieved", "sent", "ok", "shed", "deadline", "errors", "p50_ms", "p95_ms",
        "p99_ms",
    )
}

/// Per-thread tallies merged into an [`SloRow`] after the step.
#[derive(Default)]
struct StepTally {
    sent: u64,
    ok: u64,
    shed: u64,
    deadline_missed: u64,
    errors: u64,
    latencies_ms: Vec<f64>,
}

/// Run the whole ladder against a live server; one row per step.
pub fn run_slo(cfg: &SloConfig) -> Result<Vec<SloRow>> {
    // one probe connection discovers the dims to draw coordinates from
    let dims = {
        let mut probe = NetClient::connect(&cfg.addr)?;
        probe.set_read_timeout(Some(Duration::from_secs(10)))?;
        let models = probe.list().context("listing models for dims")?;
        let info = match &cfg.model {
            Some(name) => models.iter().find(|m| &m.name == name),
            None => models.iter().find(|m| m.is_default),
        };
        match info {
            Some(m) if !m.dims.is_empty() => m.dims.clone(),
            Some(m) => bail!("model {:?} reports empty dims", m.name),
            None => bail!("no matching model registered at {}", cfg.addr),
        }
    };
    cfg.steps
        .iter()
        .map(|&qps| run_step(cfg, &dims, qps))
        .collect()
}

fn run_step(cfg: &SloConfig, dims: &[u32], qps: u64) -> Result<SloRow> {
    let connections = cfg.connections.max(1);
    let per_conn_rate = qps as f64 / connections as f64;
    if per_conn_rate <= 0.0 {
        bail!("offered QPS must be positive");
    }
    let interval = Duration::from_secs_f64(1.0 / per_conn_rate);
    let started = Instant::now();
    let tallies: Vec<Result<StepTally>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..connections)
            .map(|conn_idx| {
                scope.spawn(move || -> Result<StepTally> {
                    drive_connection(cfg, dims, qps, conn_idx, interval)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    let mut merged = StepTally::default();
    for t in tallies {
        let t = t?;
        merged.sent += t.sent;
        merged.ok += t.ok;
        merged.shed += t.shed;
        merged.deadline_missed += t.deadline_missed;
        merged.errors += t.errors;
        merged.latencies_ms.extend(t.latencies_ms);
    }
    let (p50, p95, p99) = if merged.latencies_ms.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        let xs = &mut merged.latencies_ms;
        (
            percentile(xs, 50.0),
            percentile(xs, 95.0),
            percentile(xs, 99.0),
        )
    };
    Ok(SloRow {
        offered_qps: qps as f64,
        achieved_qps: merged.ok as f64 / elapsed,
        sent: merged.sent,
        ok: merged.ok,
        shed: merged.shed,
        deadline_missed: merged.deadline_missed,
        errors: merged.errors,
        p50_ms: p50,
        p95_ms: p95,
        p99_ms: p99,
    })
}

fn drive_connection(
    cfg: &SloConfig,
    dims: &[u32],
    qps: u64,
    conn_idx: usize,
    interval: Duration,
) -> Result<StepTally> {
    let mut client = NetClient::connect(&cfg.addr)?;
    client.set_read_timeout(Some(Duration::from_secs(30)))?;
    // distinct deterministic stream per (seed, step, connection)
    let mut rng = Pcg32::new(cfg.seed ^ qps, conn_idx as u64);
    let mut tally = StepTally::default();
    let start = Instant::now();
    let mut i: u32 = 0;
    while start.elapsed() < cfg.step_duration {
        // never send early; a slow server makes us late (and the achieved
        // column honest) rather than the pacer hiding the backlog
        if let Some(wait) = (interval * i).checked_sub(start.elapsed()) {
            std::thread::sleep(wait);
        }
        let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d.max(1))).collect();
        let req = if cfg.topk_every > 0 && (i as usize) % cfg.topk_every == 0 {
            Request::TopK {
                coords,
                mode: cfg.mode,
                k: cfg.k,
            }
        } else {
            Request::Predict { coords }
        };
        let sent_at = Instant::now();
        tally.sent += 1;
        match client.call(cfg.model.as_deref(), cfg.deadline_ms, req) {
            Ok(Response::Overloaded) => tally.shed += 1,
            Ok(Response::DeadlineExceeded) => tally.deadline_missed += 1,
            Ok(Response::Error(_)) => tally.errors += 1,
            Ok(_) => {
                tally.ok += 1;
                tally
                    .latencies_ms
                    .push(sent_at.elapsed().as_secs_f64() * 1e3);
            }
            // transport failure: the connection is gone, stop this thread
            Err(_) => {
                tally.errors += 1;
                break;
            }
        }
        i += 1;
    }
    Ok(tally)
}
