//! Cross-request completion cache: the calc-vs-store knob applied to
//! traffic.
//!
//! A top-K completion computes the fiber-shared exclusion product
//! `d = Π_{m≠mode} C^(m)[i_m, :]` once per request and then sweeps every
//! candidate row against it ([`super::Engine::complete_mode`]).  Real
//! recommender traffic repeats fibers — the same user asks for fresh
//! recommendations again and again — so recomputing `d` per request is
//! exactly the wasted work the paper's *calc* scheme pays per training
//! sample.  [`CompletionCache`] is the *store* scheme across requests: a
//! bounded, thread-safe map from `(generation, mode, fixed coordinates)`
//! to the exclusion product.
//!
//! Keys embed the registry **generation** of the snapshot that produced
//! the product (see [`super::Registry`]), not an `Arc` pointer the
//! allocator could reuse — so promoting or rolling back a model silently
//! invalidates its cached fibers: lookups under the new generation miss,
//! and stale entries age out of the LRU.  The cached vector is the exact
//! product the engine would recompute (elementwise multiplies don't
//! re-round, so even the SIMD tier is bit-identical here), which keeps
//! cache hits bit-for-bit equal to cache misses — pinned by
//! `tests/serve_net.rs`.
//!
//! Hit/miss/eviction counters live in the server's [`crate::obs::Metrics`]
//! registry under `serve.cache.*`, so the SLO harness and `query --stats`
//! can watch the hit rate move with traffic shape.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::obs::{Counter, Gauge, Metrics};

/// Cache key: which snapshot (by registry generation), which free mode,
/// and the fixed coordinates (free slot normalized, since completion
/// ignores it).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct FiberKey {
    generation: u64,
    mode: usize,
    coords: Vec<u32>,
}

struct Slot {
    d: Vec<f32>,
    last_used: u64,
}

struct Inner {
    map: HashMap<FiberKey, Slot>,
    /// Monotonic access clock for LRU eviction.
    tick: u64,
}

/// A bounded, thread-safe exclusion-product cache; see the module docs.
pub struct CompletionCache {
    inner: Mutex<Inner>,
    capacity: usize,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    entries: Arc<Gauge>,
}

impl CompletionCache {
    /// A cache holding at most `capacity` fibers (minimum 1), reporting
    /// `serve.cache.{hits,misses,evictions,entries}` through `metrics`.
    pub fn new(capacity: usize, metrics: &Metrics) -> CompletionCache {
        CompletionCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
            }),
            capacity: capacity.max(1),
            hits: metrics.counter("serve.cache.hits"),
            misses: metrics.counter("serve.cache.misses"),
            evictions: metrics.counter("serve.cache.evictions"),
            entries: metrics.gauge("serve.cache.entries"),
        }
    }

    /// Build the key for a completion over `mode` with `coords` fixed.
    /// The free slot is normalized to 0 so `[4, 9, 6]` and `[4, 0, 6]`
    /// (mode 1 free) hit the same fiber.
    pub fn key(generation: u64, mode: usize, coords: &[u32]) -> FiberKey {
        let mut coords = coords.to_vec();
        if mode < coords.len() {
            coords[mode] = 0;
        }
        FiberKey {
            generation,
            mode,
            coords,
        }
    }

    /// Look up a cached exclusion product, counting a hit or miss.
    pub fn get(&self, key: &FiberKey) -> Option<Vec<f32>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(slot) => {
                slot.last_used = tick;
                self.hits.inc();
                Some(slot.d.clone())
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    /// Store a freshly computed exclusion product, evicting the
    /// least-recently-used fiber when full.
    pub fn insert(&self, key: FiberKey, d: Vec<f32>) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if !inner.map.contains_key(&key) && inner.map.len() >= self.capacity {
            // full: evict the stalest fiber (O(capacity) scan, but only on
            // the insert-when-full path — lookups stay O(1))
            if let Some(stale) = inner
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&stale);
                self.evictions.inc();
            }
        }
        inner.map.insert(key, Slot { d, last_used: tick });
        self.entries.set(inner.map.len() as i64);
    }

    /// Number of cached fibers.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lifetime hit / miss counts (for tests and reports).
    pub fn hit_miss(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_insert_and_free_slot_normalization() {
        let m = Metrics::new();
        let cache = CompletionCache::new(8, &m);
        let key = CompletionCache::key(1, 1, &[4, 9, 6]);
        assert!(cache.get(&key).is_none());
        cache.insert(key.clone(), vec![1.0, 2.0]);
        assert_eq!(cache.get(&key), Some(vec![1.0, 2.0]));
        // the free slot's value is irrelevant to the fiber
        let same = CompletionCache::key(1, 1, &[4, 0, 6]);
        assert_eq!(cache.get(&same), Some(vec![1.0, 2.0]));
        assert_eq!(cache.hit_miss(), (2, 1));
    }

    #[test]
    fn generation_change_misses() {
        let m = Metrics::new();
        let cache = CompletionCache::new(8, &m);
        cache.insert(CompletionCache::key(1, 0, &[0, 2, 3]), vec![0.5]);
        // same fiber, promoted snapshot: different generation, so a miss
        assert!(cache.get(&CompletionCache::key(2, 0, &[0, 2, 3])).is_none());
        // different free mode over the same coords is a different fiber
        assert!(cache.get(&CompletionCache::key(1, 1, &[0, 2, 3])).is_none());
    }

    #[test]
    fn lru_eviction_is_bounded_and_stale_first() {
        let m = Metrics::new();
        let cache = CompletionCache::new(2, &m);
        let (a, b, c) = (
            CompletionCache::key(1, 0, &[0, 1, 1]),
            CompletionCache::key(1, 0, &[0, 2, 2]),
            CompletionCache::key(1, 0, &[0, 3, 3]),
        );
        cache.insert(a.clone(), vec![1.0]);
        cache.insert(b.clone(), vec![2.0]);
        assert!(cache.get(&a).is_some()); // touch a: b is now stalest
        cache.insert(c.clone(), vec![3.0]);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&b).is_none(), "stalest fiber should be evicted");
        assert!(cache.get(&a).is_some());
        assert!(cache.get(&c).is_some());
        assert_eq!(m.snapshot().counters["serve.cache.evictions"], 1);
    }
}
