//! Model serving: published snapshots, durable checkpoints, and a batched
//! top-K query engine over the trained decomposition.
//!
//! The training stack decomposes tensors; this subsystem is the other half
//! of the ROADMAP's production story — *answering queries* from the
//! decomposed model, the recommender workload the paper motivates
//! (§1: rating prediction and per-user ranking from the learned factors).
//! Four layers, bottom-up:
//!
//! * [`snapshot`] — [`ModelSnapshot`]: immutable, epoch-tagged, cheaply
//!   clonable published models carrying precomputed `C^(n) = A^(n) B^(n)`
//!   projection tables, plus the versioned `FTCK` on-disk checkpoint
//!   format (atomic save, lossless f32 roundtrip, checksum).
//! * [`engine`] — [`Engine`]: per-query scoring.  `predict` is
//!   bit-identical to the trainer's evaluation path; `complete_mode`
//!   computes the fiber-shared exclusion product once per query and scores
//!   every candidate of the free mode with one R-wide dot (the
//!   `InvariantCache` trick applied to serving).  Bulk scoring runs on the
//!   exact [`crate::kernel::prim`] layer by default, or the
//!   runtime-dispatched SIMD tier via [`Engine::with_policy`] /
//!   [`Server::start_with_policy`].
//! * [`topk`] — deterministic top-K selection over completion scores.
//! * [`server`] — [`Server`]: a threaded request loop with request
//!   batching and snapshot hot-swap, so `Trainer::publish` can push a
//!   fresh model mid-training while in-flight queries keep reading the
//!   old one.
//!
//! On top of the in-process layers sits the network tier:
//!
//! * [`registry`] — [`Registry`]: named, versioned snapshots with atomic
//!   promote / rollback (readers resolve a coherent `(snapshot,
//!   generation)` pair, never a torn mix).
//! * [`cache`] — [`CompletionCache`]: the calc-vs-store knob applied to
//!   traffic — a bounded LRU of fiber exclusion products keyed by
//!   registry generation, bit-identical on hit and miss.
//! * [`net`] — the TCP front end ([`NetServer`]), wire protocol, client
//!   ([`NetClient`]) and SLO load harness ([`net::run_slo`]).
//!
//! Lifecycle: `Trainer::snapshot()` freezes the live model →
//! `Server::publish` / [`Registry::publish`] swaps it in (or
//! `ModelSnapshot::save` persists it) → `ModelSnapshot::load` revives it
//! in a later process → [`Engine`] / [`Server`] / [`NetServer`] answer
//! queries.  See ARCHITECTURE.md §Serving layer.

pub mod cache;
pub mod engine;
pub mod net;
pub mod registry;
pub mod server;
pub mod snapshot;
pub mod topk;

pub use cache::CompletionCache;
pub use engine::Engine;
pub use net::{NetClient, NetConfig, NetServer, NetServerHandle};
pub use registry::{ModelInfo, Registry};
pub use server::{check_coords, Request, Response, ServeStats, Server, ServerHandle};
pub use snapshot::ModelSnapshot;
pub use topk::{mode_topk, top_k, Scored};
