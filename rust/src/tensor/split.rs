//! Seeded train/test split (the paper's Ω / Γ).

use crate::util::rng::Pcg32;

use super::coo::SparseTensor;

/// Split `t` into (train, test) with `test_frac` of entries held out.
/// Deterministic for a given seed.
pub fn train_test_split(t: &SparseTensor, test_frac: f64, seed: u64) -> (SparseTensor, SparseTensor) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut rng = Pcg32::new(seed, 0x5911_7);
    let mut ids: Vec<u32> = (0..t.nnz() as u32).collect();
    rng.shuffle(&mut ids);
    let n_test = (t.nnz() as f64 * test_frac).round() as usize;
    let mut train = SparseTensor::new(t.dims.clone());
    let mut test = SparseTensor::new(t.dims.clone());
    for (k, &e) in ids.iter().enumerate() {
        let e = e as usize;
        let dst = if k < n_test { &mut test } else { &mut train };
        dst.push(t.coords(e), t.values[e]);
    }
    train.sort_dedup();
    test.sort_dedup();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::io::toy_dataset;

    #[test]
    fn split_partitions() {
        let t = toy_dataset();
        let (tr, te) = train_test_split(&t, 0.25, 1);
        assert_eq!(tr.nnz() + te.nnz(), t.nnz());
        let frac = te.nnz() as f64 / t.nnz() as f64;
        assert!((frac - 0.25).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn split_deterministic() {
        let t = toy_dataset();
        let (a, _) = train_test_split(&t, 0.2, 7);
        let (b, _) = train_test_split(&t, 0.2, 7);
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn split_disjoint() {
        let t = toy_dataset();
        let (tr, te) = train_test_split(&t, 0.3, 3);
        use std::collections::HashSet;
        let key = |t: &SparseTensor, e: usize| t.coords(e).to_vec();
        let tr_set: HashSet<_> = (0..tr.nnz()).map(|e| key(&tr, e)).collect();
        for e in 0..te.nnz() {
            assert!(!tr_set.contains(&key(&te, e)));
        }
    }
}
