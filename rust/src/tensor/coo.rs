//! COO sparse tensor: the substrate every algorithm consumes.
//!
//! Indices are stored flat and mode-major-interleaved (`indices[e*N + n]` is
//! the mode-`n` index of entry `e`) so one cache line holds a whole entry's
//! coordinates — the layout the gather hot path wants.

use anyhow::{bail, Result};

/// A sparse N-order tensor in coordinate format.
#[derive(Clone, Debug)]
pub struct SparseTensor {
    /// Dimension sizes `I_n`, length N.
    pub dims: Vec<u32>,
    /// Flat coordinates, `nnz * N` entries, entry-major.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f32>,
}

impl SparseTensor {
    /// Empty tensor with the given dimension sizes (order ≥ 2).
    pub fn new(dims: Vec<u32>) -> Self {
        assert!(dims.len() >= 2, "need at least a 2-order tensor");
        Self {
            dims,
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Tensor order N.
    #[inline]
    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Number of stored (observed) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Coordinates of entry `e` (slice of length N).
    #[inline]
    pub fn coords(&self, e: usize) -> &[u32] {
        let n = self.order();
        &self.indices[e * n..(e + 1) * n]
    }

    /// Append one entry (coordinates must have length N).
    pub fn push(&mut self, coords: &[u32], value: f32) {
        debug_assert_eq!(coords.len(), self.order());
        self.indices.extend_from_slice(coords);
        self.values.push(value);
    }

    /// Validate all coordinates are in-bounds and values finite.
    pub fn validate(&self) -> Result<()> {
        let n = self.order();
        if self.indices.len() != self.values.len() * n {
            bail!(
                "index/value length mismatch: {} indices for {} values of order {}",
                self.indices.len(),
                self.values.len(),
                n
            );
        }
        for e in 0..self.nnz() {
            for (m, (&ix, &dim)) in self.coords(e).iter().zip(&self.dims).enumerate() {
                if ix >= dim {
                    bail!("entry {e}: mode-{m} index {ix} out of bounds (dim {dim})");
                }
            }
            if !self.values[e].is_finite() {
                bail!("entry {e}: non-finite value {}", self.values[e]);
            }
        }
        Ok(())
    }

    /// Sort entries lexicographically by coordinates and merge duplicates
    /// (last value wins, matching "latest observation" semantics).
    pub fn sort_dedup(&mut self) {
        let n = self.order();
        let nnz = self.nnz();
        let mut perm: Vec<u32> = (0..nnz as u32).collect();
        let idx = &self.indices;
        perm.sort_unstable_by(|&a, &b| {
            let (a, b) = (a as usize * n, b as usize * n);
            idx[a..a + n].cmp(&idx[b..b + n])
        });
        let mut new_idx = Vec::with_capacity(self.indices.len());
        let mut new_val = Vec::with_capacity(nnz);
        for &p in &perm {
            let p = p as usize;
            let coords = &self.indices[p * n..(p + 1) * n];
            if new_val.is_empty() || &new_idx[new_idx.len() - n..] != coords {
                new_idx.extend_from_slice(coords);
                new_val.push(self.values[p]);
            } else {
                *new_val.last_mut().unwrap() = self.values[p];
            }
        }
        self.indices = new_idx;
        self.values = new_val;
    }

    /// Density = nnz / prod(dims) (f64 — dims can overflow usize products).
    pub fn density(&self) -> f64 {
        let total: f64 = self.dims.iter().map(|&d| d as f64).product();
        self.nnz() as f64 / total
    }

    /// Mean of the stored values.
    pub fn mean_value(&self) -> f32 {
        if self.values.is_empty() {
            return 0.0;
        }
        (self.values.iter().map(|&v| v as f64).sum::<f64>() / self.nnz() as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t3() -> SparseTensor {
        let mut t = SparseTensor::new(vec![4, 5, 6]);
        t.push(&[0, 1, 2], 1.0);
        t.push(&[3, 4, 5], 2.0);
        t.push(&[1, 0, 0], 3.0);
        t
    }

    #[test]
    fn basics() {
        let t = t3();
        assert_eq!(t.order(), 3);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.coords(1), &[3, 4, 5]);
        t.validate().unwrap();
    }

    #[test]
    fn validate_catches_out_of_bounds() {
        let mut t = t3();
        t.push(&[0, 0, 6], 1.0);
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_nan() {
        let mut t = t3();
        t.push(&[0, 0, 0], f32::NAN);
        assert!(t.validate().is_err());
    }

    #[test]
    fn sort_dedup_orders_and_merges() {
        let mut t = SparseTensor::new(vec![4, 4]);
        t.push(&[2, 1], 5.0);
        t.push(&[0, 1], 1.0);
        t.push(&[2, 1], 7.0); // duplicate — last wins
        t.push(&[0, 0], 2.0);
        t.sort_dedup();
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.coords(0), &[0, 0]);
        assert_eq!(t.coords(1), &[0, 1]);
        assert_eq!(t.coords(2), &[2, 1]);
        assert_eq!(t.values[2], 7.0);
    }

    #[test]
    fn density_and_mean() {
        let t = t3();
        assert!((t.density() - 3.0 / 120.0).abs() < 1e-12);
        assert!((t.mean_value() - 2.0).abs() < 1e-6);
    }
}
