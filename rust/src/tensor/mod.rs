//! Sparse tensor substrate: COO storage, sampling indexes, I/O, splits.

pub mod coo;
pub mod index;
pub mod io;
pub mod split;

pub use coo::SparseTensor;
pub use index::{FiberIndex, ModeSliceIndex};
