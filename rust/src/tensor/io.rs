//! Tensor I/O: a text COO format (one `i1 i2 ... iN value` line per entry,
//! whitespace-separated, `#` comments, 0-based indices) and a faster binary
//! format (`FTB1`) for benchmark datasets.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::coo::SparseTensor;

/// Read a text COO file.  First non-comment line must be the header:
/// `dims I1 I2 ... IN`.
pub fn read_text(path: &Path) -> Result<SparseTensor> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    parse_text(BufReader::new(f))
}

/// Parse the text COO format from any reader (see [`read_text`]).
pub fn parse_text<R: BufRead>(r: R) -> Result<SparseTensor> {
    let mut tensor: Option<SparseTensor> = None;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        match &mut tensor {
            None => {
                let head = toks.next();
                if head != Some("dims") {
                    bail!("line {}: expected 'dims I1 ... IN' header", lineno + 1);
                }
                let dims: Vec<u32> = toks
                    .map(|t| t.parse().with_context(|| format!("line {}: bad dim", lineno + 1)))
                    .collect::<Result<_>>()?;
                if dims.len() < 2 {
                    bail!("need at least 2 dims");
                }
                tensor = Some(SparseTensor::new(dims));
            }
            Some(t) => {
                let n = t.order();
                let mut coords = Vec::with_capacity(n);
                for _ in 0..n {
                    let tok = toks
                        .next()
                        .with_context(|| format!("line {}: too few indices", lineno + 1))?;
                    coords.push(tok.parse::<u32>().with_context(|| {
                        format!("line {}: bad index {tok:?}", lineno + 1)
                    })?);
                }
                let vtok = toks
                    .next()
                    .with_context(|| format!("line {}: missing value", lineno + 1))?;
                let v: f32 = vtok
                    .parse()
                    .with_context(|| format!("line {}: bad value {vtok:?}", lineno + 1))?;
                if toks.next().is_some() {
                    bail!("line {}: trailing tokens", lineno + 1);
                }
                t.push(&coords, v);
            }
        }
    }
    let t = tensor.ok_or_else(|| anyhow::anyhow!("empty tensor file"))?;
    t.validate()?;
    Ok(t)
}

/// Write the text COO format (`dims` header + one entry per line).
pub fn write_text(t: &SparseTensor, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    write!(w, "dims")?;
    for d in &t.dims {
        write!(w, " {d}")?;
    }
    writeln!(w)?;
    for e in 0..t.nnz() {
        for c in t.coords(e) {
            write!(w, "{c} ")?;
        }
        writeln!(w, "{}", t.values[e])?;
    }
    Ok(())
}

const MAGIC: &[u8; 4] = b"FTB1";

/// Binary format: magic, u32 order, dims, u64 nnz, indices (u32 LE), values
/// (f32 LE).  ~10x faster to load than text for multi-million-nnz tensors.
pub fn write_binary(t: &SparseTensor, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(t.order() as u32).to_le_bytes())?;
    for d in &t.dims {
        w.write_all(&d.to_le_bytes())?;
    }
    w.write_all(&(t.nnz() as u64).to_le_bytes())?;
    // bulk-write via byte reinterpretation (LE host assumed; checked below)
    w.write_all(as_bytes_u32(&t.indices))?;
    w.write_all(as_bytes_f32(&t.values))?;
    Ok(())
}

/// Read a binary `FTB1` file written by [`write_binary`].
pub fn read_binary(path: &Path) -> Result<SparseTensor> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not an FTB1 file");
    }
    let order = read_u32(&mut r)? as usize;
    if !(2..=16).contains(&order) {
        bail!("implausible order {order}");
    }
    let mut dims = Vec::with_capacity(order);
    for _ in 0..order {
        dims.push(read_u32(&mut r)?);
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let nnz = u64::from_le_bytes(b8) as usize;
    let mut t = SparseTensor::new(dims);
    t.indices = read_vec_u32(&mut r, nnz * order)?;
    t.values = read_vec_f32(&mut r, nnz)?;
    t.validate()?;
    Ok(t)
}

/// Load either format by extension (`.ftb` binary, anything else text).
pub fn read_auto(path: &Path) -> Result<SparseTensor> {
    if path.extension().map(|e| e == "ftb").unwrap_or(false) {
        read_binary(path)
    } else {
        read_text(path)
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_vec_u32<R: Read>(r: &mut R, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_vec_f32<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(target_endian = "little")]
fn as_bytes_u32(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(target_endian = "little")]
fn as_bytes_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// The toy dataset shipped with the repo (mirrors the paper's reproducibility
/// toy data): a deterministic 8x8x8 low-rank tensor with 64 observed entries.
pub fn toy_dataset() -> SparseTensor {
    use crate::util::rng::Pcg32;
    let mut rng = Pcg32::new(0xF057_70CE, 0);
    let dims = vec![8u32, 8, 8];
    // rank-2 ground truth factors
    let f: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..8 * 2).map(|_| rng.gen_normal() * 0.7 + 0.5).collect())
        .collect();
    let mut t = SparseTensor::new(dims);
    for _ in 0..64 {
        let c = [
            rng.gen_range(8),
            rng.gen_range(8),
            rng.gen_range(8),
        ];
        let mut v = 0.0f32;
        for r in 0..2 {
            v += f[0][c[0] as usize * 2 + r] * f[1][c[1] as usize * 2 + r]
                * f[2][c[2] as usize * 2 + r];
        }
        t.push(&c, v + rng.gen_normal() * 0.01);
    }
    t.sort_dedup();
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_roundtrip() {
        let t = toy_dataset();
        let dir = std::env::temp_dir().join("ft_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.coo");
        write_text(&t, &p).unwrap();
        let u = read_text(&p).unwrap();
        assert_eq!(t.dims, u.dims);
        assert_eq!(t.indices, u.indices);
        for (a, b) in t.values.iter().zip(&u.values) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let t = toy_dataset();
        let dir = std::env::temp_dir().join("ft_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.ftb");
        write_binary(&t, &p).unwrap();
        let u = read_binary(&p).unwrap();
        assert_eq!(t.dims, u.dims);
        assert_eq!(t.indices, u.indices);
        assert_eq!(t.values, u.values); // bit-exact
    }

    #[test]
    fn parse_text_errors() {
        assert!(parse_text("".as_bytes()).is_err());
        assert!(parse_text("dims 4 4\n0 0\n".as_bytes()).is_err()); // missing value
        assert!(parse_text("dims 4 4\n9 0 1.0\n".as_bytes()).is_err()); // oob
        assert!(parse_text("nodims\n".as_bytes()).is_err());
        assert!(parse_text("dims 4 4\n0 0 1.0 extra\n".as_bytes()).is_err());
    }

    #[test]
    fn parse_text_with_comments() {
        let t = parse_text("# hi\ndims 2 2\n0 0 1.5 # entry\n1 1 2.5\n".as_bytes()).unwrap();
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.values, vec![1.5, 2.5]);
    }

    #[test]
    fn toy_is_deterministic() {
        let a = toy_dataset();
        let b = toy_dataset();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.values, b.values);
        assert!(a.nnz() > 32);
    }
}
