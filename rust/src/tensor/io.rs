//! Tensor I/O: a text COO format (one `i1 i2 ... iN value` line per entry,
//! whitespace-separated, `#` comments, 0-based indices) and a faster binary
//! format (`FTB1`) for benchmark datasets.  The paged `FTB2` store lives in
//! [`crate::data::store`]; [`read_auto`] dispatches to all three by
//! extension.
//!
//! The text parser is *streaming*: [`parse_text_into`] pushes the dims
//! header and every entry into an [`EntrySink`] as lines are read, holding
//! O(1) memory — [`parse_text`] builds a [`SparseTensor`] sink on top, and
//! the constant-memory ingester ([`crate::data::ingest`]) streams the same
//! lines straight into an on-disk store.  Every malformed line fails with
//! its 1-based line number (pinned by a mutation property test), and
//! [`read_binary`] cross-checks the header's entry count against the real
//! file length before allocating, so truncated or hostile files error out
//! instead of OOMing.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::coo::SparseTensor;

// ======================================================================
// Text format
// ======================================================================

/// Receiver of the streaming text parser's events (header, then entries).
pub trait EntrySink {
    /// The `dims I1 ... IN` header (exactly once, before any entry).
    fn on_dims(&mut self, dims: &[u32]) -> Result<()>;
    /// One bounds-checked, finite entry, in file order.
    fn on_entry(&mut self, coords: &[u32], value: f32) -> Result<()>;
}

/// Read a text COO file.  First non-comment line must be the header:
/// `dims I1 I2 ... IN`.
pub fn read_text(path: &Path) -> Result<SparseTensor> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    parse_text(BufReader::new(f))
}

/// Parse the text COO format from any reader (see [`read_text`]).
pub fn parse_text<R: BufRead>(r: R) -> Result<SparseTensor> {
    struct Builder(Option<SparseTensor>);
    impl EntrySink for Builder {
        fn on_dims(&mut self, dims: &[u32]) -> Result<()> {
            self.0 = Some(SparseTensor::new(dims.to_vec()));
            Ok(())
        }
        fn on_entry(&mut self, coords: &[u32], value: f32) -> Result<()> {
            let t = self.0.as_mut().expect("header precedes entries");
            t.push(coords, value);
            Ok(())
        }
    }
    let mut b = Builder(None);
    parse_text_into(r, &mut b)?;
    let t = b.0.expect("parse_text_into guarantees a dims header");
    t.validate()?; // belt and braces; the parser already bounds-checks
    Ok(t)
}

/// Streaming core of the text parser: feed the header and every entry to
/// `sink` as lines are read (O(1) memory for O(1)-memory sinks).
///
/// Guarantees on malformed input: every error is `Err` (never a panic)
/// and carries the offending 1-based line number — bad tokens, missing or
/// trailing fields, out-of-bounds indices and non-finite values are all
/// rejected at their line.
pub fn parse_text_into<R: BufRead>(r: R, sink: &mut dyn EntrySink) -> Result<()> {
    let mut dims: Option<Vec<u32>> = None;
    let mut coords: Vec<u32> = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let lineno = lineno + 1;
        let line = line.with_context(|| format!("line {lineno}: read error"))?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        match &dims {
            None => {
                if toks.next() != Some("dims") {
                    bail!("line {lineno}: expected 'dims I1 ... IN' header");
                }
                let d: Vec<u32> = toks
                    .map(|t| t.parse().map_err(|_| anyhow!("line {lineno}: bad dim {t:?}")))
                    .collect::<Result<_>>()?;
                if d.len() < 2 {
                    bail!("line {lineno}: need at least 2 dims");
                }
                sink.on_dims(&d)?;
                coords = Vec::with_capacity(d.len());
                dims = Some(d);
            }
            Some(d) => {
                coords.clear();
                for (m, &dim) in d.iter().enumerate() {
                    let tok = toks
                        .next()
                        .with_context(|| format!("line {lineno}: too few indices"))?;
                    let ix: u32 = tok
                        .parse()
                        .map_err(|_| anyhow!("line {lineno}: bad index {tok:?}"))?;
                    if ix >= dim {
                        bail!("line {lineno}: mode-{m} index {ix} out of bounds (dim {dim})");
                    }
                    coords.push(ix);
                }
                let vtok = toks
                    .next()
                    .with_context(|| format!("line {lineno}: missing value"))?;
                let v: f32 = vtok
                    .parse()
                    .map_err(|_| anyhow!("line {lineno}: bad value {vtok:?}"))?;
                if !v.is_finite() {
                    bail!("line {lineno}: non-finite value {vtok:?}");
                }
                if toks.next().is_some() {
                    bail!("line {lineno}: trailing tokens");
                }
                sink.on_entry(&coords, v)?;
            }
        }
    }
    if dims.is_none() {
        bail!("empty tensor file");
    }
    Ok(())
}

/// Write the text COO format to any writer (`dims` header + one entry per
/// line).  Values print as their shortest round-tripping decimal, so
/// `write → parse` recovers every `f32` bit-exactly.
pub fn write_text_to<W: Write>(t: &SparseTensor, w: &mut W) -> Result<()> {
    write!(w, "dims")?;
    for d in &t.dims {
        write!(w, " {d}")?;
    }
    writeln!(w)?;
    for e in 0..t.nnz() {
        for c in t.coords(e) {
            write!(w, "{c} ")?;
        }
        writeln!(w, "{}", t.values[e])?;
    }
    Ok(())
}

/// Write the text COO format to a file (see [`write_text_to`]).
pub fn write_text(t: &SparseTensor, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    write_text_to(t, &mut w)?;
    w.flush()?;
    Ok(())
}

// ======================================================================
// FTB1 binary format
// ======================================================================

const MAGIC: &[u8; 4] = b"FTB1";

/// Parsed `FTB1` header: magic, u32 order, dims, u64 nnz — followed in
/// the file by all coordinates (u32 LE, entry-major) and then all values
/// (f32 LE).
#[derive(Clone, Debug, PartialEq)]
pub struct Ftb1Header {
    /// Dimension sizes `I_n`.
    pub dims: Vec<u32>,
    /// Number of stored entries.
    pub nnz: u64,
}

impl Ftb1Header {
    /// Header length in bytes (magic + order + dims + nnz).
    pub fn header_len(&self) -> u64 {
        16 + 4 * self.dims.len() as u64
    }

    /// Absolute offset of the values block (after all coordinates).
    pub fn values_offset(&self) -> u64 {
        self.header_len() + self.nnz * 4 * self.dims.len() as u64
    }

    /// Payload bytes the header implies (coords + values), with
    /// overflow-checked arithmetic.
    pub fn payload_len(&self) -> Result<u64> {
        self.nnz
            .checked_mul(self.dims.len() as u64 + 1)
            .and_then(|x| x.checked_mul(4))
            .ok_or_else(|| anyhow!("nnz {} overflows the addressable payload", self.nnz))
    }

    /// Reject a header whose implied size disagrees with the actual file
    /// length — a truncated or hostile `nnz` fails here *before* any
    /// entry-count-sized allocation can OOM.
    pub fn check_len(&self, file_len: u64) -> Result<()> {
        let need = self.payload_len()?;
        let have = file_len.saturating_sub(self.header_len());
        if have != need {
            bail!(
                "header claims {} entries ({need} payload bytes) but the file has \
                 {have} bytes after the header (truncated or corrupt)",
                self.nnz
            );
        }
        Ok(())
    }
}

/// Read and sanity-check an `FTB1` header from `r`.
pub fn read_ftb1_header<R: Read>(r: &mut R) -> Result<Ftb1Header> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an FTB1 file");
    }
    let order = read_u32(r)? as usize;
    if !(2..=16).contains(&order) {
        bail!("implausible order {order}");
    }
    let mut dims = Vec::with_capacity(order);
    for _ in 0..order {
        dims.push(read_u32(r)?);
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let nnz = u64::from_le_bytes(b8);
    Ok(Ftb1Header { dims, nnz })
}

/// Binary format: magic, u32 order, dims, u64 nnz, indices (u32 LE), values
/// (f32 LE).  ~10x faster to load than text for multi-million-nnz tensors.
pub fn write_binary(t: &SparseTensor, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(t.order() as u32).to_le_bytes())?;
    for d in &t.dims {
        w.write_all(&d.to_le_bytes())?;
    }
    w.write_all(&(t.nnz() as u64).to_le_bytes())?;
    // bulk-write via byte reinterpretation (LE host assumed; checked below)
    w.write_all(as_bytes_u32(&t.indices))?;
    w.write_all(as_bytes_f32(&t.values))?;
    Ok(())
}

/// Read a binary `FTB1` file written by [`write_binary`].  The header's
/// `nnz` is cross-checked against the file length (see
/// [`Ftb1Header::check_len`]) before anything is allocated.
pub fn read_binary(path: &Path) -> Result<SparseTensor> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let stat = f.metadata().with_context(|| format!("stat {path:?}"))?;
    let file_len = stat.len();
    let mut r = BufReader::new(f);
    let header = read_ftb1_header(&mut r).with_context(|| format!("{path:?}"))?;
    header.check_len(file_len).with_context(|| format!("{path:?}"))?;
    let nnz = header.nnz as usize;
    let mut t = SparseTensor::new(header.dims);
    let order = t.order();
    t.indices = read_vec_u32(&mut r, nnz * order)?;
    t.values = read_vec_f32(&mut r, nnz)?;
    t.validate()?;
    Ok(t)
}

/// Load any supported format by extension: `.ftb` is `FTB1` binary,
/// `.ftb2` is the paged store (materialized — use
/// [`crate::data::PagedTensor`] to keep it out of core), anything else is
/// text.
pub fn read_auto(path: &Path) -> Result<SparseTensor> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("ftb") => read_binary(path),
        Some("ftb2") => crate::data::store::read_store(path),
        _ => read_text(path),
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_vec_u32<R: Read>(r: &mut R, n: usize) -> Result<Vec<u32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_vec_f32<R: Read>(r: &mut R, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(target_endian = "little")]
fn as_bytes_u32(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(target_endian = "little")]
fn as_bytes_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// The toy dataset shipped with the repo (mirrors the paper's reproducibility
/// toy data): a deterministic 8x8x8 low-rank tensor with 64 observed entries.
pub fn toy_dataset() -> SparseTensor {
    use crate::util::rng::Pcg32;
    let mut rng = Pcg32::new(0xF057_70CE, 0);
    let dims = vec![8u32, 8, 8];
    // rank-2 ground truth factors
    let f: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..8 * 2).map(|_| rng.gen_normal() * 0.7 + 0.5).collect())
        .collect();
    let mut t = SparseTensor::new(dims);
    for _ in 0..64 {
        let c = [
            rng.gen_range(8),
            rng.gen_range(8),
            rng.gen_range(8),
        ];
        let mut v = 0.0f32;
        for r in 0..2 {
            v += f[0][c[0] as usize * 2 + r] * f[1][c[1] as usize * 2 + r]
                * f[2][c[2] as usize * 2 + r];
        }
        t.push(&c, v + rng.gen_normal() * 0.01);
    }
    t.sort_dedup();
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_roundtrip() {
        let t = toy_dataset();
        let dir = std::env::temp_dir().join("ft_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.coo");
        write_text(&t, &p).unwrap();
        let u = read_text(&p).unwrap();
        assert_eq!(t.dims, u.dims);
        assert_eq!(t.indices, u.indices);
        for (a, b) in t.values.iter().zip(&u.values) {
            assert_eq!(a.to_bits(), b.to_bits()); // shortest-decimal exact
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let t = toy_dataset();
        let dir = std::env::temp_dir().join("ft_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.ftb");
        write_binary(&t, &p).unwrap();
        let u = read_binary(&p).unwrap();
        assert_eq!(t.dims, u.dims);
        assert_eq!(t.indices, u.indices);
        assert_eq!(t.values, u.values); // bit-exact
    }

    #[test]
    fn parse_text_errors() {
        assert!(parse_text("".as_bytes()).is_err());
        assert!(parse_text("dims 4 4\n0 0\n".as_bytes()).is_err()); // missing value
        assert!(parse_text("dims 4 4\n9 0 1.0\n".as_bytes()).is_err()); // oob
        assert!(parse_text("nodims\n".as_bytes()).is_err());
        assert!(parse_text("dims 4 4\n0 0 1.0 extra\n".as_bytes()).is_err());
        assert!(parse_text("dims 4 4\n0 0 nan\n".as_bytes()).is_err()); // non-finite
        assert!(parse_text("dims 4\n".as_bytes()).is_err()); // < 2 dims
    }

    #[test]
    fn parse_text_errors_carry_line_numbers() {
        let err = parse_text("dims 4 4\n0 0 1.0\n0 5 2.0\n".as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");
        let err = parse_text("wrong\n".as_bytes()).unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
    }

    #[test]
    fn parse_text_with_comments() {
        let t = parse_text("# hi\ndims 2 2\n0 0 1.5 # entry\n1 1 2.5\n".as_bytes()).unwrap();
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.values, vec![1.5, 2.5]);
    }

    #[test]
    fn read_binary_rejects_hostile_nnz_before_allocating() {
        let dir = std::env::temp_dir().join("ft_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("hostile.ftb");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"FTB1");
        bytes.extend_from_slice(&3u32.to_le_bytes());
        for d in [4u32, 4, 4] {
            bytes.extend_from_slice(&d.to_le_bytes());
        }
        // a claimed u64::MAX entries would overflow / OOM a trusting reader
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_binary(&p).is_err());
        // truncation of a real file is caught by the same length check
        let t = toy_dataset();
        write_binary(&t, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        std::fs::write(&p, &good[..good.len() - 7]).unwrap();
        assert!(read_binary(&p).is_err());
        // trailing garbage is also a length mismatch
        let mut long = good.clone();
        long.extend_from_slice(&[0u8; 3]);
        std::fs::write(&p, &long).unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn toy_is_deterministic() {
        let a = toy_dataset();
        let b = toy_dataset();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.values, b.values);
        assert!(a.nnz() > 32);
    }
}
