//! Secondary indexes over a COO tensor, one per sampling constraint in the
//! paper's Table 3:
//!
//! * [`ModeSliceIndex`] — `Ω_{i_n}^(n)`: entries whose mode-`n` index is
//!   `i_n` (FastTucker / Alg. 1 sampling).
//! * [`FiberIndex`] — `Ω^(n)_{i_1..i_{n-1},i_{n+1}..i_N}`: entries sharing
//!   all indices *except* mode `n` (FasterTucker / Alg. 2 sampling).
//!
//! Both are CSR-style (offsets + entry ids), built in O(nnz).

use super::coo::SparseTensor;

/// CSR-style index: for each mode-`n` slice value `i`, the entry ids whose
/// mode-`n` coordinate equals `i`.
#[derive(Clone, Debug)]
pub struct ModeSliceIndex {
    /// The mode this index groups by.
    pub mode: usize,
    /// offsets.len() == dims[mode] + 1
    pub offsets: Vec<u32>,
    /// entry ids grouped by slice, len == nnz
    pub entries: Vec<u32>,
}

impl ModeSliceIndex {
    /// Build the index for `mode` in O(nnz) (counting sort by slice).
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        let dim = t.dims[mode] as usize;
        let n = t.order();
        let mut counts = vec![0u32; dim + 1];
        for e in 0..t.nnz() {
            counts[t.indices[e * n + mode] as usize + 1] += 1;
        }
        for i in 0..dim {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut entries = vec![0u32; t.nnz()];
        for e in 0..t.nnz() {
            let slice = t.indices[e * n + mode] as usize;
            entries[cursor[slice] as usize] = e as u32;
            cursor[slice] += 1;
        }
        Self {
            mode,
            offsets,
            entries,
        }
    }

    /// Entry ids in slice `i`.
    pub fn slice(&self, i: usize) -> &[u32] {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        &self.entries[lo..hi]
    }

    /// Number of non-empty slices.
    pub fn non_empty(&self) -> usize {
        (0..self.offsets.len() - 1)
            .filter(|&i| self.offsets[i + 1] > self.offsets[i])
            .count()
    }

    /// Load-imbalance statistic: max slice size / mean slice size over
    /// non-empty slices (the paper's load-balancing critique of Alg. 1).
    pub fn imbalance(&self) -> f64 {
        let mut max = 0u32;
        let mut total = 0u64;
        let mut nonzero = 0u64;
        for i in 0..self.offsets.len() - 1 {
            let sz = self.offsets[i + 1] - self.offsets[i];
            if sz > 0 {
                max = max.max(sz);
                total += sz as u64;
                nonzero += 1;
            }
        }
        if nonzero == 0 {
            return 1.0;
        }
        max as f64 / (total as f64 / nonzero as f64)
    }
}

/// Fiber index for mode `n`: groups entries by their coordinates in all
/// modes except `n`.  Grouping key is a 64-bit FNV-1a hash of those
/// coordinates; collisions are resolved by exact comparison during build.
#[derive(Clone, Debug)]
pub struct FiberIndex {
    /// The excluded mode (fibers run along this mode).
    pub mode: usize,
    /// offsets into `entries`, one per fiber (+1).
    pub offsets: Vec<u32>,
    /// entry ids grouped by fiber.
    pub entries: Vec<u32>,
}

impl FiberIndex {
    /// Build the index for `mode` by sorting entry ids on the remaining
    /// coordinates.
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        let n = t.order();
        let nnz = t.nnz();
        // Sort entry ids by the "all but `mode`" coordinate tuple.
        let mut ids: Vec<u32> = (0..nnz as u32).collect();
        let key = |e: u32| -> &[u32] { &t.indices[e as usize * n..(e as usize + 1) * n] };
        let cmp_wo_mode = |a: &[u32], b: &[u32]| {
            for m in 0..n {
                if m == mode {
                    continue;
                }
                match a[m].cmp(&b[m]) {
                    std::cmp::Ordering::Equal => continue,
                    o => return o,
                }
            }
            std::cmp::Ordering::Equal
        };
        ids.sort_unstable_by(|&a, &b| cmp_wo_mode(key(a), key(b)));
        let mut offsets = vec![0u32];
        for w in 1..=nnz {
            if w == nnz || cmp_wo_mode(key(ids[w - 1]), key(ids[w])) != std::cmp::Ordering::Equal
            {
                offsets.push(w as u32);
            }
        }
        Self {
            mode,
            offsets,
            entries: ids,
        }
    }

    /// Number of distinct fibers.
    pub fn num_fibers(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Entry ids of fiber `f`.
    pub fn fiber(&self, f: usize) -> &[u32] {
        let lo = self.offsets[f] as usize;
        let hi = self.offsets[f + 1] as usize;
        &self.entries[lo..hi]
    }

    /// Mean fiber length — the paper notes most fibers hold far fewer than
    /// M entries, causing padding waste in Alg. 2.
    pub fn mean_len(&self) -> f64 {
        if self.num_fibers() == 0 {
            return 0.0;
        }
        self.entries.len() as f64 / self.num_fibers() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> SparseTensor {
        let mut t = SparseTensor::new(vec![3, 3, 3]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[0, 1, 0], 2.0);
        t.push(&[1, 1, 0], 3.0);
        t.push(&[2, 1, 0], 4.0);
        t.push(&[2, 2, 2], 5.0);
        t
    }

    #[test]
    fn mode_slice_groups() {
        let idx = ModeSliceIndex::build(&t(), 0);
        assert_eq!(idx.slice(0), &[0, 1]);
        assert_eq!(idx.slice(1), &[2]);
        assert_eq!(idx.slice(2), &[3, 4]);
        assert_eq!(idx.non_empty(), 3);
    }

    #[test]
    fn mode_slice_all_modes() {
        let t = t();
        for mode in 0..3 {
            let idx = ModeSliceIndex::build(&t, mode);
            let total: usize = (0..t.dims[mode] as usize).map(|i| idx.slice(i).len()).sum();
            assert_eq!(total, t.nnz());
            for i in 0..t.dims[mode] as usize {
                for &e in idx.slice(i) {
                    assert_eq!(t.coords(e as usize)[mode] as usize, i);
                }
            }
        }
    }

    #[test]
    fn fiber_groups_share_other_coords() {
        let t = t();
        // mode 0 fibers: entries sharing (i2, i3).
        let idx = FiberIndex::build(&t, 0);
        // (0,0): e0 ; (1,0): e1,e2,e3 ; (2,2): e4  => 3 fibers
        assert_eq!(idx.num_fibers(), 3);
        let sizes: Vec<usize> = (0..3).map(|f| idx.fiber(f).len()).collect();
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 1, 3]);
        for f in 0..idx.num_fibers() {
            let ids = idx.fiber(f);
            let c0 = t.coords(ids[0] as usize);
            for &e in ids {
                let c = t.coords(e as usize);
                assert_eq!(c[1], c0[1]);
                assert_eq!(c[2], c0[2]);
            }
        }
    }

    #[test]
    fn imbalance_statistic() {
        let idx = ModeSliceIndex::build(&t(), 1);
        // slices: i1=0 -> 1 entry, i1=1 -> 3, i1=2 -> 1 ; mean=5/3
        assert!((idx.imbalance() - 3.0 / (5.0 / 3.0)).abs() < 1e-9);
    }
}
