//! # fasttucker — a reproduction of *cuFastTuckerPlus* (CS.DC 2024)
//!
//! Stochastic parallel sparse FastTucker decomposition, built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`), the paper's
//!   tensor-core hot spot re-thought for the TPU MXU (WMMA 16x16x16 tiles →
//!   MXU-shaped `[S,J]x[J,R]` matmuls), lowered once at build time.
//! * **L2** — JAX step functions (`python/compile/model.py`) AOT-exported to
//!   HLO text artifacts (`make artifacts`).
//! * **L3** — this crate: the coordinator, itself layered as
//!   `coordinator::trainer` (thin driver) → `coordinator::phases` (generic
//!   factor/core phase logic) → `sampler::stream` (pipelined block
//!   scheduler: sample/stage block *k+1* while block *k* executes) →
//!   `coordinator::backend::StepBackend` (pluggable execution) →
//!   `runtime::Engine` (PJRT) or [`kernel`] (tiled CPU microkernels, with
//!   `cpu_ref::step` as the scalar oracle behind `--cpu-kernel scalar`
//!   and a runtime-dispatched AVX2/NEON SIMD tier behind
//!   `--cpu-kernel simd` — see [`kernel::simd`]).
//!
//! Execution backends (`--backend` on the CLI, [`prelude::Backend`] in
//! code):
//!
//! * `hlo` — compiled PJRT/HLO artifacts, the system under test;
//! * `cpu` — the sequential CPU reference (tiled kernels, scalar oracle
//!   behind a flag);
//! * `parallel` — Hogwild multi-threaded CPU engine: block slots
//!   sharded across workers with lock-free scatter into the factor
//!   matrices ([`model::SharedFactors`]).
//!
//! On top of training sit two subsystems:
//!
//! * the **session layer** ([`session`]) — the public entry point: a
//!   declarative, validated, JSON-serializable [`prelude::RunSpec`]
//!   (data source + config + schedule) executed by a
//!   [`prelude::Session`], which owns the train/test split and the
//!   epoch loop (evaluation cadence, early stopping, learning-rate
//!   decay, checkpoints, serve publishes) and emits
//!   [`session::EpochEvent`]s to pluggable [`session::Observer`]s;
//! * the **serving subsystem** ([`serve`]) — immutable published
//!   snapshots with a versioned on-disk checkpoint format, a batched
//!   query engine whose predictions are bit-identical to the trainer's
//!   evaluation path, mode-completion top-K scoring (the recommender
//!   query), and a threaded request loop with batching and snapshot
//!   hot-swap so training and serving run concurrently.  On top sits
//!   the **network tier** ([`serve::net`]): a std-only non-blocking
//!   TCP front end (newline-delimited JSON frames, request pipelining,
//!   per-request deadlines, admission control with explicit overload
//!   shedding, graceful drain), a named+versioned model [`serve::Registry`]
//!   with atomic promote/rollback, a cross-request fiber-invariant
//!   completion cache, and a closed-loop SLO load harness
//!   (`serve --listen` / `query --connect` / `registry` / `slo` on the
//!   CLI).
//!
//! Underneath both sits the **data layer** ([`data`]): the checksummed
//! `FTB2` paged tensor store, a constant-memory streaming ingester
//! (`fasttucker ingest`), and the [`data::TensorView`] abstraction that
//! lets the sampling/staging pipeline gather from RAM or straight from
//! disk ([`data::PagedTensor`]) — the out-of-core path the paper's
//! HOHDST motivation calls for, bit-identical to the in-RAM path.
//!
//! Scaling out sits the **distributed layer** ([`dist`]): a pure,
//! tick-driven coordinator state machine dealing disjoint section
//! ranges to N workers each round, with heartbeat-based eviction and
//! barrier model averaging (`train --workers N`; the in-process thread
//! backend today, with every protocol type JSON-serializable so a wire
//! backend is a drop-in).  One worker reproduces the serial trainer
//! byte for byte.
//!
//! Cutting across every layer is the **telemetry layer** ([`obs`]): a
//! zero-dependency metrics registry (lock-free counters, gauges, and
//! log-bucketed latency histograms), scoped timers, JSONL/text
//! exporters, and a bounded flight recorder taping the dist protocol —
//! switched on with `--metrics FILE` (`RunSpec.metrics`) and strictly
//! passive otherwise.
//!
//! Supporting modules: sparse tensor substrate ([`tensor`]), the three
//! Table-3 sampling strategies ([`sampler`]), model state + gather/scatter
//! ([`model`]), the tiled CPU kernels ([`kernel`]), analytic cost models
//! ([`cost`]), the bench harness ([`bench`]), synthetic datasets
//! ([`synth`]), and utilities ([`util`]).  See `ARCHITECTURE.md` for the
//! full layering diagram and `BENCHMARKS.md` for the paper-table bench
//! suite.
//!
//! Python never runs at decomposition time; the binary is self-contained
//! once `artifacts/` exists, and the CPU backends need no artifacts at all.
//!
//! ## Quick start
//!
//! ```no_run
//! use fasttucker::prelude::*;
//! use fasttucker::session::{DataSource, SynthPreset, SynthSpec};
//!
//! // describe the run declaratively (this spec round-trips to JSON —
//! // the CLI's `--dump-spec` / `--spec FILE` use the same type)
//! let mut spec = RunSpec::default(); // toy data, auto backend
//! spec.data = DataSource::Synth(SynthSpec {
//!     preset: SynthPreset::Order,
//!     order: 3,
//!     dim: 64,
//!     nnz: 10_000,
//!     seed: 1,
//! });
//! spec.schedule.epochs = 10;
//!
//! // validate + split + build the trainer, then execute the schedule
//! let mut session = Session::from_spec(&spec).unwrap();
//! let report = session.run(&mut ProgressPrinter).unwrap();
//! println!("best RMSE {:?} in {} epochs", report.best_rmse, report.epochs_run);
//! ```
//!
//! The [`prelude::Trainer`] remains available underneath for callers
//! that need epoch-level control ([`session::Session::trainer_mut`]).

#![warn(missing_docs)]

pub mod bench;
pub mod coordinator;
pub mod cost;
pub mod cpu_ref;
pub mod data;
pub mod dist;
pub mod kernel;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod session;
pub mod synth;
pub mod tensor;
pub mod util;

/// The handful of types most programs need: the session entry point
/// (spec + driver + observers), config enums, the trainer, the model,
/// the sparse tensor and the serving snapshot.
pub mod prelude {
    pub use crate::coordinator::config::{Algo, Backend, Strategy, TrainConfig, Variant};
    pub use crate::coordinator::trainer::Trainer;
    pub use crate::data::{PagedTensor, TensorView};
    pub use crate::kernel::KernelPolicy;
    pub use crate::model::TuckerModel;
    pub use crate::serve::ModelSnapshot;
    pub use crate::session::{Observer, ProgressPrinter, RunSpec, Schedule, Session};
    pub use crate::tensor::SparseTensor;
}
