//! # fasttucker — a reproduction of *cuFastTuckerPlus* (CS.DC 2024)
//!
//! Stochastic parallel sparse FastTucker decomposition, built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`), the paper's
//!   tensor-core hot spot re-thought for the TPU MXU (WMMA 16x16x16 tiles →
//!   MXU-shaped `[S,J]x[J,R]` matmuls), lowered once at build time.
//! * **L2** — JAX step functions (`python/compile/model.py`) AOT-exported to
//!   HLO text artifacts (`make artifacts`).
//! * **L3** — this crate: the coordinator.  Sparse tensor substrate, the
//!   three Table-3 sampling strategies, gather/scatter batch assembly, the
//!   PJRT runtime that executes the artifacts, trainers for all three
//!   algorithms (FastTucker / FasterTucker / FastTuckerPlus), analytic cost
//!   models, benchmarks for every table and figure in the paper, and a CLI.
//!
//! Python never runs at decomposition time; the binary is self-contained
//! once `artifacts/` exists.
//!
//! ## Quick start
//!
//! ```no_run
//! use fasttucker::prelude::*;
//!
//! let tensor = fasttucker::synth::generate(
//!     &fasttucker::synth::SynthConfig::order_sweep(3, 64, 10_000, 1));
//! let (train, test) = fasttucker::tensor::split::train_test_split(&tensor, 0.2, 1);
//! let cfg = TrainConfig::default();
//! let mut trainer = Trainer::new(&train, cfg).unwrap();
//! for epoch in 0..10 {
//!     let stats = trainer.epoch(&train).unwrap();
//!     let (rmse, mae) = trainer.evaluate(&test).unwrap();
//!     println!("epoch {epoch}: rmse {rmse:.4} mae {mae:.4} ({stats:?})");
//! }
//! ```

pub mod bench;
pub mod coordinator;
pub mod cost;
pub mod cpu_ref;
pub mod model;
pub mod runtime;
pub mod sampler;
pub mod synth;
pub mod tensor;
pub mod util;

pub mod prelude {
    pub use crate::coordinator::config::{Algo, Strategy, TrainConfig, Variant};
    pub use crate::coordinator::trainer::Trainer;
    pub use crate::model::TuckerModel;
    pub use crate::tensor::SparseTensor;
}
