//! Streaming block scheduler: lazy block generation + pipelined staging.
//!
//! Two pieces replace the eager `Vec<Block>` materialization in the
//! training hot path:
//!
//! * [`BlockIter`] — a lazy generator with one constructor per Table-3
//!   sampling strategy.  It yields [`Block`]s one at a time and is the
//!   single source of truth for block construction: the eager helpers in
//!   the parent module (`uniform_blocks`, `mode_slice_blocks`, ...) are
//!   now thin `collect()`s over it, so streaming and eager block lists are
//!   identical by construction (and pinned by a property test).
//! * [`StagedStream`] — a double-buffered producer running on a scoped
//!   thread: it samples block *k+1* and stages its coordinate/value slabs
//!   while the consumer executes block *k* (the gather/compute overlap the
//!   paper's pipeline relies on).  A bounded channel of depth 2 gives the
//!   classic double buffer: one block in flight, one staged ahead.
//!
//! Staged slabs are full-size: `coords` is `[S, N]` with padded slots
//! carrying defined (zero) coordinates and `values` is `[S]` zero-padded,
//! so every downstream consumer sees a complete rectangular batch.  Each
//! block also carries the transposed `lanes` slab (`[N, S]`, mode-major):
//! one contiguous coordinate lane per mode, the layout the tiled CPU
//! kernels scan when they touch a single mode per sample (ALTO-style
//! linearized access — consecutive samples read consecutive words).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::{Scope, ScopedJoinHandle};

use crate::data::TensorView;
use crate::tensor::{FiberIndex, ModeSliceIndex};
use crate::util::rng::Pcg32;

use super::{Block, PAD, WARP_M};

/// One fully staged batch: compacted valid entries up front, padding after.
#[derive(Clone, Debug)]
pub struct StagedBlock {
    /// Entry coordinates, `[S, N]` entry-major; padded slots are all-zero
    /// (defined, inert — padded rows are masked by `valid` downstream).
    pub coords: Vec<u32>,
    /// The same coordinates transposed to `[N, S]` mode-major: lane `m` is
    /// `lanes[m * s..(m + 1) * s]`, contiguous per mode for the tiled CPU
    /// kernels.  Zero-padded past `valid` like `coords`.
    pub lanes: Vec<u32>,
    /// Entry values, `[S]`, zero-padded.
    pub values: Vec<f32>,
    /// Number of valid (non-padding) slots, compacted to the front.
    pub valid: usize,
    /// Total slot count S of the block.
    pub s: usize,
}

/// Materialize the coordinate/value slabs for a block.  Valid entries are
/// compacted to the front (sound for uniform sampling; grouped samplers
/// only pad at warp tails, so group order is preserved), and both slabs
/// are padded to their full `[S, N]` / `[S]` shapes.
///
/// Generic over [`TensorView`], so the gather reads from RAM or from the
/// paged `FTB2` store identically — the staged slabs are a pure function
/// of (view contents, block ids), which is what makes the out-of-core
/// path bit-identical to the in-RAM one.
///
/// Allocates fresh slabs per block: ~S·(2N+1) words, microseconds against
/// the milliseconds of per-block compute, and ownership then transfers
/// cleanly through the channel (a recycling return-path would complicate
/// the consumer for no measurable win at current block sizes).  The lane
/// transpose is built unconditionally — only the storage-scheme CPU
/// kernels read it, but it runs on the producer thread where the double
/// buffer hides it, and a conditional would leak backend knowledge into
/// the scheduler.
pub fn stage<T: TensorView + ?Sized>(t: &T, block: &Block) -> StagedBlock {
    let n = t.order();
    let s = block.ids.len();
    let mut coords = vec![0u32; s * n];
    let mut values = vec![0f32; s];
    let mut slot = 0usize;
    for &id in &block.ids {
        if id == PAD {
            continue;
        }
        values[slot] = t.load_entry(id as usize, &mut coords[slot * n..(slot + 1) * n]);
        slot += 1;
    }
    debug_assert_eq!(slot, block.valid);
    // transpose to mode-major lanes (one contiguous coordinate run per mode)
    let mut lanes = vec![0u32; n * s];
    for m in 0..n {
        let lane = &mut lanes[m * s..(m + 1) * s];
        for (e, dst) in lane.iter_mut().enumerate().take(slot) {
            *dst = coords[e * n + m];
        }
    }
    StagedBlock {
        coords,
        lanes,
        values,
        valid: slot,
        s,
    }
}

/// Lazy block generator — one state machine per sampling strategy.
pub struct BlockIter<'a> {
    s: usize,
    kind: Kind<'a>,
}

enum Kind<'a> {
    /// Shuffled full pass over Ω in chunks of S.
    Uniform { ids: Vec<u32>, pos: usize },
    /// Variable-length groups cut into 16-slot warps, warps packed into
    /// blocks of S (mode-slice and fiber sampling).
    Grouped {
        entries: &'a [u32],
        offsets: &'a [u32],
        order: Vec<u32>,
        group: usize,
        entry: usize,
        cur: Block,
        done: bool,
    },
    /// Fibers in shuffled order packed densely (no warp alignment).
    Dense {
        idx: &'a FiberIndex,
        order: Vec<u32>,
        group: usize,
        entry: usize,
        cur: Block,
        done: bool,
    },
}

impl<'a> BlockIter<'a> {
    /// FastTuckerPlus sampling: shuffled full pass over Ω.  Only the
    /// entry *count* is read here, so any [`TensorView`] (in-RAM or
    /// paged) with the same nnz yields the same id schedule.
    ///
    /// # Panics
    /// If `t.nnz() >= u32::MAX`: block ids are `u32` with `u32::MAX`
    /// reserved as the [`PAD`] sentinel, so larger tensors would silently
    /// wrap.  [`crate::coordinator::Trainer::new`] rejects such tensors
    /// with a clean error before any stream is built.
    pub fn uniform<T: TensorView + ?Sized>(
        t: &T,
        s: usize,
        seed: u64,
        epoch: u64,
    ) -> BlockIter<'a> {
        assert!(
            t.nnz() < u32::MAX as usize,
            "block ids are u32 (u32::MAX is the PAD sentinel); nnz {} does not fit",
            t.nnz()
        );
        let mut rng = Pcg32::new(seed, 0x0731 ^ epoch);
        let mut ids: Vec<u32> = (0..t.nnz() as u32).collect();
        rng.shuffle(&mut ids);
        BlockIter {
            s,
            kind: Kind::Uniform { ids, pos: 0 },
        }
    }

    /// FastTucker sampling: warp groups share the mode-`n` index.
    pub fn mode_slice(idx: &'a ModeSliceIndex, s: usize, seed: u64, epoch: u64) -> BlockIter<'a> {
        let mut rng = Pcg32::new(seed, 0x517C_E ^ (epoch << 8) ^ idx.mode as u64);
        Self::grouped(&idx.entries, &idx.offsets, s, &mut rng)
    }

    /// FasterTucker sampling: warp groups are fibers.
    pub fn fiber(idx: &'a FiberIndex, s: usize, seed: u64, epoch: u64) -> BlockIter<'a> {
        let mut rng = Pcg32::new(seed, 0xF1BE_12 ^ (epoch << 8) ^ idx.mode as u64);
        Self::grouped(&idx.entries, &idx.offsets, s, &mut rng)
    }

    fn grouped(
        entries: &'a [u32],
        offsets: &'a [u32],
        s: usize,
        rng: &mut Pcg32,
    ) -> BlockIter<'a> {
        debug_assert!(s % WARP_M == 0);
        let n_groups = offsets.len() - 1;
        let mut order: Vec<u32> = (0..n_groups as u32).collect();
        rng.shuffle(&mut order);
        BlockIter {
            s,
            kind: Kind::Grouped {
                entries,
                offsets,
                order,
                group: 0,
                entry: 0,
                cur: Block::new(s),
                done: false,
            },
        }
    }

    /// FasterTuckerCOO sampling: fibers shuffled, packed densely.
    pub fn fiber_coo(idx: &'a FiberIndex, s: usize, seed: u64, epoch: u64) -> BlockIter<'a> {
        let mut rng = Pcg32::new(seed, 0xF1BE_C0 ^ (epoch << 8) ^ idx.mode as u64);
        let mut order: Vec<u32> = (0..idx.num_fibers() as u32).collect();
        rng.shuffle(&mut order);
        BlockIter {
            s,
            kind: Kind::Dense {
                idx,
                order,
                group: 0,
                entry: 0,
                cur: Block::new(s),
                done: false,
            },
        }
    }

    /// Yield the next block, or `None` when the epoch's samples are spent.
    pub fn next_block(&mut self) -> Option<Block> {
        let s = self.s;
        match &mut self.kind {
            Kind::Uniform { ids, pos } => {
                if *pos >= ids.len() {
                    return None;
                }
                let hi = (*pos + s).min(ids.len());
                let mut b = Block::new(s);
                b.ids.extend_from_slice(&ids[*pos..hi]);
                *pos = hi;
                Some(b.seal(s))
            }
            Kind::Grouped {
                entries,
                offsets,
                order,
                group,
                entry,
                cur,
                done,
            } => {
                if *done {
                    return None;
                }
                while *group < order.len() {
                    let g = order[*group] as usize;
                    let lo = offsets[g] as usize;
                    let hi = offsets[g + 1] as usize;
                    if lo == hi {
                        *group += 1;
                        *entry = 0;
                        continue;
                    }
                    while lo + *entry < hi {
                        if cur.ids.len() + WARP_M > s {
                            let full = std::mem::replace(cur, Block::new(s));
                            return Some(full.seal(s));
                        }
                        let warp_hi = (lo + *entry + WARP_M).min(hi);
                        cur.ids.extend_from_slice(&entries[lo + *entry..warp_hi]);
                        *entry = warp_hi - lo;
                        // pad the warp tail so the next group starts on a
                        // warp boundary
                        cur.ids.resize(cur.ids.len().div_ceil(WARP_M) * WARP_M, PAD);
                    }
                    *group += 1;
                    *entry = 0;
                }
                *done = true;
                if cur.ids.is_empty() {
                    None
                } else {
                    let tail = std::mem::replace(cur, Block::new(s));
                    Some(tail.seal(s))
                }
            }
            Kind::Dense {
                idx,
                order,
                group,
                entry,
                cur,
                done,
            } => {
                if *done {
                    return None;
                }
                while *group < order.len() {
                    let fiber = idx.fiber(order[*group] as usize);
                    while *entry < fiber.len() {
                        if cur.ids.len() == s {
                            let full = std::mem::replace(cur, Block::new(s));
                            return Some(full.seal(s));
                        }
                        cur.ids.push(fiber[*entry]);
                        *entry += 1;
                    }
                    *group += 1;
                    *entry = 0;
                }
                *done = true;
                if cur.ids.is_empty() {
                    None
                } else {
                    let tail = std::mem::replace(cur, Block::new(s));
                    Some(tail.seal(s))
                }
            }
        }
    }

    /// Drain into an eager block list (the pre-scheduler API shape).
    pub fn collect_blocks(mut self) -> Vec<Block> {
        let mut out = Vec::new();
        while let Some(b) = self.next_block() {
            out.push(b);
        }
        out
    }
}

/// Channel depth of the staging pipeline: one block staged ahead of the
/// one in flight (double buffer).
const PIPELINE_DEPTH: usize = 2;

/// A pipelined staging stream: a scoped producer thread runs the
/// [`BlockIter`] and stages each block's slabs, the consumer pulls
/// [`StagedBlock`]s.  Dropping the stream (e.g. on an error path) unblocks
/// the producer via channel disconnect; the enclosing [`std::thread::scope`]
/// joins it.
pub struct StagedStream<'scope> {
    rx: Receiver<StagedBlock>,
    _producer: ScopedJoinHandle<'scope, ()>,
}

impl<'scope> StagedStream<'scope> {
    /// Spawn the producer on `scope`.  `tensor` and everything `iter`
    /// borrows must outlive the scope (`'env`).  The view is shared with
    /// the producer thread ([`TensorView`] is `Sync`), so staging gathers
    /// from RAM or from a paged store through the same code path.
    pub fn spawn<'env, T: TensorView + ?Sized>(
        scope: &'scope Scope<'scope, 'env>,
        tensor: &'env T,
        iter: BlockIter<'env>,
    ) -> StagedStream<'scope> {
        let (tx, rx) = sync_channel::<StagedBlock>(PIPELINE_DEPTH);
        let producer = scope.spawn(move || {
            let mut iter = iter;
            while let Some(block) = iter.next_block() {
                let staged = stage(tensor, &block);
                if tx.send(staged).is_err() {
                    // consumer hung up (error path) — stop producing
                    return;
                }
            }
        });
        StagedStream {
            rx,
            _producer: producer,
        }
    }

    /// Next staged block, or `None` at end of epoch.  Blocks only when the
    /// producer is behind — that wait is the *exposed* staging time.
    pub fn next(&mut self) -> Option<StagedBlock> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthConfig};
    use crate::tensor::SparseTensor;

    fn tensor() -> SparseTensor {
        generate(&SynthConfig::order_sweep(3, 32, 1500, 11))
    }

    #[test]
    fn staged_slabs_are_full_size() {
        let t = tensor();
        let mut it = BlockIter::uniform(&t, 256, 1, 0);
        while let Some(b) = it.next_block() {
            let staged = stage(&t, &b);
            assert_eq!(staged.coords.len(), 256 * t.order());
            assert_eq!(staged.lanes.len(), 256 * t.order());
            assert_eq!(staged.values.len(), 256);
            assert_eq!(staged.s, 256);
            // padded slots carry defined (zero) coordinates
            for e in staged.valid..staged.s {
                assert!(staged.coords[e * t.order()..(e + 1) * t.order()]
                    .iter()
                    .all(|&c| c == 0));
                assert_eq!(staged.values[e], 0.0);
            }
            // lanes are the exact transpose of the entry-major slab
            for m in 0..t.order() {
                for e in 0..staged.s {
                    assert_eq!(
                        staged.lanes[m * staged.s + e],
                        staged.coords[e * t.order() + m],
                        "lane transpose mismatch at e={e} m={m}"
                    );
                }
            }
        }
    }

    #[test]
    fn stream_delivers_every_sample_once() {
        let t = tensor();
        let mut total_valid = 0usize;
        std::thread::scope(|scope| {
            let iter = BlockIter::uniform(&t, 128, 3, 0);
            let mut stream = StagedStream::spawn(scope, &t, iter);
            while let Some(block) = stream.next() {
                total_valid += block.valid;
                for e in 0..block.valid {
                    let c = &block.coords[e * t.order()..(e + 1) * t.order()];
                    assert!(c.iter().zip(&t.dims).all(|(&i, &d)| i < d));
                }
            }
        });
        // uniform sampling is a partition of Ω, so the stream must deliver
        // exactly nnz valid slots (exact block equality is pinned by the
        // eager-vs-stream property test in tests/properties.rs)
        assert_eq!(total_valid, t.nnz());
    }

    #[test]
    fn stream_matches_eager_for_all_strategies() {
        let t = tensor();
        let eager = super::super::uniform_blocks(&t, 256, 9, 4);
        let lazy = BlockIter::uniform(&t, 256, 9, 4).collect_blocks();
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.valid, b.valid);
        }
    }
}
