//! Sampling strategies — one per row of the paper's Table 3.
//!
//! Every strategy yields fixed-size *blocks* of `S` sample slots (the HLO
//! batch shape), each slot either an entry id or `PAD`.  A block is the
//! analog of a grid launch: `S/16` "warps" of `M = 16` samples.
//!
//! * [`uniform_blocks`] — FastTuckerPlus: Ψ from the whole Ω.  An epoch is a
//!   shuffled pass over Ω, so blocks are always full: perfect load balance
//!   (the paper's "load-balanced sampling method").
//! * [`mode_slice_blocks`] — FastTucker: every 16-slot warp group holds
//!   samples sharing the mode-`n` index `i_n` (Ψ ⊂ Ω_{i_n}^(n)); short
//!   groups are padded, reproducing Alg. 1's warp-level imbalance.
//! * [`fiber_blocks`] — FasterTucker: warp groups are fibers
//!   (Ω^(n)_{i_1,..,i_{n-1},i_{n+1},..}); real fibers are mostly much
//!   shorter than 16, so padding waste is large — exactly the effect the
//!   paper describes ("most Ω contain fewer than M elements").
//!
//! The training hot path does not materialize eager `Vec<Block>` lists any
//! more: [`stream::BlockIter`] generates blocks lazily and
//! [`stream::StagedStream`] double-buffers their staging on a producer
//! thread (gather block *k+1* while block *k* executes).  The eager
//! functions below remain as thin `collect()`s for benches and tests.
//!
//! A property the distributed layer leans on: the uniform stream reads
//! nothing from the tensor except `nnz()` (its shuffle is a pure function
//! of `(seed, epoch, nnz)`), and gathers entries only through
//! [`TensorView::load_entry`].  That is why a [`crate::data::ShardView`]
//! covering the full id range replays the serial schedule bit-for-bit —
//! the `--workers 1` parity anchor in `tests/dist.rs`.

pub mod stream;

pub use stream::{stage, BlockIter, StagedBlock, StagedStream};

use crate::data::TensorView;
use crate::tensor::{FiberIndex, ModeSliceIndex};

/// Padding slot marker.
pub const PAD: u32 = u32::MAX;

/// The paper's warp sample count M.
pub const WARP_M: usize = 16;

/// One executable-shaped batch of sample slots.
#[derive(Clone, Debug)]
pub struct Block {
    /// Length S; `PAD` marks inert slots.
    pub ids: Vec<u32>,
    /// Number of non-PAD slots.
    pub valid: usize,
}

impl Block {
    fn new(s: usize) -> Self {
        Self {
            ids: Vec::with_capacity(s),
            valid: 0,
        }
    }

    fn seal(mut self, s: usize) -> Self {
        debug_assert!(self.ids.len() <= s);
        self.valid = self.ids.iter().filter(|&&i| i != PAD).count();
        self.ids.resize(s, PAD);
        self
    }
}

/// FastTuckerPlus sampling: shuffled full pass over Ω in blocks of `s`.
/// (Eager wrapper over [`BlockIter::uniform`] — benches and tests use it;
/// the trainer streams through [`StagedStream`] instead.)
pub fn uniform_blocks<T: TensorView + ?Sized>(
    t: &T,
    s: usize,
    seed: u64,
    epoch: u64,
) -> Vec<Block> {
    BlockIter::uniform(t, s, seed, epoch).collect_blocks()
}

/// FastTucker sampling for `mode`: warp groups share the mode index.
pub fn mode_slice_blocks(idx: &ModeSliceIndex, s: usize, seed: u64, epoch: u64) -> Vec<Block> {
    BlockIter::mode_slice(idx, s, seed, epoch).collect_blocks()
}

/// FasterTucker sampling for `mode`: warp groups are fibers.
pub fn fiber_blocks(idx: &FiberIndex, s: usize, seed: u64, epoch: u64) -> Vec<Block> {
    BlockIter::fiber(idx, s, seed, epoch).collect_blocks()
}

/// FasterTuckerCOO sampling: fibers in shuffled order but packed *densely*
/// (no warp alignment) — the paper's cuFasterTuckerCOO variant, which trades
/// the shared-intermediate reuse for full occupancy.  Blocks are always full
/// except the last.
pub fn fiber_blocks_coo(idx: &FiberIndex, s: usize, seed: u64, epoch: u64) -> Vec<Block> {
    BlockIter::fiber_coo(idx, s, seed, epoch).collect_blocks()
}

/// Padding overhead of a block list: padded slots / total slots.  This is
/// the measurable analog of the paper's load-imbalance column in Table 1.
pub fn padding_ratio(blocks: &[Block]) -> f64 {
    let total: usize = blocks.iter().map(|b| b.ids.len()).sum();
    let valid: usize = blocks.iter().map(|b| b.valid).sum();
    if total == 0 {
        0.0
    } else {
        1.0 - valid as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthConfig};
    use crate::tensor::SparseTensor;

    fn tensor() -> SparseTensor {
        generate(&SynthConfig::order_sweep(3, 32, 1500, 11))
    }

    #[test]
    fn uniform_covers_omega_exactly_once() {
        let t = tensor();
        let blocks = uniform_blocks(&t, 256, 1, 0);
        let mut seen = vec![0u32; t.nnz()];
        for b in &blocks {
            for &id in &b.ids {
                if id != PAD {
                    seen[id as usize] += 1;
                }
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        // only the last block may be padded
        for b in &blocks[..blocks.len() - 1] {
            assert_eq!(b.valid, 256);
        }
    }

    #[test]
    fn uniform_epochs_differ() {
        let t = tensor();
        let a = uniform_blocks(&t, 256, 1, 0);
        let b = uniform_blocks(&t, 256, 1, 1);
        assert_ne!(a[0].ids, b[0].ids);
    }

    #[test]
    fn mode_slice_warps_share_index() {
        let t = tensor();
        let idx = ModeSliceIndex::build(&t, 0);
        let blocks = mode_slice_blocks(&idx, 256, 2, 0);
        let mut covered = 0usize;
        for b in &blocks {
            for warp in b.ids.chunks(WARP_M) {
                let mut slice_ix = None;
                for &id in warp {
                    if id == PAD {
                        continue;
                    }
                    covered += 1;
                    let c = t.coords(id as usize)[0];
                    match slice_ix {
                        None => slice_ix = Some(c),
                        Some(s) => assert_eq!(s, c, "warp mixes slices"),
                    }
                }
            }
        }
        assert_eq!(covered, t.nnz());
    }

    #[test]
    fn fiber_warps_share_all_other_coords() {
        let t = tensor();
        let idx = FiberIndex::build(&t, 1);
        let blocks = fiber_blocks(&idx, 256, 3, 0);
        let mut covered = 0usize;
        for b in &blocks {
            for warp in b.ids.chunks(WARP_M) {
                let mut first: Option<Vec<u32>> = None;
                for &id in warp {
                    if id == PAD {
                        continue;
                    }
                    covered += 1;
                    let c = t.coords(id as usize);
                    let key: Vec<u32> = c
                        .iter()
                        .enumerate()
                        .filter(|(m, _)| *m != 1)
                        .map(|(_, &v)| v)
                        .collect();
                    match &first {
                        None => first = Some(key),
                        Some(f) => assert_eq!(f, &key, "warp mixes fibers"),
                    }
                }
            }
        }
        assert_eq!(covered, t.nnz());
    }

    #[test]
    fn fiber_padding_exceeds_uniform() {
        let t = tensor();
        let u = padding_ratio(&uniform_blocks(&t, 256, 1, 0));
        let f = padding_ratio(&fiber_blocks(&FiberIndex::build(&t, 0), 256, 1, 0));
        assert!(f > u, "fiber {f} <= uniform {u}");
    }

    #[test]
    fn blocks_are_exactly_s_long() {
        let t = tensor();
        for b in uniform_blocks(&t, 128, 5, 0) {
            assert_eq!(b.ids.len(), 128);
        }
        let idx = ModeSliceIndex::build(&t, 2);
        for b in mode_slice_blocks(&idx, 128, 5, 0) {
            assert_eq!(b.ids.len(), 128);
        }
    }
}
