//! Analytic cost models — a direct transcription of the paper's Table 4
//! (memory parameters read, multiply counts) plus bandwidth-scaled time
//! estimates used for the Table 7 / Fig. 3 memory-access experiments and
//! the DESIGN.md §Perf MXU/VMEM estimates.

/// Which algorithm a cost row describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Algorithm 1 (per-mode convex SGD).
    FastTucker,
    /// Algorithm 2 (storage scheme, fiber sampling).
    FasterTucker,
    /// Algorithm 3 (the paper's contribution).
    FastTuckerPlus,
}

impl Algo {
    /// Display name used in tables.
    pub fn name(self) -> &'static str {
        match self {
            Algo::FastTucker => "fasttucker",
            Algo::FasterTucker => "fastertucker",
            Algo::FastTuckerPlus => "fasttuckerplus",
        }
    }
}

/// Problem shape for one batch: N modes, uniform rank J per mode, Kruskal
/// rank R, batch M.
#[derive(Clone, Copy, Debug)]
pub struct Shape {
    /// Tensor order N.
    pub n: usize,
    /// Factor rank J per mode.
    pub j: usize,
    /// Kruskal rank R.
    pub r: usize,
    /// Batch size M (the paper's warp sample count).
    pub m: usize,
}

impl Shape {
    /// `Σ_n J_n` (uniform J, so `N * J`).
    pub fn sum_j(&self) -> usize {
        self.n * self.j
    }
}

/// Parameters read from memory per batch, totalled over all modes
/// (Table 4, "Total for all n" row of the read section).
pub fn params_read(algo: Algo, s: Shape) -> usize {
    let Shape { n, r, m, .. } = s;
    let sum_j = s.sum_j();
    match algo {
        // (MN - M + R + 1) * sum J_n
        Algo::FastTucker => (m * n - m + r + 1) * sum_j,
        // (M + R) * sum J_n + N(N-1)R
        Algo::FasterTucker => (m + r) * sum_j + n * (n - 1) * r,
        // (M + R) * sum J_n
        Algo::FastTuckerPlus => (m + r) * sum_j,
    }
}

/// Multiplications to form the D chains per batch, totalled over all modes
/// (Table 4, calculation of D / d rows).
pub fn d_chain_muls(algo: Algo, s: Shape) -> usize {
    let Shape { n, r, m, .. } = s;
    let sum_j = s.sum_j();
    match algo {
        // MR((N-1) sum J_n + N(N-2))
        Algo::FastTucker => m * r * ((n - 1) * sum_j + n * (n - 2)),
        // N(N-2)R   (C rows are read, only the Hadamard chain is computed)
        Algo::FasterTucker => n * (n - 2) * r,
        // MR(sum J_n + N(N-2))
        Algo::FastTuckerPlus => m * r * (sum_j + n * (n - 2)),
    }
}

/// Multiplications for the B D^T products per batch, totalled over modes
/// (Table 4, calculation of B D^T rows).
pub fn bd_muls(algo: Algo, s: Shape) -> usize {
    let Shape { n: _, r, m, .. } = s;
    let sum_j = s.sum_j();
    match algo {
        Algo::FastTucker => m * r * sum_j,
        Algo::FasterTucker => r * sum_j,
        Algo::FastTuckerPlus => m * r * sum_j,
    }
}

/// Parameters written back per batch (Table 4, update rows).
pub fn params_written(algo: Algo, s: Shape) -> usize {
    let Shape { n, j, m, .. } = s;
    match algo {
        Algo::FastTucker => n * j,      // one row per mode
        Algo::FasterTucker => m * n * j,
        Algo::FastTuckerPlus => m * n * j,
    }
}

/// Estimated memory-access seconds for a full pass over `nnz` samples, given
/// measured effective bandwidth (bytes/s).  This is the model behind our
/// Table 7 / Fig. 3 reproduction: the paper's numbers are CUDA-event
/// measurements of exactly this traffic.
pub fn memory_time_s(algo: Algo, s: Shape, nnz: usize, bandwidth: f64) -> f64 {
    let batches = nnz.div_ceil(s.m);
    let bytes = (params_read(algo, s) + params_written(algo, s)) as f64 * 4.0;
    batches as f64 * bytes / bandwidth
}

/// FLOPs (2*muls, counting the adds of each FMA) of a full pass.
pub fn flops_per_pass(algo: Algo, s: Shape, nnz: usize) -> f64 {
    let batches = nnz.div_ceil(s.m) as f64;
    batches * 2.0 * (d_chain_muls(algo, s) + bd_muls(algo, s)) as f64
}

/// L1 kernel VMEM footprint estimate in bytes for a grid step holding
/// `tile_s` samples (DESIGN.md §Perf): the a-block, core block, C/D/E tiles
/// and the value/err vectors, all f32.
pub fn vmem_bytes(s: Shape, tile_s: usize) -> usize {
    let Shape { n, j, r, .. } = s;
    4 * (n * tile_s * j            // a tile
        + n * j * r                // cores
        + 3 * tile_s * r           // C, D and one temp row block
        + 2 * tile_s               // x, err
        + tile_s * j)              // E / output tile
}

/// MXU-eligible fraction of the kernel's multiplies (dot-shaped work over
/// total work) — the utilization *estimate* recorded in EXPERIMENTS.md.
pub fn mxu_fraction(algo: Algo, s: Shape) -> f64 {
    // FasterTucker reads its C rows from memory and its remaining products
    // are matrix-vector shaped — no MXU-tileable work (the paper's Table 1
    // gives it the lowest Tensor-Core adaptability).
    let dot = match algo {
        Algo::FasterTucker => return 0.0,
        // C^(n) recompute + D B^T are dot-shaped in FastTucker(+Plus)
        Algo::FastTucker => (bd_muls(algo, s) + s.m * s.r * (s.n - 1) * s.sum_j()) as f64,
        Algo::FastTuckerPlus => (bd_muls(algo, s) + s.m * s.r * s.sum_j()) as f64,
    };
    let total = (d_chain_muls(algo, s) + bd_muls(algo, s)) as f64;
    (dot / total).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: Shape = Shape {
        n: 3,
        j: 16,
        r: 16,
        m: 16,
    };

    #[test]
    fn table4_ordering_reads() {
        // Plus reads strictly less than FastTucker and FasterTucker.
        let plus = params_read(Algo::FastTuckerPlus, S);
        let fast = params_read(Algo::FastTucker, S);
        let faster = params_read(Algo::FasterTucker, S);
        assert!(plus < faster && faster < fast, "{plus} {faster} {fast}");
        // exact formulas at the paper's M=16, N=3, J=R=16
        assert_eq!(plus, (16 + 16) * 48);
        assert_eq!(faster, (16 + 16) * 48 + 3 * 2 * 16);
        assert_eq!(fast, (16 * 3 - 16 + 16 + 1) * 48);
    }

    #[test]
    fn table4_dchain() {
        assert_eq!(
            d_chain_muls(Algo::FastTuckerPlus, S),
            16 * 16 * (48 + 3 * 1)
        );
        assert_eq!(d_chain_muls(Algo::FasterTucker, S), 3 * 1 * 16);
        assert_eq!(
            d_chain_muls(Algo::FastTucker, S),
            16 * 16 * (2 * 48 + 3 * 1)
        );
    }

    #[test]
    fn growth_with_order() {
        // Plus memory grows linearly in N; FastTucker superlinearly.
        let t = |n| Shape { n, ..S };
        let g_plus = params_read(Algo::FastTuckerPlus, t(8)) as f64
            / params_read(Algo::FastTuckerPlus, t(4)) as f64;
        let g_fast =
            params_read(Algo::FastTucker, t(8)) as f64 / params_read(Algo::FastTucker, t(4)) as f64;
        assert!(g_plus < g_fast);
    }

    #[test]
    fn vmem_within_budget() {
        // default artifact tile: 128 samples, N<=8, J=R<=32
        let s = Shape {
            n: 8,
            j: 32,
            r: 32,
            m: 16,
        };
        assert!(vmem_bytes(s, 128) < 16 * 1024 * 1024);
    }

    #[test]
    fn mxu_fraction_sane() {
        for algo in [Algo::FastTucker, Algo::FasterTucker, Algo::FastTuckerPlus] {
            let f = mxu_fraction(algo, S);
            assert!((0.0..=1.0).contains(&f), "{algo:?} {f}");
        }
        assert!(mxu_fraction(Algo::FastTuckerPlus, S) > 0.9);
        assert_eq!(mxu_fraction(Algo::FasterTucker, S), 0.0);
    }
}
