//! Epoch events, run reports, and the pluggable [`Observer`] trait the
//! [`super::Session`] emits to — a progress printer for the CLI, a JSON
//! line logger for tooling, and a recorder for benches and tests.

use std::io::Write;
use std::path::PathBuf;

use crate::coordinator::EpochStats;
use crate::data::CacheStats;
use crate::util::json::{self, Json};

/// One completed step of a session run.  The first event of a run (when
/// the schedule evaluates at all) is the pre-training evaluation — on a
/// fresh session that is the epoch-0 random init; every later event
/// follows one full training epoch.
#[derive(Clone, Debug)]
pub struct EpochEvent {
    /// The trainer's absolute epoch counter when the event fired (0 =
    /// random init; a continued `run()` keeps counting, matching the
    /// epoch tags on published snapshots and checkpoints).
    pub epoch: usize,
    /// Phase timings of the epoch just run (`None` for the init event).
    pub stats: Option<EpochStats>,
    /// Test RMSE, when this epoch was evaluated.
    pub rmse: Option<f64>,
    /// Test MAE, when this epoch was evaluated.
    pub mae: Option<f64>,
    /// Factor learning rate in effect during this epoch (visible decay).
    pub lr_a: f32,
    /// Checkpoint written after this epoch, if the schedule asked for one.
    pub checkpoint: Option<PathBuf>,
    /// Whether a snapshot was published to the attached serve server.
    pub published: bool,
    /// Paged-store cache traffic during this epoch (hits/loads/bytes as
    /// deltas, not cumulative) — `None` unless training from `--store`.
    pub cache: Option<CacheStats>,
}

impl EpochEvent {
    /// Invariant-cache hit rate of this epoch's storage-scheme kernels
    /// (`None` for the init event or when no storage-scheme kernel ran).
    pub fn invariant_hit_rate(&self) -> Option<f64> {
        self.stats.as_ref().and_then(|s| s.invariant_hit_rate())
    }

    /// Serialize for JSON-line logs (`EPOCH_JSON` scrape lines).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("epoch", json::num(self.epoch as f64))];
        if let Some(rmse) = self.rmse {
            fields.push(("rmse", json::num(rmse)));
        }
        if let Some(mae) = self.mae {
            fields.push(("mae", json::num(mae)));
        }
        fields.push(("lr_a", json::num(self.lr_a as f64)));
        if let Some(st) = &self.stats {
            fields.push(("stats", st.to_json()));
            fields.push(("pad_rate", json::num(st.padding_ratio())));
        }
        if let Some(rate) = self.invariant_hit_rate() {
            fields.push(("inv_hit_rate", json::num(rate)));
        }
        if let Some(c) = &self.cache {
            fields.push(("cache", c.to_json()));
        }
        if let Some(p) = &self.checkpoint {
            fields.push(("checkpoint", json::s(&p.to_string_lossy())));
        }
        if self.published {
            fields.push(("published", Json::Bool(true)));
        }
        json::obj(fields)
    }
}

/// Summary of a finished run, with the full event history.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Training epochs actually executed (≤ the schedule's maximum).
    pub epochs_run: usize,
    /// Whether the early-stopping policy cut the run short.
    pub stopped_early: bool,
    /// RMSE of the last evaluation, if the schedule evaluated at all.
    pub final_rmse: Option<f64>,
    /// MAE of the last evaluation.
    pub final_mae: Option<f64>,
    /// Best (lowest) RMSE seen across all evaluations.
    pub best_rmse: Option<f64>,
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Every emitted [`EpochEvent`], in order (init eval first, when any).
    pub history: Vec<EpochEvent>,
}

impl RunReport {
    /// Serialize the summary (without the per-epoch history).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("epochs_run", json::num(self.epochs_run as f64)),
            ("stopped_early", Json::Bool(self.stopped_early)),
            ("wall_s", json::num(self.wall_s)),
        ];
        if let Some(v) = self.final_rmse {
            fields.push(("final_rmse", json::num(v)));
        }
        if let Some(v) = self.final_mae {
            fields.push(("final_mae", json::num(v)));
        }
        if let Some(v) = self.best_rmse {
            fields.push(("best_rmse", json::num(v)));
        }
        json::obj(fields)
    }
}

/// Receives the session's progress as it runs.  All methods have empty
/// defaults, so implementors override only what they need.
pub trait Observer {
    /// Called after every emitted event (init eval and each epoch).
    fn on_epoch(&mut self, _event: &EpochEvent) {}

    /// Called once when the run finishes (normally or by early stop).
    fn on_finish(&mut self, _report: &RunReport) {}

    /// Called by the distributed driver ([`crate::dist::local`]) whenever
    /// the coordinator's observable state changes — phase transitions,
    /// round starts, evictions.  Serial sessions never call this.
    fn on_round(&mut self, _state: &crate::dist::CoordinatorState) {}
}

/// Ignores everything — for callers that only want the [`RunReport`].
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}

/// Prints the CLI's classic per-epoch progress lines to stdout:
///
/// ```text
/// epoch  0: rmse 1.2345  mae 0.9876  (init)
/// epoch  3: rmse 0.9123  mae 0.7012  factor 0.412s core 0.198s (mem 0.051s, pad 2.1%)
/// ```
///
/// When the storage-scheme kernels report invariant-cache traffic the
/// line also carries the epoch's hit rate (`inv 83.2%`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProgressPrinter;

impl Observer for ProgressPrinter {
    fn on_epoch(&mut self, ev: &EpochEvent) {
        let mut line = format!("epoch {:>2}:", ev.epoch);
        if let (Some(rmse), Some(mae)) = (ev.rmse, ev.mae) {
            line.push_str(&format!(" rmse {rmse:.4}  mae {mae:.4} "));
        }
        match &ev.stats {
            None => line.push_str(" (init)"),
            Some(st) => {
                line.push_str(&format!(
                    " factor {:.3}s core {:.3}s (mem {:.3}s, pad {:.1}%)",
                    st.factor.total().as_secs_f64(),
                    st.core.total().as_secs_f64(),
                    (st.factor.memory() + st.core.memory()).as_secs_f64(),
                    100.0 * st.padding_ratio(),
                ));
                if let Some(rate) = st.invariant_hit_rate() {
                    line.push_str(&format!(" inv {:.1}%", 100.0 * rate));
                }
            }
        }
        if let Some(rate) = ev.cache.as_ref().and_then(|c| c.hit_rate()) {
            line.push_str(&format!(" cache {:.1}%", 100.0 * rate));
        }
        if let Some(p) = &ev.checkpoint {
            line.push_str(&format!("  [checkpoint {}]", p.display()));
        }
        if ev.published {
            line.push_str("  [published]");
        }
        println!("{line}");
    }

    fn on_round(&mut self, state: &crate::dist::CoordinatorState) {
        // one dist-prefixed line per coordinator transition, next to the
        // epoch lines (CoordinatorState's Display is the compact summary)
        println!("dist: {state}");
    }
}

/// Writes one `EPOCH_JSON {...}` line per event and a final
/// `RUN_JSON {...}` summary to any [`Write`] sink — the machine-readable
/// twin of [`ProgressPrinter`], in the same scrape-line style as the
/// bench suite's `BENCH_JSON`.
///
/// The sink is flushed after every event and again on drop, so a run
/// that aborts mid-way (panic, watchdog) still leaves every completed
/// epoch's line on disk.
#[derive(Debug)]
pub struct JsonLogger<W: Write> {
    // Option so `into_inner` can move the sink out from under the Drop
    // impl; always `Some` while the logger is alive.
    sink: Option<W>,
}

impl<W: Write> JsonLogger<W> {
    /// Log to `sink` (e.g. `std::io::stdout()` or a `Vec<u8>`).
    pub fn new(sink: W) -> Self {
        Self { sink: Some(sink) }
    }

    /// Recover the sink (e.g. to inspect a `Vec<u8>` in tests).
    pub fn into_inner(mut self) -> W {
        let mut sink = self.sink.take().expect("sink present until into_inner");
        let _ = sink.flush();
        sink
    }
}

impl<W: Write> Observer for JsonLogger<W> {
    fn on_epoch(&mut self, ev: &EpochEvent) {
        // logging must never abort a run; drop the line on sink errors
        if let Some(sink) = self.sink.as_mut() {
            let _ = writeln!(sink, "EPOCH_JSON {}", ev.to_json().dump());
            let _ = sink.flush();
        }
    }

    fn on_finish(&mut self, report: &RunReport) {
        if let Some(sink) = self.sink.as_mut() {
            let _ = writeln!(sink, "RUN_JSON {}", report.to_json().dump());
            let _ = sink.flush();
        }
    }
}

impl<W: Write> Drop for JsonLogger<W> {
    fn drop(&mut self) {
        if let Some(sink) = self.sink.as_mut() {
            let _ = sink.flush();
        }
    }
}

/// Collects every event (and the final report) in memory — what benches
/// and tests use to assert on trajectories without printing.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    /// Every event seen so far, in emission order.
    pub events: Vec<EpochEvent>,
    /// The final report, once the run finished.
    pub report: Option<RunReport>,
}

impl Observer for Recorder {
    fn on_epoch(&mut self, ev: &EpochEvent) {
        self.events.push(ev.clone());
    }

    fn on_finish(&mut self, report: &RunReport) {
        self.report = Some(report.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(epoch: usize, rmse: Option<f64>) -> EpochEvent {
        EpochEvent {
            epoch,
            stats: None,
            rmse,
            mae: rmse,
            lr_a: 0.01,
            checkpoint: None,
            published: false,
            cache: None,
        }
    }

    #[test]
    fn recorder_collects() {
        let mut r = Recorder::default();
        r.on_epoch(&ev(0, Some(1.0)));
        r.on_epoch(&ev(1, None));
        assert_eq!(r.events.len(), 2);
        assert_eq!(r.events[1].epoch, 1);
        assert!(r.report.is_none());
    }

    #[test]
    fn epoch_json_carries_hit_rate() {
        use crate::coordinator::{EpochStats, PhaseStats};
        let mut e = ev(2, Some(0.8));
        assert!(e.invariant_hit_rate().is_none());
        assert!(e.to_json().get("inv_hit_rate").is_none());
        e.stats = Some(EpochStats {
            factor: PhaseStats {
                inv_hits: 3,
                inv_misses: 1,
                ..Default::default()
            },
            core: PhaseStats::default(),
        });
        assert!((e.invariant_hit_rate().unwrap() - 0.75).abs() < 1e-12);
        let j = e.to_json();
        assert!(j.get("inv_hit_rate").is_some());
    }

    #[test]
    fn epoch_json_carries_pad_rate_and_cache() {
        use crate::coordinator::{EpochStats, PhaseStats};
        let mut e = ev(1, Some(0.9));
        assert!(e.to_json().get("pad_rate").is_none(), "no stats, no pad");
        e.stats = Some(EpochStats {
            factor: PhaseStats {
                samples: 75,
                padded_slots: 25,
                ..Default::default()
            },
            core: PhaseStats {
                samples: 100,
                padded_slots: 0,
                ..Default::default()
            },
        });
        let j = e.to_json();
        // combined over both phases: 25 / 200
        assert!((j.get("pad_rate").unwrap().as_f64().unwrap() - 0.125).abs() < 1e-12);
        assert!(j.get("cache").is_none());

        e.cache = Some(CacheStats {
            hits: 7,
            loads: 1,
            bytes_read: 4096,
        });
        let j = e.to_json();
        let c = j.get("cache").unwrap();
        assert_eq!(c.get("hits").unwrap().as_usize(), Some(7));
        assert_eq!(c.get("loads").unwrap().as_usize(), Some(1));
        assert_eq!(c.get("bytes_read").unwrap().as_usize(), Some(4096));
        assert!((c.get("hit_rate").unwrap().as_f64().unwrap() - 0.875).abs() < 1e-12);
    }

    /// A sink that only exposes bytes once `flush` is called — models a
    /// buffered file so the test can see exactly when flushes happen.
    struct FlushGate {
        pending: Vec<u8>,
        flushed: std::sync::Arc<std::sync::Mutex<Vec<u8>>>,
    }

    impl Write for FlushGate {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.pending.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            self.flushed.lock().unwrap().append(&mut self.pending);
            Ok(())
        }
    }

    #[test]
    fn json_logger_flushes_every_event_and_on_drop() {
        let flushed = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut log = JsonLogger::new(FlushGate {
            pending: Vec::new(),
            flushed: flushed.clone(),
        });
        log.on_epoch(&ev(0, Some(1.0)));
        // visible immediately — before on_finish / into_inner / drop —
        // so an abort mid-run cannot lose completed epochs
        {
            let seen = String::from_utf8(flushed.lock().unwrap().clone()).unwrap();
            assert!(
                seen.starts_with("EPOCH_JSON {"),
                "epoch line not flushed eagerly: {seen:?}"
            );
        }
        log.on_epoch(&ev(1, Some(0.9)));
        drop(log); // no on_finish: drop alone must leave nothing buffered
        let seen = String::from_utf8(flushed.lock().unwrap().clone()).unwrap();
        assert_eq!(seen.lines().count(), 2);
    }

    #[test]
    fn json_logger_emits_lines() {
        let mut log = JsonLogger::new(Vec::new());
        log.on_epoch(&ev(1, Some(0.5)));
        log.on_finish(&RunReport {
            epochs_run: 1,
            stopped_early: false,
            final_rmse: Some(0.5),
            final_mae: Some(0.4),
            best_rmse: Some(0.5),
            wall_s: 0.1,
            history: vec![],
        });
        let text = String::from_utf8(log.into_inner()).unwrap();
        assert!(text.starts_with("EPOCH_JSON {"));
        assert!(text.contains("\nRUN_JSON {"));
        let line = text.lines().next().unwrap().strip_prefix("EPOCH_JSON ").unwrap();
        let parsed = Json::parse(line).unwrap();
        assert_eq!(parsed.get("epoch").unwrap().as_usize(), Some(1));
    }
}
