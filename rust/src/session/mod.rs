//! The session layer: one validated, declarative entry point for
//! train → evaluate → checkpoint → serve.
//!
//! The paper's experiments are all *schedules* — run the Table-6
//! iteration for N epochs, evaluate RMSE/MAE periodically, stop at a
//! convergence cutoff (Fig. 1), sweep parameters (Table 10).  Before
//! this layer existed, every consumer (CLI subcommands, examples,
//! benches) hand-assembled a `TrainConfig` and wrote its own
//! `for epoch in 0..` loop, duplicating split / eval / checkpoint logic
//! and validating nothing.  The session layer replaces all of that:
//!
//! * [`RunSpec`] ([`spec`]) — data source + trainer config +
//!   [`Schedule`], with [`RunSpec::validate`] returning a typed
//!   [`SpecError`] taxonomy and a lossless JSON round-trip
//!   ([`RunSpec::dump`] / [`RunSpec::parse_str`]) so every run is a
//!   reproducible file (`fasttucker train --dump-spec` / `--spec FILE`).
//! * [`Session`] ([`run`]) — the builder-constructed driver that owns
//!   the train/test split and the [`crate::coordinator::Trainer`] and
//!   executes the schedule: evaluation cadence, RMSE-plateau early
//!   stopping, learning-rate decay, periodic FTCK checkpoints, and
//!   mid-run [`crate::serve::Server`] publishes
//!   ([`Session::run_with_server`]).
//! * [`Observer`] ([`observer`]) — pluggable progress sinks fed one
//!   [`EpochEvent`] per epoch: [`ProgressPrinter`] (the CLI's classic
//!   lines), [`JsonLogger`] (scrape-friendly JSON lines), [`Recorder`]
//!   (in-memory, for benches and tests), or anything user-defined.
//!
//! The session sits between the CLI and the trainer (see
//! ARCHITECTURE.md §Session layer); sharding, sweep runners and
//! multi-tenant serving build on this surface.

pub mod observer;
pub mod run;
pub mod spec;

pub use observer::{
    EpochEvent, JsonLogger, NullObserver, Observer, ProgressPrinter, Recorder, RunReport,
};
pub use run::Session;
pub use spec::{DataSource, EarlyStop, RunSpec, Schedule, SpecError, SynthPreset, SynthSpec};
