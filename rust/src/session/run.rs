//! The [`Session`] facade: owns the train/test split and the [`Trainer`],
//! and drives the epoch loop a [`RunSpec`]'s schedule describes —
//! evaluation cadence, early stopping, learning-rate decay, periodic
//! checkpoints and mid-run publishes to a serve [`Server`].

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::{EpochStats, TrainConfig, Trainer};
use crate::data::{CacheStats, PagedTensor, TensorView};
use crate::obs::{Metrics, MetricsFile};
use crate::serve::{ModelSnapshot, Registry, Server};
use crate::session::observer::{EpochEvent, Observer, RunReport};
use crate::session::spec::{DataSource, RunSpec, Schedule};
use crate::tensor::{split::train_test_split, SparseTensor};

/// The training data a session drives epochs over: fully in RAM, or an
/// out-of-core paged store (both feed the trainer through [`TensorView`]).
enum TrainData {
    Ram(SparseTensor),
    Paged(PagedTensor),
}

impl TrainData {
    fn view(&self) -> &dyn TensorView {
        match self {
            TrainData::Ram(t) => t,
            TrainData::Paged(p) => p,
        }
    }
}

/// Where mid-run snapshot publishes go: the in-process [`Server`]
/// (hot-swap) or a named model in a [`Registry`] (the network tier).
enum PublishSink<'a> {
    None,
    Server(&'a Server),
    Registry {
        registry: &'a Registry,
        model: &'a str,
    },
}

/// The builder-constructed run driver — one validated spec, executed.
///
/// A session owns its train/test split and trainer, so the epoch loop,
/// evaluation, early stopping, learning-rate decay, checkpointing and
/// serving publishes live in exactly one place instead of being re-rolled
/// by every CLI subcommand, example and bench:
///
/// ```no_run
/// use fasttucker::session::{ProgressPrinter, RunSpec, Session};
///
/// let spec = RunSpec::default(); // toy data, auto backend, 10 epochs
/// let mut session = Session::from_spec(&spec).unwrap();
/// let report = session.run(&mut ProgressPrinter).unwrap();
/// println!("best RMSE {:?} after {} epochs", report.best_rmse, report.epochs_run);
/// ```
pub struct Session {
    schedule: Schedule,
    trainer: Trainer,
    train: TrainData,
    test: SparseTensor,
    metrics: Option<SessionMetrics>,
}

/// The telemetry half of a session: a registry the epoch loop feeds and
/// the `metrics.jsonl` sink it snapshots into.  Only exists when
/// `--metrics` / [`RunSpec::metrics`] switched it on; export errors are
/// swallowed (observation must never abort a run).
struct SessionMetrics {
    registry: Metrics,
    file: MetricsFile,
}

impl SessionMetrics {
    /// Fold one epoch's trainer stats (and paged-cache traffic, when
    /// training from a store) into the registry, then append a
    /// `"scope":"epoch"` snapshot line.
    fn observe_epoch(&mut self, stats: &EpochStats, cache: Option<&CacheStats>) {
        let r = &self.registry;
        r.counter("train.epochs").inc();
        r.counter("train.blocks")
            .add((stats.factor.blocks + stats.core.blocks) as u64);
        r.counter("train.samples")
            .add((stats.factor.samples + stats.core.samples) as u64);
        r.counter("train.padded_slots")
            .add((stats.factor.padded_slots + stats.core.padded_slots) as u64);
        r.counter("train.inv_hits")
            .add(stats.factor.inv_hits + stats.core.inv_hits);
        r.counter("train.inv_misses")
            .add(stats.factor.inv_misses + stats.core.inv_misses);
        r.hist("train.epoch_ns")
            .record_duration(stats.factor.total() + stats.core.total());
        r.hist("train.factor_ns").record_duration(stats.factor.total());
        r.hist("train.core_ns").record_duration(stats.core.total());
        r.hist("train.stage_wait_ns")
            .record_duration(stats.factor.sample + stats.core.sample);
        if let Some(c) = cache {
            r.counter("data.page_hits").add(c.hits);
            r.counter("data.page_loads").add(c.loads);
            r.counter("data.bytes_read").add(c.bytes_read);
        }
        let snap = self.registry.snapshot();
        let _ = self.file.write_snapshot("epoch", &snap);
    }

    fn finish(&mut self) {
        let snap = self.registry.snapshot();
        let _ = self.file.write_snapshot("final", &snap);
    }
}

impl Session {
    /// Validate `spec`, resolve its data source, split, and build the
    /// trainer.  The one entry point the CLI's `--spec` path, the flag
    /// path, the examples and the benches all share.
    ///
    /// A [`DataSource::Store`] stays *out of core*: the session opens it
    /// as a [`PagedTensor`] (verifying every section checksum) and trains
    /// straight from disk; every other source materializes in RAM.
    pub fn from_spec(spec: &RunSpec) -> Result<Session> {
        spec.validate().context("invalid run spec")?;
        ensure!(
            spec.train.workers == 0,
            "spec requests {} sharded workers; drive it through \
             crate::dist::local::run_local (the CLI's `train --workers N` path) \
             instead of a serial Session",
            spec.train.workers
        );
        let mut session = if let DataSource::Store(path) = &spec.data {
            let paged = PagedTensor::open(path).with_context(|| format!("opening {path:?}"))?;
            Session::with_paged(paged, spec.train.clone(), spec.schedule.clone())?
        } else {
            let tensor = spec.data.resolve()?;
            Session::with_owned_tensor(tensor, spec.train.clone(), spec.schedule.clone())?
        };
        if let Some(path) = &spec.metrics {
            session.enable_metrics(path)?;
        }
        Ok(session)
    }

    /// Switch on telemetry export: the epoch loop feeds an [`crate::obs`]
    /// registry and appends one `metrics.jsonl` snapshot line per epoch
    /// (plus a final one) to `path`.  Strictly passive — the training
    /// trajectory is bit-identical with or without it (pinned by
    /// `tests/session.rs`).
    pub fn enable_metrics(&mut self, path: &Path) -> Result<()> {
        let file = MetricsFile::create(path)
            .with_context(|| format!("creating metrics file {path:?}"))?;
        self.metrics = Some(SessionMetrics {
            registry: Metrics::new(),
            file,
        });
        Ok(())
    }

    /// Build a session that trains out of core from an opened paged
    /// store.  Paged runs have no held-out split (`schedule.test_frac`
    /// must be 0) — evaluate against a separate in-RAM tensor through
    /// [`Session::trainer_mut`] if needed.
    pub fn with_paged(train: PagedTensor, cfg: TrainConfig, schedule: Schedule) -> Result<Session> {
        ensure!(
            schedule.test_frac == 0.0,
            "paged stores train without a held-out split (test_frac must be 0)"
        );
        let trainer = Trainer::new(&train, cfg)?;
        let test = SparseTensor::new(train.dims().to_vec());
        Ok(Session {
            schedule,
            trainer,
            train: TrainData::Paged(train),
            test,
            metrics: None,
        })
    }

    /// Build a session over an already-loaded tensor (what benches and
    /// examples with bespoke tensors use).  Splits per
    /// `schedule.test_frac` with the config seed; `test_frac == 0` trains
    /// on everything (the caller's tensor is copied — prefer
    /// [`Session::with_owned_tensor`] when the tensor can be moved) and
    /// disables evaluation.
    pub fn with_tensor(
        tensor: &SparseTensor,
        cfg: TrainConfig,
        schedule: Schedule,
    ) -> Result<Session> {
        if schedule.test_frac > 0.0 {
            let (train, test) = train_test_split(tensor, schedule.test_frac, cfg.seed);
            Session::parts(train, test, cfg, schedule)
        } else {
            Session::with_owned_tensor(tensor.clone(), cfg, schedule)
        }
    }

    /// Like [`Session::with_tensor`], taking ownership: the no-split
    /// path keeps the tensor instead of copying it (`from_spec` resolves
    /// an owned tensor, so serve-style runs never hold two copies).
    pub fn with_owned_tensor(
        tensor: SparseTensor,
        cfg: TrainConfig,
        schedule: Schedule,
    ) -> Result<Session> {
        if schedule.test_frac > 0.0 {
            let (train, test) = train_test_split(&tensor, schedule.test_frac, cfg.seed);
            Session::parts(train, test, cfg, schedule)
        } else {
            let empty = SparseTensor::new(tensor.dims.clone());
            Session::parts(tensor, empty, cfg, schedule)
        }
    }

    fn parts(
        train: SparseTensor,
        test: SparseTensor,
        cfg: TrainConfig,
        schedule: Schedule,
    ) -> Result<Session> {
        let trainer = Trainer::new(&train, cfg)?;
        Ok(Session {
            schedule,
            trainer,
            train: TrainData::Ram(train),
            test,
            metrics: None,
        })
    }

    /// The schedule this session executes.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// The underlying trainer (model, config, epoch counter).
    pub fn trainer(&self) -> &Trainer {
        &self.trainer
    }

    /// Mutable access to the trainer (e.g. saving the FTM1 model after a
    /// run, or adjusting hypers between [`Session::run`] calls).
    pub fn trainer_mut(&mut self) -> &mut Trainer {
        &mut self.trainer
    }

    /// The in-RAM training split (`None` when this session trains out of
    /// core from a paged store — use [`Session::train_nnz`] /
    /// [`Session::train_dims`] for the shape either way).
    pub fn train_tensor(&self) -> Option<&SparseTensor> {
        match &self.train {
            TrainData::Ram(t) => Some(t),
            TrainData::Paged(_) => None,
        }
    }

    /// The training data as a [`TensorView`] (RAM or paged).
    pub fn train_view(&self) -> &dyn TensorView {
        self.train.view()
    }

    /// Entries in the training data.
    pub fn train_nnz(&self) -> usize {
        self.train.view().nnz()
    }

    /// Dimension sizes of the training data.
    pub fn train_dims(&self) -> &[u32] {
        self.train.view().dims()
    }

    /// The held-out split (empty when `test_frac == 0`).
    pub fn test_tensor(&self) -> &SparseTensor {
        &self.test
    }

    /// Platform string of the trainer's execution backend (for banners).
    pub fn platform(&self) -> String {
        self.trainer.platform()
    }

    /// Freeze the current model into a serving snapshot.
    pub fn snapshot(&self) -> ModelSnapshot {
        self.trainer.snapshot()
    }

    /// Evaluate test RMSE/MAE now (`None` without a held-out split).
    pub fn evaluate(&mut self) -> Result<Option<(f64, f64)>> {
        if self.test.nnz() == 0 {
            return Ok(None);
        }
        self.trainer.evaluate(&self.test).map(Some)
    }

    /// Execute the schedule, emitting events to `observer`.
    ///
    /// Runs up to `schedule.epochs` training epochs (fewer if early
    /// stopping triggers), evaluating every `eval_every` epochs, decaying
    /// learning rates, and writing checkpoints per the schedule — a final
    /// checkpoint is always written when a checkpoint path is set.
    /// Calling `run` again continues training for another round of the
    /// schedule.
    pub fn run(&mut self, observer: &mut dyn Observer) -> Result<RunReport> {
        self.drive(PublishSink::None, observer)
    }

    /// Like [`Session::run`], but publishes a model snapshot to `server`
    /// every `schedule.publish_every` epochs (hot-swap under live
    /// traffic) — the train-and-serve-concurrently loop.
    pub fn run_with_server(
        &mut self,
        server: &Server,
        observer: &mut dyn Observer,
    ) -> Result<RunReport> {
        self.drive(PublishSink::Server(server), observer)
    }

    /// Like [`Session::run`], but publishes a model snapshot into
    /// `registry` as a new **active** version of `model` every
    /// `schedule.publish_every` epochs — the network serving tier's
    /// train-and-serve loop: [`crate::serve::NetServer`] workers resolve
    /// the fresh generation on their next request.
    pub fn run_with_registry(
        &mut self,
        registry: &Registry,
        model: &str,
        observer: &mut dyn Observer,
    ) -> Result<RunReport> {
        self.drive(PublishSink::Registry { registry, model }, observer)
    }

    fn drive(
        &mut self,
        sink: PublishSink<'_>,
        observer: &mut dyn Observer,
    ) -> Result<RunReport> {
        let t0 = Instant::now();
        let sched = self.schedule.clone();
        let can_eval = sched.eval_every > 0 && self.test.nnz() > 0;
        // a second run() continues training, so event numbering follows
        // the trainer's absolute epoch counter (matching checkpoint tags)
        let base_epoch = self.trainer.epoch_no as usize;

        let mut history: Vec<EpochEvent> = Vec::new();
        let mut best_rmse: Option<f64> = None;
        let mut final_eval: Option<(f64, f64)> = None;
        let mut strikes = 0usize;
        let mut stopped_early = false;
        let mut last_epoch_checkpointed = false;

        // before any training this round: evaluate the current model so
        // convergence curves start from the same origin the paper's
        // Fig. 1 plots do (the random init on a fresh session)
        if can_eval {
            let (rmse, mae) = self.trainer.evaluate(&self.test)?;
            best_rmse = Some(rmse);
            final_eval = Some((rmse, mae));
            let ev = EpochEvent {
                epoch: base_epoch,
                stats: None,
                rmse: Some(rmse),
                mae: Some(mae),
                lr_a: self.trainer.cfg.hyper.lr_a,
                checkpoint: None,
                published: false,
                cache: None,
            };
            observer.on_epoch(&ev);
            history.push(ev);
        }

        let mut epochs_run = 0usize;
        let mut last_cache = match &self.train {
            TrainData::Paged(p) => p.cache_stats_full(),
            TrainData::Ram(_) => CacheStats::default(),
        };
        for epoch in 1..=sched.epochs {
            let lr_a = self.trainer.cfg.hyper.lr_a;
            let stats = self.trainer.epoch(self.train.view())?;
            epochs_run = epoch;

            // paged-cache traffic attributable to this epoch (reported in
            // the event / stats JSON whether or not --metrics is set)
            let cache = match &self.train {
                TrainData::Paged(p) => {
                    let now = p.cache_stats_full();
                    let delta = now.delta_since(&last_cache);
                    last_cache = now;
                    Some(delta)
                }
                TrainData::Ram(_) => None,
            };

            if let Some(m) = &mut self.metrics {
                m.observe_epoch(&stats, cache.as_ref());
            }

            let eval = if can_eval && epoch % sched.eval_every == 0 {
                let (rmse, mae) = self.trainer.evaluate(&self.test)?;
                final_eval = Some((rmse, mae));
                Some((rmse, mae))
            } else {
                None
            };

            let due = sched.publish_every > 0 && epoch % sched.publish_every == 0;
            let published = match &sink {
                PublishSink::Server(srv) if due => {
                    srv.publish(self.trainer.snapshot());
                    true
                }
                PublishSink::Registry { registry, model } if due => {
                    registry.publish(model, self.trainer.snapshot());
                    true
                }
                _ => false,
            };

            let checkpoint = match &sched.checkpoint {
                Some(path)
                    if sched.checkpoint_every > 0 && epoch % sched.checkpoint_every == 0 =>
                {
                    self.trainer.snapshot().save(path)?;
                    Some(path.clone())
                }
                _ => None,
            };
            last_epoch_checkpointed = checkpoint.is_some();

            // early stopping: a strike per evaluation that fails to beat
            // the best RMSE by min_delta; stop after `patience` strikes
            if let (Some(es), Some((rmse, _))) = (&sched.early_stop, eval) {
                let improved = match best_rmse {
                    Some(best) => rmse < best - es.min_delta,
                    None => true,
                };
                if improved {
                    strikes = 0;
                } else {
                    strikes += 1;
                    if strikes >= es.patience {
                        stopped_early = true;
                    }
                }
            }
            if let Some((rmse, _)) = eval {
                best_rmse = Some(best_rmse.map_or(rmse, |b| b.min(rmse)));
            }

            let ev = EpochEvent {
                epoch: base_epoch + epoch,
                stats: Some(stats),
                rmse: eval.map(|e| e.0),
                mae: eval.map(|e| e.1),
                lr_a,
                checkpoint,
                published,
                cache,
            };
            observer.on_epoch(&ev);
            history.push(ev);

            if stopped_early {
                break;
            }

            if let Some(decay) = sched.lr_decay {
                let mut hyper = self.trainer.cfg.hyper;
                hyper.lr_a *= decay;
                hyper.lr_b *= decay;
                self.trainer.set_hyper(hyper);
            }
        }

        // a set checkpoint path always gets the final model, unless the
        // cadence already wrote it after the very last epoch
        if let Some(path) = &sched.checkpoint {
            if !last_epoch_checkpointed {
                self.trainer.snapshot().save(path)?;
            }
        }

        if let Some(m) = &mut self.metrics {
            m.finish();
        }

        let report = RunReport {
            epochs_run,
            stopped_early,
            final_rmse: final_eval.map(|e| e.0),
            final_mae: final_eval.map(|e| e.1),
            best_rmse,
            wall_s: t0.elapsed().as_secs_f64(),
            history,
        };
        observer.on_finish(&report);
        Ok(report)
    }
}
