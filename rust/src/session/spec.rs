//! The declarative run description: [`RunSpec`] = data source + trainer
//! configuration + [`Schedule`], with typed validation ([`SpecError`]) and
//! a lossless JSON round-trip so every run is a reproducible file.
//!
//! The paper's experiments are all *schedules* — N epochs of the Table-6
//! iteration with periodic RMSE/MAE evaluation, convergence cutoffs
//! (Fig. 1) and parameter sweeps (Table 10).  A `RunSpec` captures one
//! such schedule declaratively; [`super::Session`] executes it.  The CLI's
//! `--dump-spec` / `--spec FILE` flags serialize and replay specs through
//! exactly this representation, so a flag-driven run and its dumped spec
//! produce bit-identical trajectories.

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{Algo, Backend, Strategy, TrainConfig, Variant};
use crate::cpu_ref::Hyper;
use crate::kernel::KernelPolicy;
use crate::synth::{self, SynthConfig};
use crate::tensor::{io, SparseTensor};
use crate::util::json::{self, Json};

/// Current spec-file format version (the `"version"` field).
pub const SPEC_VERSION: u64 = 1;

// ======================================================================
// Data source
// ======================================================================

/// Synthetic-dataset preset family (mirrors `fasttucker synth --preset`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthPreset {
    /// Netflix-like 3-order surrogate (Zipf-skewed rating tensor).
    Netflix,
    /// Yahoo!Music-like 3-order surrogate.
    Yahoo,
    /// Paper §5.1 order-sweep family: order-N cubic tensor.
    Order,
}

impl SynthPreset {
    /// Parse a CLI / spec-file value (`netflix`, `yahoo`, `order`).
    pub fn parse(s: &str) -> Option<SynthPreset> {
        match s {
            "netflix" => Some(SynthPreset::Netflix),
            "yahoo" => Some(SynthPreset::Yahoo),
            "order" => Some(SynthPreset::Order),
            _ => None,
        }
    }

    /// Canonical name (`parse(name()) == Some(self)`).
    pub fn name(self) -> &'static str {
        match self {
            SynthPreset::Netflix => "netflix",
            SynthPreset::Yahoo => "yahoo",
            SynthPreset::Order => "order",
        }
    }
}

/// A serializable synthetic-tensor recipe (preset + its parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct SynthSpec {
    /// Which generator family.
    pub preset: SynthPreset,
    /// Tensor order (used by the `order` preset only).
    pub order: usize,
    /// Per-mode dimension (used by the `order` preset only).
    pub dim: u32,
    /// Entries to draw.
    pub nnz: usize,
    /// Generator seed.
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        Self {
            preset: SynthPreset::Order,
            order: 3,
            dim: 1000,
            nnz: 200_000,
            seed: 1,
        }
    }
}

impl SynthSpec {
    /// Expand into the generator configuration.
    pub fn config(&self) -> SynthConfig {
        match self.preset {
            SynthPreset::Netflix => SynthConfig::netflix_like(self.nnz, self.seed),
            SynthPreset::Yahoo => SynthConfig::yahoo_like(self.nnz, self.seed),
            SynthPreset::Order => {
                SynthConfig::order_sweep(self.order, self.dim, self.nnz, self.seed)
            }
        }
    }
}

/// Where the run's tensor comes from.  Everything here is serializable, so
/// a spec file fully determines its input data.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSource {
    /// The deterministic 8x8x8 toy dataset shipped with the repo.
    Toy,
    /// A tensor file on disk (text, `.ftb` binary or `.ftb2` store,
    /// auto-detected), materialized in RAM.
    File(PathBuf),
    /// A synthetic tensor generated in-process from a preset recipe.
    Synth(SynthSpec),
    /// An `FTB2` paged store trained *out of core*: entries stay on disk
    /// and page in on demand (`fasttucker ingest` produces these).
    /// Requires the `plus` algorithm and `test_frac == 0` — see
    /// [`SpecError::StoreNeedsPlus`] / [`SpecError::StoreWithSplit`].
    Store(PathBuf),
}

impl DataSource {
    /// Load or generate the tensor this source describes, in RAM.  For
    /// [`DataSource::Store`] this *materializes* the store —
    /// [`super::Session::from_spec`] instead keeps store sources paged
    /// through [`crate::data::PagedTensor`]; this path serves tools that
    /// genuinely need the whole tensor.
    pub fn resolve(&self) -> Result<SparseTensor> {
        match self {
            DataSource::Toy => Ok(io::toy_dataset()),
            DataSource::File(path) => {
                io::read_auto(path).with_context(|| format!("reading {path:?}"))
            }
            DataSource::Synth(s) => Ok(synth::generate(&s.config())),
            DataSource::Store(path) => crate::data::store::read_store(path)
                .with_context(|| format!("materializing store {path:?}")),
        }
    }

    /// Short human-readable description (for banners and logs).
    pub fn describe(&self) -> String {
        match self {
            DataSource::Toy => "toy dataset".to_string(),
            DataSource::File(p) => p.display().to_string(),
            DataSource::Synth(s) => format!("synth preset {} ({} nnz)", s.preset.name(), s.nnz),
            DataSource::Store(p) => format!("paged store {}", p.display()),
        }
    }
}

// ======================================================================
// Schedule
// ======================================================================

/// RMSE-plateau early-stopping policy: stop after `patience` consecutive
/// evaluations that fail to improve the best test RMSE by `min_delta`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EarlyStop {
    /// Non-improving evaluations tolerated before stopping (≥ 1).
    pub patience: usize,
    /// Minimum RMSE improvement that counts as progress.
    pub min_delta: f64,
}

impl Default for EarlyStop {
    fn default() -> Self {
        Self {
            patience: 3,
            min_delta: 1e-4,
        }
    }
}

/// What the epoch loop does and for how long: epochs, evaluation cadence,
/// early stopping, learning-rate decay, checkpointing and mid-run serving
/// publishes.  The [`super::Session`] honors every field.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// Maximum epochs to run (≥ 1).
    pub epochs: usize,
    /// Evaluate test RMSE/MAE every this many epochs (0 = never; an
    /// epoch-0 evaluation of the random init is also emitted when > 0).
    pub eval_every: usize,
    /// Held-out fraction for the train/test split, in `[0, 1)`
    /// (0 = train on everything, no evaluation possible).
    pub test_frac: f64,
    /// Stop on an RMSE plateau (requires an evaluation cadence).
    pub early_stop: Option<EarlyStop>,
    /// Per-epoch multiplicative decay applied to both learning rates
    /// after each epoch (e.g. `0.95`; `None` = constant rates).
    pub lr_decay: Option<f32>,
    /// Write an FTCK serve checkpoint every this many epochs (0 = only a
    /// final checkpoint, when [`Schedule::checkpoint`] is set).
    pub checkpoint_every: usize,
    /// Checkpoint destination.  When set, the session always writes a
    /// final checkpoint at run end (in addition to any cadence above).
    pub checkpoint: Option<PathBuf>,
    /// Publish a snapshot to an attached serve [`crate::serve::Server`]
    /// every this many epochs (0 = never; only meaningful through
    /// [`super::Session::run_with_server`]).
    pub publish_every: usize,
}

impl Default for Schedule {
    fn default() -> Self {
        Self {
            epochs: 10,
            eval_every: 1,
            test_frac: 0.2,
            early_stop: None,
            lr_decay: None,
            checkpoint_every: 0,
            checkpoint: None,
            publish_every: 0,
        }
    }
}

// ======================================================================
// Validation
// ======================================================================

/// Everything `RunSpec::validate` can reject, as a typed taxonomy so
/// callers (CLI, tests, sweep runners) can match on the failure class
/// instead of parsing prose.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// Factor rank J is not a non-zero multiple of 16 (the paper's
    /// WMMA/MXU tile width; every compiled kernel shape assumes it).
    JNotTileable {
        /// The offending J.
        j: usize,
    },
    /// Kruskal rank R is not a non-zero multiple of 16.
    RNotTileable {
        /// The offending R.
        r: usize,
    },
    /// `--threads` was set on a backend that cannot use worker threads.
    ThreadsOnSerialBackend {
        /// The configured backend.
        backend: Backend,
        /// The requested thread count.
        threads: usize,
    },
    /// The HLO backend was selected but no compiled artifacts exist.
    HloWithoutArtifacts {
        /// The artifact directory that is missing `manifest.json`.
        dir: PathBuf,
    },
    /// A file data source points at a path that does not exist.
    MissingData {
        /// The missing path.
        path: PathBuf,
    },
    /// A store data source whose `FTB2` header does not check out
    /// (wrong magic/version, checksum mismatch, or truncation).
    StoreInvalid {
        /// The offending store path.
        path: PathBuf,
        /// Why the header was rejected.
        detail: String,
    },
    /// A paged store was combined with an algorithm whose sampling needs
    /// in-RAM per-mode indexes (only `plus` trains out of core).
    StoreNeedsPlus {
        /// The configured algorithm.
        algo: Algo,
    },
    /// A paged store was combined with a held-out split — splits are
    /// in-RAM; hold out a test set at ingest time instead.
    StoreWithSplit,
    /// A synthetic data source would generate an empty tensor.
    EmptySynth,
    /// A hyper-parameter is NaN or infinite.
    NonFiniteHyper {
        /// Which hyper-parameter (`lr_a`, `lr_b`, `lam_a`, `lam_b`).
        name: &'static str,
    },
    /// `schedule.epochs` is zero.
    ZeroEpochs,
    /// `schedule.test_frac` is outside `[0, 1)` (or not finite).
    BadTestFrac {
        /// The offending fraction.
        frac: f64,
    },
    /// An evaluation cadence was requested with no held-out split to
    /// evaluate on (`eval_every > 0` but `test_frac == 0`).
    EvalWithoutSplit,
    /// Early stopping needs RMSE evaluations, but `eval_every == 0`.
    EarlyStopWithoutEval,
    /// Early stopping with zero patience (would stop immediately) or a
    /// negative / non-finite `min_delta`.
    BadEarlyStop {
        /// The offending patience.
        patience: usize,
        /// The offending minimum delta.
        min_delta: f64,
    },
    /// A learning-rate decay that is zero, negative or non-finite.
    BadLrDecay {
        /// The offending decay factor.
        decay: f32,
    },
    /// A checkpoint cadence (`checkpoint_every > 0`) with no checkpoint
    /// path to write to.
    CheckpointCadenceWithoutPath,
    /// Sharded workers were combined with the HLO backend — the
    /// in-process workers each build their own backend, and the compiled
    /// artifacts assume exclusive device ownership.
    WorkersOnHlo {
        /// The requested worker count.
        workers: usize,
    },
    /// Sharded workers were combined with an algorithm whose sampling
    /// needs in-RAM per-mode indexes; shards train through
    /// [`crate::data::ShardView`], which never exposes one, so only
    /// `plus` trains sharded.
    WorkersNeedPlus {
        /// The configured algorithm.
        algo: Algo,
    },
    /// Sharded workers were combined with a serve-publish cadence — the
    /// distributed driver has no attached server (publish from the saved
    /// final model instead).
    WorkersWithPublish,
    /// `metrics` names a path that cannot be written: empty, an existing
    /// directory, or inside a directory that does not exist.
    BadMetricsPath {
        /// The offending path.
        path: PathBuf,
        /// Why it was rejected.
        detail: String,
    },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::JNotTileable { j } => write!(
                f,
                "J = {j} must be a non-zero multiple of 16 (WMMA/MXU tile width)"
            ),
            SpecError::RNotTileable { r } => write!(
                f,
                "R = {r} must be a non-zero multiple of 16 (WMMA/MXU tile width)"
            ),
            SpecError::ThreadsOnSerialBackend { backend, threads } => write!(
                f,
                "--threads {threads} has no effect on backend {} \
                 (only parallel_cpu uses worker threads)",
                backend.name()
            ),
            SpecError::HloWithoutArtifacts { dir } => write!(
                f,
                "backend hlo needs compiled artifacts, but {dir:?} has no manifest.json \
                 (run `make artifacts`, or use --backend parallel)"
            ),
            SpecError::MissingData { path } => {
                write!(f, "data file {path:?} does not exist")
            }
            SpecError::StoreInvalid { path, detail } => {
                write!(f, "store {path:?} is not a valid FTB2 file: {detail}")
            }
            SpecError::StoreNeedsPlus { algo } => write!(
                f,
                "algorithm {} needs in-RAM sampling indexes; paged FTB2 stores \
                 train with --algo plus",
                algo.name()
            ),
            SpecError::StoreWithSplit => write!(
                f,
                "paged stores train without a held-out split (set test_frac to 0 \
                 and hold out a test set at ingest time)"
            ),
            SpecError::EmptySynth => write!(f, "synthetic data source with nnz = 0"),
            SpecError::NonFiniteHyper { name } => {
                write!(f, "hyper-parameter {name} is not finite")
            }
            SpecError::ZeroEpochs => write!(f, "schedule.epochs must be >= 1"),
            SpecError::BadTestFrac { frac } => write!(
                f,
                "schedule.test_frac = {frac} must lie in [0, 1) (0 disables the held-out split)"
            ),
            SpecError::EvalWithoutSplit => write!(
                f,
                "schedule.eval_every > 0 needs a held-out split (test_frac > 0)"
            ),
            SpecError::EarlyStopWithoutEval => write!(
                f,
                "early stopping watches test RMSE, so schedule.eval_every must be > 0"
            ),
            SpecError::BadEarlyStop {
                patience,
                min_delta,
            } => write!(
                f,
                "early_stop needs patience >= 1 and a finite, non-negative min_delta \
                 (got patience {patience}, min_delta {min_delta})"
            ),
            SpecError::BadLrDecay { decay } => write!(
                f,
                "lr_decay = {decay} must be finite and > 0 (1.0 keeps rates constant)"
            ),
            SpecError::CheckpointCadenceWithoutPath => write!(
                f,
                "schedule.checkpoint_every > 0 needs schedule.checkpoint to name a path"
            ),
            SpecError::WorkersOnHlo { workers } => write!(
                f,
                "--workers {workers} runs in-process CPU workers; the hlo backend \
                 assumes exclusive device ownership (use --backend parallel)"
            ),
            SpecError::WorkersNeedPlus { algo } => write!(
                f,
                "algorithm {} needs in-RAM sampling indexes; sharded workers \
                 train with --algo plus",
                algo.name()
            ),
            SpecError::WorkersWithPublish => write!(
                f,
                "sharded runs have no attached serve server \
                 (set publish_every to 0 and publish from the saved model)"
            ),
            SpecError::BadMetricsPath { path, detail } => {
                write!(f, "metrics path {path:?} is not writable: {detail}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

// ======================================================================
// RunSpec
// ======================================================================

/// One complete, validated, serializable description of a run:
/// data source + trainer configuration + schedule.
///
/// `RunSpec` is the single entry point every consumer shares — the CLI
/// (`train --spec FILE` / `--dump-spec`), the examples, the convergence
/// benches and library users all construct one and hand it to
/// [`super::Session`].  The JSON round-trip is lossless
/// (`parse_str(dump()) == spec`), so a dumped spec file reproduces the
/// run bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// Where the tensor comes from.
    pub data: DataSource,
    /// The trainer configuration (algorithm, backend, ranks, hypers).
    pub train: TrainConfig,
    /// The epoch loop: duration, evaluation, stopping, checkpointing.
    pub schedule: Schedule,
    /// Telemetry sink: when set, the run appends `metrics.jsonl` snapshot
    /// lines (and, for sharded runs, the dist flight-recorder dump) to
    /// this path — the CLI's `--metrics FILE`.  `None` keeps telemetry
    /// export off; either way the trajectory is bit-identical
    /// (observation is strictly passive — see [`crate::obs`]).
    pub metrics: Option<PathBuf>,
}

impl Default for RunSpec {
    /// Toy data, default trainer config with the backend auto-selected
    /// for this checkout ([`TrainConfig::auto_backend`] — HLO when the
    /// artifacts exist, the parallel CPU engine otherwise), default
    /// schedule.
    fn default() -> Self {
        let base = TrainConfig::default();
        let backend = base.auto_backend();
        Self {
            data: DataSource::Toy,
            train: TrainConfig { backend, ..base },
            schedule: Schedule::default(),
            metrics: None,
        }
    }
}

impl RunSpec {
    /// Check the spec against the typed rejection taxonomy, returning the
    /// first violation.  [`super::Session::from_spec`] calls this, so an
    /// invalid spec never reaches the trainer.
    pub fn validate(&self) -> Result<(), SpecError> {
        // --- data ------------------------------------------------------
        match &self.data {
            DataSource::Toy => {}
            DataSource::File(path) => {
                if !path.exists() {
                    return Err(SpecError::MissingData { path: path.clone() });
                }
            }
            DataSource::Synth(s) => {
                if s.nnz == 0 {
                    return Err(SpecError::EmptySynth);
                }
            }
            DataSource::Store(path) => {
                if !path.exists() {
                    return Err(SpecError::MissingData { path: path.clone() });
                }
                if let Err(e) = crate::data::store::open_store(path) {
                    return Err(SpecError::StoreInvalid {
                        path: path.clone(),
                        detail: format!("{e:#}"),
                    });
                }
                if self.train.algo != Algo::Plus {
                    return Err(SpecError::StoreNeedsPlus {
                        algo: self.train.algo,
                    });
                }
                if self.schedule.test_frac != 0.0 {
                    return Err(SpecError::StoreWithSplit);
                }
            }
        }
        // --- trainer config -------------------------------------------
        let t = &self.train;
        if t.j == 0 || t.j % 16 != 0 {
            return Err(SpecError::JNotTileable { j: t.j });
        }
        if t.r == 0 || t.r % 16 != 0 {
            return Err(SpecError::RNotTileable { r: t.r });
        }
        if t.threads > 0 && t.backend != Backend::ParallelCpu {
            return Err(SpecError::ThreadsOnSerialBackend {
                backend: t.backend,
                threads: t.threads,
            });
        }
        // workers checks are structural, so they come before the
        // environment-dependent artifact probe
        if t.workers > 0 {
            if t.backend == Backend::Hlo {
                return Err(SpecError::WorkersOnHlo { workers: t.workers });
            }
            if t.algo != Algo::Plus {
                return Err(SpecError::WorkersNeedPlus { algo: t.algo });
            }
            if self.schedule.publish_every > 0 {
                return Err(SpecError::WorkersWithPublish);
            }
        }
        if t.backend == Backend::Hlo && !t.hlo_available() {
            return Err(SpecError::HloWithoutArtifacts {
                dir: t.artifact_dir.clone(),
            });
        }
        for (name, v) in [
            ("lr_a", t.hyper.lr_a),
            ("lr_b", t.hyper.lr_b),
            ("lam_a", t.hyper.lam_a),
            ("lam_b", t.hyper.lam_b),
        ] {
            if !v.is_finite() {
                return Err(SpecError::NonFiniteHyper { name });
            }
        }
        // --- schedule --------------------------------------------------
        let s = &self.schedule;
        if s.epochs == 0 {
            return Err(SpecError::ZeroEpochs);
        }
        if !s.test_frac.is_finite() || !(0.0..1.0).contains(&s.test_frac) {
            return Err(SpecError::BadTestFrac { frac: s.test_frac });
        }
        if s.eval_every > 0 && s.test_frac == 0.0 {
            return Err(SpecError::EvalWithoutSplit);
        }
        if let Some(es) = &s.early_stop {
            if s.eval_every == 0 {
                return Err(SpecError::EarlyStopWithoutEval);
            }
            if es.patience == 0 || !es.min_delta.is_finite() || es.min_delta < 0.0 {
                return Err(SpecError::BadEarlyStop {
                    patience: es.patience,
                    min_delta: es.min_delta,
                });
            }
        }
        if let Some(d) = s.lr_decay {
            if !d.is_finite() || d <= 0.0 {
                return Err(SpecError::BadLrDecay { decay: d });
            }
        }
        if s.checkpoint_every > 0 && s.checkpoint.is_none() {
            return Err(SpecError::CheckpointCadenceWithoutPath);
        }
        // --- metrics ---------------------------------------------------
        if let Some(m) = &self.metrics {
            if m.as_os_str().is_empty() {
                return Err(SpecError::BadMetricsPath {
                    path: m.clone(),
                    detail: "empty path".to_string(),
                });
            }
            if m.is_dir() {
                return Err(SpecError::BadMetricsPath {
                    path: m.clone(),
                    detail: "is a directory".to_string(),
                });
            }
            if let Some(parent) = m.parent() {
                if !parent.as_os_str().is_empty() && !parent.is_dir() {
                    return Err(SpecError::BadMetricsPath {
                        path: m.clone(),
                        detail: format!("parent directory {parent:?} does not exist"),
                    });
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // JSON round-trip
    // ------------------------------------------------------------------

    /// Serialize to a JSON value (the `--dump-spec` representation).
    pub fn to_json(&self) -> Json {
        let data = match &self.data {
            DataSource::Toy => json::obj(vec![("kind", json::s("toy"))]),
            DataSource::File(p) => json::obj(vec![
                ("kind", json::s("file")),
                ("path", json::s(&p.to_string_lossy())),
            ]),
            DataSource::Synth(s) => json::obj(vec![
                ("kind", json::s("synth")),
                ("preset", json::s(s.preset.name())),
                ("order", json::num(s.order as f64)),
                ("dim", json::num(s.dim as f64)),
                ("nnz", json::num(s.nnz as f64)),
                ("seed", num_u64(s.seed)),
            ]),
            DataSource::Store(p) => json::obj(vec![
                ("kind", json::s("store")),
                ("path", json::s(&p.to_string_lossy())),
            ]),
        };
        let t = &self.train;
        let train = json::obj(vec![
            ("algo", json::s(t.algo.name())),
            ("variant", json::s(t.variant.name())),
            ("strategy", json::s(t.strategy.name())),
            ("backend", json::s(t.backend.name())),
            ("j", json::num(t.j as f64)),
            ("r", json::num(t.r as f64)),
            ("seed", num_u64(t.seed)),
            ("threads", json::num(t.threads as f64)),
            ("workers", json::num(t.workers as f64)),
            ("cpu_kernel", json::s(t.cpu_kernel.name())),
            ("artifacts", json::s(&t.artifact_dir.to_string_lossy())),
            ("lr_a", num_f32(t.hyper.lr_a)),
            ("lr_b", num_f32(t.hyper.lr_b)),
            ("lam_a", num_f32(t.hyper.lam_a)),
            ("lam_b", num_f32(t.hyper.lam_b)),
        ]);
        let s = &self.schedule;
        let schedule = json::obj(vec![
            ("epochs", json::num(s.epochs as f64)),
            ("eval_every", json::num(s.eval_every as f64)),
            ("test_frac", json::num(s.test_frac)),
            (
                "early_stop",
                match &s.early_stop {
                    None => Json::Null,
                    Some(es) => json::obj(vec![
                        ("patience", json::num(es.patience as f64)),
                        ("min_delta", json::num(es.min_delta)),
                    ]),
                },
            ),
            (
                "lr_decay",
                match s.lr_decay {
                    None => Json::Null,
                    Some(d) => num_f32(d),
                },
            ),
            ("checkpoint_every", json::num(s.checkpoint_every as f64)),
            (
                "checkpoint",
                match &s.checkpoint {
                    None => Json::Null,
                    Some(p) => json::s(&p.to_string_lossy()),
                },
            ),
            ("publish_every", json::num(s.publish_every as f64)),
        ]);
        json::obj(vec![
            ("version", json::num(SPEC_VERSION as f64)),
            ("data", data),
            ("train", train),
            ("schedule", schedule),
            (
                "metrics",
                match &self.metrics {
                    None => Json::Null,
                    Some(m) => json::s(&m.to_string_lossy()),
                },
            ),
        ])
    }

    /// Serialize to a compact JSON string.
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    /// Parse a spec from a JSON value (inverse of [`RunSpec::to_json`]).
    pub fn from_json(v: &Json) -> Result<RunSpec, String> {
        let version = get_u64(v, "version")?;
        if version != SPEC_VERSION {
            return Err(format!(
                "unsupported spec version {version} (this build reads version {SPEC_VERSION})"
            ));
        }
        // --- data ------------------------------------------------------
        let d = v.get("data").ok_or("missing field \"data\"")?;
        let data = match get_str(d, "kind")? {
            "toy" => DataSource::Toy,
            "file" => DataSource::File(PathBuf::from(get_str(d, "path")?)),
            "store" => DataSource::Store(PathBuf::from(get_str(d, "path")?)),
            "synth" => DataSource::Synth(SynthSpec {
                preset: parse_field(d, "preset", SynthPreset::parse)?,
                order: get_usize(d, "order")?,
                dim: get_usize(d, "dim")? as u32,
                nnz: get_usize(d, "nnz")?,
                seed: get_u64(d, "seed")?,
            }),
            other => return Err(format!("unknown data kind {other:?}")),
        };
        // --- trainer config -------------------------------------------
        let t = v.get("train").ok_or("missing field \"train\"")?;
        let train = TrainConfig {
            algo: parse_field(t, "algo", Algo::parse)?,
            variant: parse_field(t, "variant", Variant::parse)?,
            strategy: parse_field(t, "strategy", Strategy::parse)?,
            backend: parse_field(t, "backend", Backend::parse)?,
            j: get_usize(t, "j")?,
            r: get_usize(t, "r")?,
            seed: get_u64(t, "seed")?,
            threads: get_usize(t, "threads")?,
            // absent in pre-dist spec files (same SPEC_VERSION): default 0
            workers: match t.get("workers") {
                None => 0,
                Some(_) => get_usize(t, "workers")?,
            },
            cpu_kernel: parse_field(t, "cpu_kernel", KernelPolicy::parse)?,
            artifact_dir: PathBuf::from(get_str(t, "artifacts")?),
            hyper: Hyper {
                lr_a: get_f64(t, "lr_a")? as f32,
                lr_b: get_f64(t, "lr_b")? as f32,
                lam_a: get_f64(t, "lam_a")? as f32,
                lam_b: get_f64(t, "lam_b")? as f32,
            },
        };
        // --- schedule --------------------------------------------------
        let s = v.get("schedule").ok_or("missing field \"schedule\"")?;
        let early_stop = match s.get("early_stop") {
            None | Some(Json::Null) => None,
            Some(es) => Some(EarlyStop {
                patience: get_usize(es, "patience")?,
                min_delta: get_f64(es, "min_delta")?,
            }),
        };
        let lr_decay = match s.get("lr_decay") {
            None | Some(Json::Null) => None,
            Some(d) => Some(
                d.as_f64()
                    .ok_or_else(|| format!("schedule.lr_decay: expected a number, got {d:?}"))?
                    as f32,
            ),
        };
        let checkpoint = match s.get("checkpoint") {
            None | Some(Json::Null) => None,
            Some(c) => Some(PathBuf::from(c.as_str().ok_or_else(|| {
                format!("schedule.checkpoint: expected a string, got {c:?}")
            })?)),
        };
        let schedule = Schedule {
            epochs: get_usize(s, "epochs")?,
            eval_every: get_usize(s, "eval_every")?,
            test_frac: get_f64(s, "test_frac")?,
            early_stop,
            lr_decay,
            checkpoint_every: get_usize(s, "checkpoint_every")?,
            checkpoint,
            publish_every: get_usize(s, "publish_every")?,
        };
        // absent in pre-telemetry spec files (same SPEC_VERSION): None
        let metrics = match v.get("metrics") {
            None | Some(Json::Null) => None,
            Some(m) => Some(PathBuf::from(m.as_str().ok_or_else(|| {
                format!("metrics: expected a string path, got {m:?}")
            })?)),
        };
        Ok(RunSpec {
            data,
            train,
            schedule,
            metrics,
        })
    }

    /// Parse a spec from its JSON text (inverse of [`RunSpec::dump`]).
    pub fn parse_str(text: &str) -> Result<RunSpec, String> {
        RunSpec::from_json(&Json::parse(text)?)
    }

    /// Write the spec to a file (the artifact `--dump-spec` produces).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.dump() + "\n").with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }

    /// Read a spec file written by [`RunSpec::save`] / `--dump-spec`.
    pub fn load(path: &Path) -> Result<RunSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {path:?}"))?;
        RunSpec::parse_str(&text)
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("parsing spec {path:?}"))
    }
}

// ======================================================================
// JSON field helpers
// ======================================================================

/// Exactly-representable u64s are emitted as JSON numbers; larger values
/// fall back to a decimal string so the round-trip stays lossless (the
/// in-tree JSON parser stores numbers as f64).
fn num_u64(v: u64) -> Json {
    if v <= (1u64 << 53) {
        Json::Num(v as f64)
    } else {
        Json::Str(v.to_string())
    }
}

/// Emit an f32 as the f64 nearest its shortest decimal representation:
/// `0.01f32` dumps as `0.01` (not `0.010000000707805157`), and parsing
/// that back through f64 then narrowing recovers the exact f32.
fn num_f32(v: f32) -> Json {
    Json::Num(v.to_string().parse::<f64>().unwrap_or(v as f64))
}

fn field<'a>(v: &'a Json, key: &str) -> Result<&'a Json, String> {
    v.get(key).ok_or_else(|| format!("missing field {key:?}"))
}

fn parse_field<T>(v: &Json, key: &str, parse: impl Fn(&str) -> Option<T>) -> Result<T, String> {
    let s = get_str(v, key)?;
    parse(s).ok_or_else(|| format!("field {key:?}: bad value {s:?}"))
}

fn get_str<'a>(v: &'a Json, key: &str) -> Result<&'a str, String> {
    field(v, key)?
        .as_str()
        .ok_or_else(|| format!("field {key:?}: expected a string"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    field(v, key)?
        .as_usize()
        .ok_or_else(|| format!("field {key:?}: expected a non-negative integer"))
}

fn get_f64(v: &Json, key: &str) -> Result<f64, String> {
    field(v, key)?
        .as_f64()
        .ok_or_else(|| format!("field {key:?}: expected a number"))
}

fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    match field(v, key)? {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= (1u64 << 53) as f64 => {
            Ok(*n as u64)
        }
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| format!("field {key:?}: bad u64 string {s:?}")),
        other => Err(format!("field {key:?}: expected a u64, got {other:?}")),
    }
}
