//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced by
//! `make artifacts`), compile them once per process on the CPU PJRT client,
//! and execute them from the L3 hot path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod exec;
pub mod manifest;

pub use exec::{Engine, Executable};
pub use manifest::{ArtifactInfo, Manifest};
