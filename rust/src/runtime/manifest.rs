//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  `manifest.json` lists every lowered kernel with its config
//! (kernel name, N, J, R, S) and input shapes; the runtime resolves logical
//! requests ("plus_factor_tc for N=3, J=16, R=16") to files through it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    /// Unique artifact name (`<kernel>_n<N>_j<J>_r<R>_s<S>`).
    pub name: String,
    /// Logical kernel this artifact implements.
    pub kernel: String,
    /// Tensor order N the kernel was lowered for.
    pub n: usize,
    /// Factor rank J.
    pub j: usize,
    /// Kruskal rank R.
    pub r: usize,
    /// Block slot count S (the batch shape).
    pub s: usize,
    /// HLO text file, resolved relative to the manifest directory.
    pub file: PathBuf,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<usize>>,
}

/// Parsed manifest with lookup by (kernel, n, j, r).
#[derive(Debug, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    by_name: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let format = root
            .get("format")
            .and_then(Json::as_usize)
            .context("manifest missing format")?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut by_name = BTreeMap::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts")?
        {
            let get_us = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("artifact entry missing {k}"))
            };
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact missing name")?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .context("artifact missing inputs")?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .context("bad input shape")?
                        .iter()
                        .map(|d| d.as_usize().context("bad dim"))
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let info = ArtifactInfo {
                kernel: a
                    .get("kernel")
                    .and_then(Json::as_str)
                    .context("artifact missing kernel")?
                    .to_string(),
                n: get_us("n")?,
                j: get_us("j")?,
                r: get_us("r")?,
                s: get_us("s")?,
                file: dir.join(
                    a.get("file")
                        .and_then(Json::as_str)
                        .context("artifact missing file")?,
                ),
                inputs,
                name: name.clone(),
            };
            by_name.insert(name, info);
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            by_name,
        })
    }

    /// Number of artifacts listed.
    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    /// Whether the manifest lists no artifacts.
    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Look up an artifact by exact name.
    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.by_name.get(name)
    }

    /// Find an artifact for `kernel` with the given decomposition config.
    /// Any S is accepted (the trainer adapts its block size to the artifact).
    pub fn find(&self, kernel: &str, n: usize, j: usize, r: usize) -> Result<&ArtifactInfo> {
        self.by_name
            .values()
            .filter(|a| a.kernel == kernel && a.n == n && a.j == j && a.r == r)
            .max_by_key(|a| a.s)
            .with_context(|| {
                format!("no artifact for kernel={kernel} n={n} j={j} r={r}; re-run `make artifacts`")
            })
    }

    /// Like [`find`](Self::find) but ignoring N — for kernels whose shape is
    /// order-independent (`compute_c` works on one mode's matrices).
    pub fn find_any_n(&self, kernel: &str, j: usize, r: usize) -> Result<&ArtifactInfo> {
        self.by_name
            .values()
            .filter(|a| a.kernel == kernel && a.j == j && a.r == r)
            .max_by_key(|a| a.s)
            .with_context(|| format!("no artifact for kernel={kernel} j={j} r={r}"))
    }

    /// Iterate over all artifact entries.
    pub fn iter(&self) -> impl Iterator<Item = &ArtifactInfo> {
        self.by_name.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("ft_manifest_test");
        write_manifest(
            &dir,
            r#"{"format":1,"dtype":"f32","artifacts":[
                {"name":"plus_factor_tc_n3_j16_r16_s512","kernel":"plus_factor_tc",
                 "n":3,"j":16,"r":16,"s":512,"file":"a.hlo.txt",
                 "inputs":[[3,512,16],[3,16,16],[512],[2]]},
                {"name":"plus_factor_tc_n3_j16_r16_s128","kernel":"plus_factor_tc",
                 "n":3,"j":16,"r":16,"s":128,"file":"b.hlo.txt",
                 "inputs":[[3,128,16],[3,16,16],[128],[2]]}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        let a = m.find("plus_factor_tc", 3, 16, 16).unwrap();
        assert_eq!(a.s, 512); // prefers the larger block
        assert_eq!(a.inputs[0], vec![3, 512, 16]);
        assert!(m.find("nope", 3, 16, 16).is_err());
    }

    #[test]
    fn rejects_bad_format() {
        let dir = std::env::temp_dir().join("ft_manifest_bad");
        write_manifest(&dir, r#"{"format":99,"artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_file_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
