//! Executable engine: PJRT CPU client + compile-once cache + typed execute.
//!
//! `Engine` owns the `PjRtClient` and a cache of compiled executables keyed
//! by artifact name; `Executable::run` stages `&[f32]` slabs as literals,
//! executes, and unpacks the return tuple back to `Vec<f32>` slabs.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactInfo, Manifest};

/// Compiled-artifact engine.  Not `Send`: PJRT client handles stay on the
/// thread that created them (the coordinator's executor thread).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

/// One compiled computation plus its interface metadata.
///
/// Execution goes through `execute_b` with self-managed `PjRtBuffer` inputs:
/// the crate's literal-based `execute` transfers each input literal to a
/// device buffer and `release()`s it without ever freeing — ~2 MB leaked per
/// call, which OOM-killed long bench runs (EXPERIMENTS.md §Perf #6).  Owning
/// the buffers ourselves restores correct Drop semantics and also skips one
/// host-side literal copy per input.
pub struct Executable {
    /// Interface metadata (shapes, block size S) from the manifest.
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest from `artifact_dir`.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    /// PJRT platform name (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the artifact with this exact name.
    pub fn load_named(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("parse HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let e = Rc::new(Executable {
            info,
            exe,
            client: self.client.clone(),
        });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Resolve by (kernel, n, j, r) and compile.
    pub fn load(&self, kernel: &str, n: usize, j: usize, r: usize) -> Result<Rc<Executable>> {
        let name = self.manifest.find(kernel, n, j, r)?.name.clone();
        self.load_named(&name)
    }

    /// Resolve ignoring N (order-independent kernels like `compute_c`).
    pub fn load_any_n(&self, kernel: &str, j: usize, r: usize) -> Result<Rc<Executable>> {
        let name = self.manifest.find_any_n(kernel, j, r)?.name.clone();
        self.load_named(&name)
    }
}

impl Executable {
    /// Execute with f32 slabs matching the artifact's declared input shapes.
    /// Returns the output tuple as f32 slabs.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        let mut bufs = Vec::with_capacity(inputs.len());
        for (k, (&data, shape)) in inputs.iter().zip(&self.info.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!(
                    "{}: input {k} has {} elements, shape {:?} wants {want}",
                    self.info.name,
                    data.len(),
                    shape
                );
            }
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, shape, None)
                .with_context(|| format!("stage input {k} shape {shape:?}"))?;
            bufs.push(buf);
        }
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&bufs)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        drop(bufs);
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple().context("unpack result tuple")?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().context("result to_vec"))
            .collect()
    }
}
