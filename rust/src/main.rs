//! `fasttucker` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   synth      — generate a synthetic sparse tensor (presets or custom)
//!   train      — run a decomposition and report per-epoch RMSE/MAE + timings
//!   serve      — train-or-load a checkpoint and answer batched queries
//!   query      — one-shot predict / top-K against a checkpoint
//!   checkpoint — convert / inspect serve checkpoints (FTCK format)
//!   cost       — print the Table-4 analytic cost model for a configuration
//!   info       — runtime / artifact inventory

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use fasttucker::bench::percentile;
use fasttucker::coordinator::{Algo, Backend, Strategy, TrainConfig, Variant};
use fasttucker::coordinator::Trainer;
use fasttucker::cost;
use fasttucker::kernel::KernelPolicy;
use fasttucker::model::TuckerModel;
use fasttucker::serve::{check_coords, mode_topk, Engine, ModelSnapshot, Server};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::{io, split::train_test_split};
use fasttucker::util::cli::{parse_u32_list, Args};
use fasttucker::util::rng::Pcg32;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> &'static str {
    "usage: fasttucker <synth|train|serve|query|checkpoint|cost|info> [flags]\n\
     \n\
     synth --out FILE [--preset netflix|yahoo|order] [--order N] [--dim I]\n\
           [--nnz K] [--seed S]\n\
     train --data FILE [--algo plus|fasttucker|fastertucker] [--variant tc|cc]\n\
           [--strategy calc|storage] [--backend hlo|cpu|parallel] [--threads K]\n\
           [--cpu-kernel tiled|scalar] [--epochs T] [--j J] [--r R] [--lr-a F]\n\
           [--lr-b F] [--lam-a F] [--lam-b F] [--test-frac F] [--seed S]\n\
           [--artifacts DIR] [--save FILE] [--checkpoint FILE]\n\
     serve [--checkpoint FILE] [--data FILE|--toy] [--epochs T] [--nnz K]\n\
           [--algo A] [--backend hlo|cpu|parallel] [--threads K] [--j J]\n\
           [--r R] [--seed S]\n\
           [--serve-threads K] [--batch B] [--queries Q] [--topk K] [--mode M]\n\
           (loads FILE if it exists; otherwise trains in this invocation and,\n\
            when FILE is given, checkpoints to it before serving)\n\
     query --checkpoint FILE --coords I1,I2,...,IN [--mode M] [--topk K]\n\
     checkpoint save --model FILE --out FILE [--algo A] [--epoch E]\n\
     checkpoint load --file FILE [--model-out FILE]\n\
     cost  [--order N] [--j J] [--r R] [--m M] [--nnz K]\n\
     info  [--artifacts DIR]"
}

fn run(argv: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        bail!("{}", usage());
    };
    match cmd.as_str() {
        "synth" => cmd_synth(rest.to_vec()),
        "train" => cmd_train(rest.to_vec()),
        "serve" => cmd_serve(rest.to_vec()),
        "query" => cmd_query(rest.to_vec()),
        "checkpoint" => cmd_checkpoint(rest.to_vec()),
        "cost" => cmd_cost(rest.to_vec()),
        "info" => cmd_info(rest.to_vec()),
        "profile" => cmd_profile(rest.to_vec()),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_synth(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &["out", "preset", "order", "dim", "nnz", "seed"],
        &[],
    )
    .map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(a.get("out").context("--out required")?);
    let seed = a.get_parse("seed", 1u64).map_err(anyhow::Error::msg)?;
    let nnz = a.get_parse("nnz", 200_000usize).map_err(anyhow::Error::msg)?;
    let cfg = match a.get_or("preset", "order") {
        "netflix" => SynthConfig::netflix_like(nnz, seed),
        "yahoo" => SynthConfig::yahoo_like(nnz, seed),
        "order" => {
            let order = a.get_parse("order", 3usize).map_err(anyhow::Error::msg)?;
            let dim = a.get_parse("dim", 1000u32).map_err(anyhow::Error::msg)?;
            SynthConfig::order_sweep(order, dim, nnz, seed)
        }
        p => bail!("unknown preset {p:?}"),
    };
    let t = generate(&cfg);
    if out.extension().map(|e| e == "ftb").unwrap_or(false) {
        io::write_binary(&t, &out)?;
    } else {
        io::write_text(&t, &out)?;
    }
    println!(
        "wrote {:?}: order {} dims {:?} nnz {} density {:.2e}",
        out,
        t.order(),
        t.dims,
        t.nnz(),
        t.density()
    );
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "data", "algo", "variant", "strategy", "backend", "threads", "cpu-kernel", "epochs",
            "j", "r", "lr-a", "lr-b", "lam-a", "lam-b", "test-frac", "seed", "artifacts", "save",
            "checkpoint", "toy",
        ],
        &["toy"],
    )
    .map_err(anyhow::Error::msg)?;
    let tensor = if a.get_bool("toy") {
        io::toy_dataset()
    } else {
        let data = a.get("data").context("--data FILE (or --toy) required")?;
        io::read_auto(Path::new(data))?
    };
    let mut cfg = TrainConfig::default();
    if let Some(s) = a.get("algo") {
        cfg.algo = Algo::parse(s).with_context(|| format!("bad --algo {s}"))?;
    }
    if let Some(s) = a.get("variant") {
        cfg.variant = Variant::parse(s).with_context(|| format!("bad --variant {s}"))?;
    }
    if let Some(s) = a.get("strategy") {
        cfg.strategy = Strategy::parse(s).with_context(|| format!("bad --strategy {s}"))?;
    }
    if let Some(s) = a.get("backend") {
        cfg.backend = Backend::parse(s).with_context(|| format!("bad --backend {s}"))?;
    }
    if let Some(s) = a.get("cpu-kernel") {
        cfg.cpu_kernel =
            KernelPolicy::parse(s).with_context(|| format!("bad --cpu-kernel {s}"))?;
    }
    cfg.threads = a.get_parse("threads", cfg.threads).map_err(anyhow::Error::msg)?;
    cfg.j = a.get_parse("j", cfg.j).map_err(anyhow::Error::msg)?;
    cfg.r = a.get_parse("r", cfg.r).map_err(anyhow::Error::msg)?;
    cfg.seed = a.get_parse("seed", cfg.seed).map_err(anyhow::Error::msg)?;
    cfg.hyper.lr_a = a.get_parse("lr-a", cfg.hyper.lr_a).map_err(anyhow::Error::msg)?;
    cfg.hyper.lr_b = a.get_parse("lr-b", cfg.hyper.lr_b).map_err(anyhow::Error::msg)?;
    cfg.hyper.lam_a = a.get_parse("lam-a", cfg.hyper.lam_a).map_err(anyhow::Error::msg)?;
    cfg.hyper.lam_b = a.get_parse("lam-b", cfg.hyper.lam_b).map_err(anyhow::Error::msg)?;
    cfg.artifact_dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let epochs: usize = a.get_parse("epochs", 10).map_err(anyhow::Error::msg)?;
    let test_frac: f64 = a.get_parse("test-frac", 0.2).map_err(anyhow::Error::msg)?;

    let (train, test) = train_test_split(&tensor, test_frac, cfg.seed);
    println!(
        "train nnz {} / test nnz {} | algo {} variant {} backend {:?}",
        train.nnz(),
        test.nnz(),
        cfg.algo.name(),
        cfg.variant.suffix(),
        cfg.backend
    );
    let mut trainer = Trainer::new(&train, cfg.clone())?;
    println!("runtime platform: {}", trainer.platform());
    let (rmse0, mae0) = trainer.evaluate(&test)?;
    println!("epoch  0: rmse {rmse0:.4}  mae {mae0:.4}  (init)");
    for epoch in 1..=epochs {
        let stats = trainer.epoch(&train)?;
        let (rmse, mae) = trainer.evaluate(&test)?;
        println!(
            "epoch {epoch:>2}: rmse {rmse:.4}  mae {mae:.4}  factor {:.3}s core {:.3}s (mem {:.3}s, pad {:.1}%)",
            stats.factor.total().as_secs_f64(),
            stats.core.total().as_secs_f64(),
            (stats.factor.memory() + stats.core.memory()).as_secs_f64(),
            100.0 * stats.factor.padding_ratio(),
        );
    }
    if let Some(path) = a.get("save") {
        trainer.model.save(Path::new(path))?;
        println!("saved model to {path}");
    }
    if let Some(path) = a.get("checkpoint") {
        trainer.snapshot().save(Path::new(path))?;
        println!(
            "saved serve checkpoint to {path} (epoch {}, algo {})",
            trainer.epoch_no,
            trainer.cfg.algo.name()
        );
    }
    Ok(())
}

/// Train-or-load a serving checkpoint, then answer a burst of batched
/// queries through the threaded serve loop (self-issued — runs offline).
/// With `--checkpoint FILE`: loads it if it exists, otherwise trains and
/// checkpoints to it first, then serves from the durable copy.
fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "checkpoint", "data", "toy", "epochs", "nnz", "algo", "backend", "threads", "j", "r",
            "seed", "serve-threads", "batch", "queries", "topk", "mode",
        ],
        &["toy"],
    )
    .map_err(anyhow::Error::msg)?;
    let ckpt = a.get("checkpoint").map(PathBuf::from);
    let snap = match &ckpt {
        Some(p) if p.exists() => {
            let s = ModelSnapshot::load(p)?;
            println!(
                "loaded checkpoint {p:?}: dims {:?} J {} R {} algo {} epoch {}",
                s.dims(),
                s.j(),
                s.r(),
                s.algo().name(),
                s.epoch()
            );
            s
        }
        _ => {
            let tensor = if a.get_bool("toy") {
                io::toy_dataset()
            } else if let Some(d) = a.get("data") {
                io::read_auto(Path::new(d))?
            } else {
                let nnz = a.get_parse("nnz", 60_000usize).map_err(anyhow::Error::msg)?;
                let seed = a.get_parse("seed", 42u64).map_err(anyhow::Error::msg)?;
                generate(&SynthConfig::netflix_like(nnz, seed))
            };
            let mut cfg = TrainConfig::default();
            cfg.backend = Backend::ParallelCpu; // serving path needs no artifacts
            if let Some(s) = a.get("algo") {
                cfg.algo = Algo::parse(s).with_context(|| format!("bad --algo {s}"))?;
            }
            if let Some(s) = a.get("backend") {
                cfg.backend = Backend::parse(s).with_context(|| format!("bad --backend {s}"))?;
            }
            cfg.threads = a.get_parse("threads", cfg.threads).map_err(anyhow::Error::msg)?;
            cfg.j = a.get_parse("j", cfg.j).map_err(anyhow::Error::msg)?;
            cfg.r = a.get_parse("r", cfg.r).map_err(anyhow::Error::msg)?;
            cfg.seed = a.get_parse("seed", cfg.seed).map_err(anyhow::Error::msg)?;
            let epochs: usize = a.get_parse("epochs", 5).map_err(anyhow::Error::msg)?;
            println!(
                "training {} epochs of {} on dims {:?} ({} nnz) before serving",
                epochs,
                cfg.algo.name(),
                tensor.dims,
                tensor.nnz()
            );
            let mut trainer = Trainer::new(&tensor, cfg)?;
            for _ in 0..epochs {
                trainer.epoch(&tensor)?;
            }
            let snap = trainer.snapshot();
            match &ckpt {
                Some(p) => {
                    snap.save(p)?;
                    println!("checkpointed to {p:?}; serving from the durable copy");
                    ModelSnapshot::load(p)?
                }
                None => snap,
            }
        }
    };

    let workers: usize = a.get_parse("serve-threads", 2).map_err(anyhow::Error::msg)?;
    let batch: usize = a.get_parse("batch", 32).map_err(anyhow::Error::msg)?;
    let queries: usize = a.get_parse("queries", 1000).map_err(anyhow::Error::msg)?;
    let k: usize = a.get_parse("topk", 5).map_err(anyhow::Error::msg)?;
    let mode: usize = a
        .get_parse("mode", 1usize.min(snap.order() - 1))
        .map_err(anyhow::Error::msg)?;
    ensure!(mode < snap.order(), "--mode {mode} out of range");
    let seed: u64 = a.get_parse("seed", 42).map_err(anyhow::Error::msg)?;

    let dims = snap.dims().to_vec();
    let server = Server::start(snap, workers, batch);
    let handle = server.handle();

    // a few demonstration top-K answers first
    let mut rng = Pcg32::new(seed, 0x5E);
    println!("\nsample top-{k} completions over mode {mode}:");
    for _ in 0..3 {
        let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
        let top = handle.topk(coords.clone(), mode, k).map_err(anyhow::Error::msg)?;
        let ranked: Vec<String> = top
            .iter()
            .map(|s| format!("{}:{:.3}", s.index, s.score))
            .collect();
        println!("  fixed {coords:?} -> {}", ranked.join(" "));
    }

    // query burst from concurrent clients (1 top-K per 8 predicts)
    let clients = workers.max(2);
    let per_client = queries.div_ceil(clients);
    let latencies = std::sync::Mutex::new(Vec::with_capacity(clients * per_client));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let handle = handle.clone();
            let dims = &dims;
            let latencies = &latencies;
            scope.spawn(move || {
                let mut rng = Pcg32::new(seed, 0x100 + c as u64);
                let mut local = Vec::with_capacity(per_client);
                for q in 0..per_client {
                    let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
                    let t = Instant::now();
                    let ok = if q % 8 == 7 {
                        handle.topk(coords, mode, k).is_ok()
                    } else {
                        handle.predict(coords).is_ok()
                    };
                    assert!(ok, "query failed");
                    local.push(t.elapsed().as_secs_f64());
                }
                latencies.lock().unwrap().extend(local);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut lat = latencies.into_inner().unwrap();
    let stats = server.shutdown();
    // qps counts only the timed burst (the demo top-Ks above predate t0)
    println!(
        "\nburst: {} requests in {:.3} s ({:.0} qps); server total {} requests, \
         {} batches (mean batch {:.1})",
        lat.len(),
        wall,
        lat.len() as f64 / wall,
        stats.served,
        stats.batches,
        stats.served as f64 / stats.batches.max(1) as f64
    );
    if !lat.is_empty() {
        println!(
            "latency p50 {:.1} µs  p99 {:.1} µs",
            percentile(&mut lat, 50.0) * 1e6,
            percentile(&mut lat, 99.0) * 1e6
        );
    }
    Ok(())
}

/// One-shot query against a checkpoint: predict an entry, or top-K
/// completion over `--mode` when given.
fn cmd_query(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["checkpoint", "coords", "mode", "topk"], &[])
        .map_err(anyhow::Error::msg)?;
    let path = PathBuf::from(a.get("checkpoint").context("--checkpoint FILE required")?);
    let snap = ModelSnapshot::load(&path)?;
    let coords = parse_u32_list(a.get("coords").context("--coords I1,I2,... required")?)
        .map_err(anyhow::Error::msg)?;
    let free_mode = match a.get("mode") {
        Some(_) => {
            let mode: usize = a.get_parse("mode", 0).map_err(anyhow::Error::msg)?;
            ensure!(mode < snap.order(), "--mode {mode} out of range");
            Some(mode)
        }
        None => None,
    };
    // same validation the serving workers apply (arity + bounds, free
    // mode exempt)
    check_coords(&snap, &coords, free_mode).map_err(anyhow::Error::msg)?;
    let mut engine = Engine::new(snap);
    match free_mode {
        Some(mode) => {
            let k: usize = a.get_parse("topk", 10).map_err(anyhow::Error::msg)?;
            for s in mode_topk(&mut engine, &coords, mode, k) {
                println!("{:>8}  {:.6}", s.index, s.score);
            }
        }
        None => println!("{:.6}", engine.predict(&coords)),
    }
    Ok(())
}

/// Convert an FTM1 model into a serve checkpoint (`save`), or validate and
/// describe an existing checkpoint (`load`).
fn cmd_checkpoint(argv: Vec<String>) -> Result<()> {
    let Some((sub, rest)) = argv.split_first() else {
        bail!("usage: checkpoint <save|load> [flags]");
    };
    match sub.as_str() {
        "save" => {
            let a = Args::parse(rest.to_vec(), &["model", "out", "algo", "epoch"], &[])
                .map_err(anyhow::Error::msg)?;
            let model = TuckerModel::load(Path::new(
                a.get("model").context("--model FILE (FTM1) required")?,
            ))?;
            let out = PathBuf::from(a.get("out").context("--out FILE required")?);
            let algo = match a.get("algo") {
                Some(s) => Algo::parse(s).with_context(|| format!("bad --algo {s}"))?,
                None => Algo::Plus,
            };
            let epoch: u64 = a.get_parse("epoch", 0).map_err(anyhow::Error::msg)?;
            let snap = ModelSnapshot::from_model(&model, algo, epoch);
            snap.save(&out)?;
            println!(
                "wrote {out:?}: dims {:?} J {} R {} algo {} epoch {} ({} params)",
                snap.dims(),
                snap.j(),
                snap.r(),
                algo.name(),
                epoch,
                snap.param_count()
            );
        }
        "load" => {
            let a = Args::parse(rest.to_vec(), &["file", "model-out"], &[])
                .map_err(anyhow::Error::msg)?;
            let path = PathBuf::from(a.get("file").context("--file FILE required")?);
            let snap = ModelSnapshot::load(&path)?;
            println!(
                "{path:?}: checksum ok; dims {:?} J {} R {} algo {} epoch {} ({} params)",
                snap.dims(),
                snap.j(),
                snap.r(),
                snap.algo().name(),
                snap.epoch(),
                snap.param_count()
            );
            if let Some(out) = a.get("model-out") {
                snap.to_model().save(Path::new(out))?;
                println!("wrote FTM1 model to {out}");
            }
        }
        other => bail!("unknown checkpoint subcommand {other:?} (save|load)"),
    }
    Ok(())
}

fn cmd_cost(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["order", "j", "r", "m", "nnz"], &[]).map_err(anyhow::Error::msg)?;
    let shape = cost::Shape {
        n: a.get_parse("order", 3usize).map_err(anyhow::Error::msg)?,
        j: a.get_parse("j", 16usize).map_err(anyhow::Error::msg)?,
        r: a.get_parse("r", 16usize).map_err(anyhow::Error::msg)?,
        m: a.get_parse("m", 16usize).map_err(anyhow::Error::msg)?,
    };
    let nnz: usize = a.get_parse("nnz", 1_000_000).map_err(anyhow::Error::msg)?;
    println!(
        "Table 4 cost model (N={} J={} R={} M={}, |Ω|={nnz}):",
        shape.n, shape.j, shape.r, shape.m
    );
    println!(
        "{:<16} {:>14} {:>14} {:>14} {:>12} {:>10}",
        "algorithm", "params read", "D-chain muls", "B·D muls", "written", "MXU frac"
    );
    for algo in [
        cost::Algo::FastTucker,
        cost::Algo::FasterTucker,
        cost::Algo::FastTuckerPlus,
    ] {
        println!(
            "{:<16} {:>14} {:>14} {:>14} {:>12} {:>10.2}",
            algo.name(),
            cost::params_read(algo, shape),
            cost::d_chain_muls(algo, shape),
            cost::bd_muls(algo, shape),
            cost::params_written(algo, shape),
            cost::mxu_fraction(algo, shape),
        );
    }
    println!("\nper-pass estimates over |Ω| (bandwidth-scaled):");
    let bw = fasttucker::bench::measure_bandwidth();
    println!("measured host bandwidth: {:.2} GB/s", bw / 1e9);
    for algo in [
        cost::Algo::FastTucker,
        cost::Algo::FasterTucker,
        cost::Algo::FastTuckerPlus,
    ] {
        println!(
            "{:<16} memory {:>10}  flops {:.3e}",
            algo.name(),
            fasttucker::bench::fmt_secs(cost::memory_time_s(algo, shape, nnz, bw)),
            cost::flops_per_pass(algo, shape, nnz),
        );
    }
    Ok(())
}

/// Raw executable microbenchmark: `fasttucker profile --name <artifact>`
/// times `execute` with synthetic inputs, isolating PJRT/XLA cost from the
/// coordinator (gather/scatter/sampling).  The L2 §Perf numbers in
/// EXPERIMENTS.md come from this.
fn cmd_profile(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["artifacts", "name", "reps"], &[]).map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let engine = fasttucker::runtime::Engine::new(&dir)?;
    let reps: usize = a.get_parse("reps", 50).map_err(anyhow::Error::msg)?;
    let names: Vec<String> = match a.get("name") {
        Some(n) => n.split(',').map(|s| s.to_string()).collect(),
        None => engine.manifest().iter().map(|i| i.name.clone()).collect(),
    };
    for name in names {
        let exe = engine.load_named(&name)?;
        let inputs: Vec<Vec<f32>> = exe
            .info
            .inputs
            .iter()
            .map(|shape| vec![0.1f32; shape.iter().product()])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let row = fasttucker::bench::measure(&name, 3, reps, || {
            exe.run(&refs).expect("execute");
            0.0
        });
        println!(
            "{:<44} {:>12} (mad {})",
            row.label,
            fasttucker::bench::fmt_secs(row.median_s),
            fasttucker::bench::fmt_secs(row.mad_s)
        );
    }
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["artifacts"], &[]).map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let engine = fasttucker::runtime::Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts in {dir:?}: {}", engine.manifest().len());
    let mut kernels: Vec<&str> = engine.manifest().iter().map(|a| a.kernel.as_str()).collect();
    kernels.sort_unstable();
    kernels.dedup();
    for k in kernels {
        let configs: Vec<String> = engine
            .manifest()
            .iter()
            .filter(|a| a.kernel == k)
            .map(|a| format!("n{}j{}r{}s{}", a.n, a.j, a.r, a.s))
            .collect();
        println!("  {k}: {}", configs.join(" "));
    }
    Ok(())
}
