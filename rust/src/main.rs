//! `fasttucker` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   synth  — generate a synthetic sparse tensor (presets or custom)
//!   train  — run a decomposition and report per-epoch RMSE/MAE + timings
//!   cost   — print the Table-4 analytic cost model for a configuration
//!   info   — runtime / artifact inventory

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use fasttucker::coordinator::{Algo, Backend, Strategy, TrainConfig, Variant};
use fasttucker::coordinator::Trainer;
use fasttucker::cost;
use fasttucker::kernel::KernelPolicy;
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::{io, split::train_test_split};
use fasttucker::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> &'static str {
    "usage: fasttucker <synth|train|cost|info> [flags]\n\
     \n\
     synth --out FILE [--preset netflix|yahoo|order] [--order N] [--dim I]\n\
           [--nnz K] [--seed S]\n\
     train --data FILE [--algo plus|fasttucker|fastertucker] [--variant tc|cc]\n\
           [--strategy calc|storage] [--backend hlo|cpu|parallel] [--threads K]\n\
           [--cpu-kernel tiled|scalar] [--epochs T] [--j J] [--r R] [--lr-a F]\n\
           [--lr-b F] [--lam-a F] [--lam-b F] [--test-frac F] [--seed S]\n\
           [--artifacts DIR] [--save FILE]\n\
     cost  [--order N] [--j J] [--r R] [--m M] [--nnz K]\n\
     info  [--artifacts DIR]"
}

fn run(argv: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        bail!("{}", usage());
    };
    match cmd.as_str() {
        "synth" => cmd_synth(rest.to_vec()),
        "train" => cmd_train(rest.to_vec()),
        "cost" => cmd_cost(rest.to_vec()),
        "info" => cmd_info(rest.to_vec()),
        "profile" => cmd_profile(rest.to_vec()),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_synth(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &["out", "preset", "order", "dim", "nnz", "seed"],
        &[],
    )
    .map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(a.get("out").context("--out required")?);
    let seed = a.get_parse("seed", 1u64).map_err(anyhow::Error::msg)?;
    let nnz = a.get_parse("nnz", 200_000usize).map_err(anyhow::Error::msg)?;
    let cfg = match a.get_or("preset", "order") {
        "netflix" => SynthConfig::netflix_like(nnz, seed),
        "yahoo" => SynthConfig::yahoo_like(nnz, seed),
        "order" => {
            let order = a.get_parse("order", 3usize).map_err(anyhow::Error::msg)?;
            let dim = a.get_parse("dim", 1000u32).map_err(anyhow::Error::msg)?;
            SynthConfig::order_sweep(order, dim, nnz, seed)
        }
        p => bail!("unknown preset {p:?}"),
    };
    let t = generate(&cfg);
    if out.extension().map(|e| e == "ftb").unwrap_or(false) {
        io::write_binary(&t, &out)?;
    } else {
        io::write_text(&t, &out)?;
    }
    println!(
        "wrote {:?}: order {} dims {:?} nnz {} density {:.2e}",
        out,
        t.order(),
        t.dims,
        t.nnz(),
        t.density()
    );
    Ok(())
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "data", "algo", "variant", "strategy", "backend", "threads", "cpu-kernel", "epochs",
            "j", "r", "lr-a", "lr-b", "lam-a", "lam-b", "test-frac", "seed", "artifacts", "save",
            "toy",
        ],
        &["toy"],
    )
    .map_err(anyhow::Error::msg)?;
    let tensor = if a.get_bool("toy") {
        io::toy_dataset()
    } else {
        let data = a.get("data").context("--data FILE (or --toy) required")?;
        io::read_auto(Path::new(data))?
    };
    let mut cfg = TrainConfig::default();
    if let Some(s) = a.get("algo") {
        cfg.algo = Algo::parse(s).with_context(|| format!("bad --algo {s}"))?;
    }
    if let Some(s) = a.get("variant") {
        cfg.variant = Variant::parse(s).with_context(|| format!("bad --variant {s}"))?;
    }
    if let Some(s) = a.get("strategy") {
        cfg.strategy = Strategy::parse(s).with_context(|| format!("bad --strategy {s}"))?;
    }
    if let Some(s) = a.get("backend") {
        cfg.backend = Backend::parse(s).with_context(|| format!("bad --backend {s}"))?;
    }
    if let Some(s) = a.get("cpu-kernel") {
        cfg.cpu_kernel =
            KernelPolicy::parse(s).with_context(|| format!("bad --cpu-kernel {s}"))?;
    }
    cfg.threads = a.get_parse("threads", cfg.threads).map_err(anyhow::Error::msg)?;
    cfg.j = a.get_parse("j", cfg.j).map_err(anyhow::Error::msg)?;
    cfg.r = a.get_parse("r", cfg.r).map_err(anyhow::Error::msg)?;
    cfg.seed = a.get_parse("seed", cfg.seed).map_err(anyhow::Error::msg)?;
    cfg.hyper.lr_a = a.get_parse("lr-a", cfg.hyper.lr_a).map_err(anyhow::Error::msg)?;
    cfg.hyper.lr_b = a.get_parse("lr-b", cfg.hyper.lr_b).map_err(anyhow::Error::msg)?;
    cfg.hyper.lam_a = a.get_parse("lam-a", cfg.hyper.lam_a).map_err(anyhow::Error::msg)?;
    cfg.hyper.lam_b = a.get_parse("lam-b", cfg.hyper.lam_b).map_err(anyhow::Error::msg)?;
    cfg.artifact_dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let epochs: usize = a.get_parse("epochs", 10).map_err(anyhow::Error::msg)?;
    let test_frac: f64 = a.get_parse("test-frac", 0.2).map_err(anyhow::Error::msg)?;

    let (train, test) = train_test_split(&tensor, test_frac, cfg.seed);
    println!(
        "train nnz {} / test nnz {} | algo {} variant {} backend {:?}",
        train.nnz(),
        test.nnz(),
        cfg.algo.name(),
        cfg.variant.suffix(),
        cfg.backend
    );
    let mut trainer = Trainer::new(&train, cfg.clone())?;
    println!("runtime platform: {}", trainer.platform());
    let (rmse0, mae0) = trainer.evaluate(&test)?;
    println!("epoch  0: rmse {rmse0:.4}  mae {mae0:.4}  (init)");
    for epoch in 1..=epochs {
        let stats = trainer.epoch(&train)?;
        let (rmse, mae) = trainer.evaluate(&test)?;
        println!(
            "epoch {epoch:>2}: rmse {rmse:.4}  mae {mae:.4}  factor {:.3}s core {:.3}s (mem {:.3}s, pad {:.1}%)",
            stats.factor.total().as_secs_f64(),
            stats.core.total().as_secs_f64(),
            (stats.factor.memory() + stats.core.memory()).as_secs_f64(),
            100.0 * stats.factor.padding_ratio(),
        );
    }
    if let Some(path) = a.get("save") {
        trainer.model.save(Path::new(path))?;
        println!("saved model to {path}");
    }
    Ok(())
}

fn cmd_cost(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["order", "j", "r", "m", "nnz"], &[]).map_err(anyhow::Error::msg)?;
    let shape = cost::Shape {
        n: a.get_parse("order", 3usize).map_err(anyhow::Error::msg)?,
        j: a.get_parse("j", 16usize).map_err(anyhow::Error::msg)?,
        r: a.get_parse("r", 16usize).map_err(anyhow::Error::msg)?,
        m: a.get_parse("m", 16usize).map_err(anyhow::Error::msg)?,
    };
    let nnz: usize = a.get_parse("nnz", 1_000_000).map_err(anyhow::Error::msg)?;
    println!(
        "Table 4 cost model (N={} J={} R={} M={}, |Ω|={nnz}):",
        shape.n, shape.j, shape.r, shape.m
    );
    println!(
        "{:<16} {:>14} {:>14} {:>14} {:>12} {:>10}",
        "algorithm", "params read", "D-chain muls", "B·D muls", "written", "MXU frac"
    );
    for algo in [
        cost::Algo::FastTucker,
        cost::Algo::FasterTucker,
        cost::Algo::FastTuckerPlus,
    ] {
        println!(
            "{:<16} {:>14} {:>14} {:>14} {:>12} {:>10.2}",
            algo.name(),
            cost::params_read(algo, shape),
            cost::d_chain_muls(algo, shape),
            cost::bd_muls(algo, shape),
            cost::params_written(algo, shape),
            cost::mxu_fraction(algo, shape),
        );
    }
    println!("\nper-pass estimates over |Ω| (bandwidth-scaled):");
    let bw = fasttucker::bench::measure_bandwidth();
    println!("measured host bandwidth: {:.2} GB/s", bw / 1e9);
    for algo in [
        cost::Algo::FastTucker,
        cost::Algo::FasterTucker,
        cost::Algo::FastTuckerPlus,
    ] {
        println!(
            "{:<16} memory {:>10}  flops {:.3e}",
            algo.name(),
            fasttucker::bench::fmt_secs(cost::memory_time_s(algo, shape, nnz, bw)),
            cost::flops_per_pass(algo, shape, nnz),
        );
    }
    Ok(())
}

/// Raw executable microbenchmark: `fasttucker profile --name <artifact>`
/// times `execute` with synthetic inputs, isolating PJRT/XLA cost from the
/// coordinator (gather/scatter/sampling).  The L2 §Perf numbers in
/// EXPERIMENTS.md come from this.
fn cmd_profile(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["artifacts", "name", "reps"], &[]).map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let engine = fasttucker::runtime::Engine::new(&dir)?;
    let reps: usize = a.get_parse("reps", 50).map_err(anyhow::Error::msg)?;
    let names: Vec<String> = match a.get("name") {
        Some(n) => n.split(',').map(|s| s.to_string()).collect(),
        None => engine.manifest().iter().map(|i| i.name.clone()).collect(),
    };
    for name in names {
        let exe = engine.load_named(&name)?;
        let inputs: Vec<Vec<f32>> = exe
            .info
            .inputs
            .iter()
            .map(|shape| vec![0.1f32; shape.iter().product()])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let row = fasttucker::bench::measure(&name, 3, reps, || {
            exe.run(&refs).expect("execute");
            0.0
        });
        println!(
            "{:<44} {:>12} (mad {})",
            row.label,
            fasttucker::bench::fmt_secs(row.median_s),
            fasttucker::bench::fmt_secs(row.mad_s)
        );
    }
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["artifacts"], &[]).map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let engine = fasttucker::runtime::Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts in {dir:?}: {}", engine.manifest().len());
    let mut kernels: Vec<&str> = engine.manifest().iter().map(|a| a.kernel.as_str()).collect();
    kernels.sort_unstable();
    kernels.dedup();
    for k in kernels {
        let configs: Vec<String> = engine
            .manifest()
            .iter()
            .filter(|a| a.kernel == k)
            .map(|a| format!("n{}j{}r{}s{}", a.n, a.j, a.r, a.s))
            .collect();
        println!("  {k}: {}", configs.join(" "));
    }
    Ok(())
}
