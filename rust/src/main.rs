//! `fasttucker` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   synth      — generate a synthetic sparse tensor (presets or custom)
//!   ingest     — convert text/FTB1 tensors to the paged FTB2 store, in
//!                constant memory
//!   train      — run a decomposition and report per-epoch RMSE/MAE + timings
//!                (`--store FILE.ftb2` trains out of core)
//!   serve      — train-or-load a checkpoint and answer batched queries;
//!                `--listen ADDR` runs the TCP front end + model registry
//!   query      — one-shot predict / top-K against a checkpoint, or over
//!                the wire with `--connect ADDR` (`--stats` for telemetry)
//!   registry   — promote / rollback / load / list models on a live server
//!   slo        — closed-loop SLO load harness against a live server
//!   checkpoint — convert / inspect serve checkpoints (FTCK format)
//!   cost       — print the Table-4 analytic cost model for a configuration
//!   info       — runtime / artifact inventory
//!
//! `train` and `serve` are thin shells over the session layer: every flag
//! path constructs a [`RunSpec`] and executes it through a [`Session`],
//! and `--dump-spec` / `--spec FILE` serialize and replay that spec, so a
//! flag-driven run and its dumped spec file are bit-identical.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use fasttucker::bench::percentile;
use fasttucker::coordinator::{Algo, Backend, Strategy, TrainConfig, Variant};
use fasttucker::cost;
use fasttucker::data;
use fasttucker::dist;
use fasttucker::kernel::KernelPolicy;
use fasttucker::model::TuckerModel;
use fasttucker::obs::{render_text, MetricsFile};
use fasttucker::serve::net::{run_slo, slo_header, NetClient, NetConfig, NetServer, SloConfig, SloRow};
use fasttucker::serve::{
    check_coords, mode_topk, Engine, ModelSnapshot, Registry, Request, Response, Server,
};
use fasttucker::util::json;
use fasttucker::session::{
    DataSource, EarlyStop, NullObserver, ProgressPrinter, RunSpec, Schedule, Session, SynthPreset,
    SynthSpec,
};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::io;
use fasttucker::util::cli::{parse_u32_list, Args};
use fasttucker::util::rng::Pcg32;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> &'static str {
    "usage: fasttucker <synth|ingest|train|serve|query|registry|slo|checkpoint|cost|info> [flags]\n\
     \n\
     synth --out FILE [--preset netflix|yahoo|order] [--order N] [--dim I]\n\
           [--nnz K] [--seed S]\n\
           (extension picks the format: .ftb binary, .ftb2 paged store,\n\
            anything else text)\n\
     ingest --input FILE --out FILE.ftb2 [--page-entries N]\n\
           (streaming text/FTB1 -> FTB2 conversion in constant memory;\n\
            train from the result with train --store)\n\
     train --data FILE|--store FILE.ftb2|--toy\n\
           [--algo plus|fasttucker|fastertucker]\n\
           [--variant tc|cc] [--strategy calc|storage]\n\
           [--backend hlo|cpu|parallel] [--threads K] [--workers N]\n\
           [--cpu-kernel tiled|scalar|simd] [--epochs T] [--j J] [--r R] [--lr-a F]\n\
           [--lr-b F] [--lam-a F] [--lam-b F] [--test-frac F] [--seed S]\n\
           [--eval-every N] [--early-stop PATIENCE] [--min-delta F]\n\
           [--lr-decay F] [--artifacts DIR] [--save FILE]\n\
           [--checkpoint FILE] [--checkpoint-every N]\n\
           [--spec FILE] [--dump-spec] [--metrics FILE.jsonl]\n\
           (flags build a validated RunSpec executed by the session layer;\n\
            --dump-spec prints that spec as JSON and exits, --spec FILE\n\
            replays a dumped spec bit-identically, ignoring config flags;\n\
            --workers N trains data-parallel on N in-process shard workers\n\
            with barrier averaging — N=1 matches serial byte-for-byte;\n\
            --metrics FILE.jsonl appends telemetry snapshots per epoch and,\n\
            under --workers, the protocol flight-recorder tape — purely\n\
            observational, the trained model is bit-identical without it)\n\
     train --coordinator HOST:PORT --workers N [train's config flags]\n\
           (the TCP coordinator: binds HOST:PORT, waits for N worker\n\
            processes to join, then runs the same sharded protocol over\n\
            sockets — 1 worker over loopback matches serial byte-for-byte)\n\
     train --join HOST:PORT [--store FILE.ftb2] [--timeout-ms MS]\n\
           (a TCP worker process: all training config comes from the\n\
            coordinator's welcome frame; --store opens a local copy of\n\
            the paged store instead of the coordinator's data source)\n\
     serve [--checkpoint FILE] [--data FILE|--toy] [--epochs T] [--nnz K]\n\
           [--spec FILE] [--dump-spec] [train's config flags: --algo,\n\
            --backend, --threads, --j, --r, --seed, --artifacts, ...]\n\
           [--serve-threads K] [--batch B] [--queries Q] [--topk K] [--mode M]\n\
           [--metrics FILE.jsonl]\n\
           (loads FILE if it exists; otherwise trains through the session\n\
            layer and, when FILE is given, checkpoints to it before serving;\n\
            --metrics writes per-request latency histograms, batch-size\n\
            distribution and queue stats after the burst, plus a text dump)\n\
     serve --listen HOST:PORT [--model NAME] [--max-pending N]\n\
           [--deadline-ms D] [--cache-fibers N] [--publish-every N]\n\
           [serve's config flags: --checkpoint, --serve-threads, ...]\n\
           (the network tier: a TCP front end over newline-delimited JSON\n\
            frames, backed by a model registry; an existing --checkpoint is\n\
            served directly, otherwise training runs behind the listener,\n\
            publishing into the registry every --publish-every epochs;\n\
            drains cleanly on SIGTERM, `query --shutdown`, or a shutdown\n\
            frame — every accepted request is answered before exit)\n\
     query --checkpoint FILE --coords I1,I2,...,IN [--mode M] [--topk K]\n\
           [--cpu-kernel tiled|scalar|simd]\n\
     query --connect HOST:PORT [--model NAME] [--deadline-ms D]\n\
           [--timeout-ms MS]\n\
           (--coords ... [--mode M] [--topk K] | --stats | --epoch |\n\
            --shutdown; --timeout-ms bounds every socket read/write,\n\
            default 30000)\n\
           (same output formats as the checkpoint path, over the wire;\n\
            --stats prints the server's telemetry registry, --shutdown\n\
            asks it to drain)\n\
     registry <list|promote|rollback|load> --connect HOST:PORT\n\
           [--model NAME] [--version V] [--path FILE.ftck] [--timeout-ms MS]\n\
           (admin ops against a live server; every op prints the\n\
            resulting registry table)\n\
     slo   --connect HOST:PORT [--model NAME] [--connections C]\n\
           [--qps Q1,Q2,...] [--step-secs S] [--deadline-ms D]\n\
           [--topk-every N] [--mode M] [--k K] [--seed S] [--json FILE]\n\
           (closed-loop load harness: walks the offered-QPS ladder and\n\
            reports achieved QPS, p50/p95/p99 latency and shed counts per\n\
            step; --json writes the BENCH_serve_slo.json row format)\n\
     checkpoint save --model FILE --out FILE [--algo A] [--epoch E]\n\
     checkpoint load --file FILE [--model-out FILE]\n\
     cost  [--order N] [--j J] [--r R] [--m M] [--nnz K]\n\
     info  [--artifacts DIR]"
}

fn run(argv: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        bail!("{}", usage());
    };
    match cmd.as_str() {
        "synth" => cmd_synth(rest.to_vec()),
        "ingest" => cmd_ingest(rest.to_vec()),
        "train" => cmd_train(rest.to_vec()),
        "serve" => cmd_serve(rest.to_vec()),
        "query" => cmd_query(rest.to_vec()),
        "registry" => cmd_registry(rest.to_vec()),
        "slo" => cmd_slo(rest.to_vec()),
        "checkpoint" => cmd_checkpoint(rest.to_vec()),
        "cost" => cmd_cost(rest.to_vec()),
        "info" => cmd_info(rest.to_vec()),
        "profile" => cmd_profile(rest.to_vec()),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_synth(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &["out", "preset", "order", "dim", "nnz", "seed"],
        &[],
    )
    .map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(a.get("out").context("--out required")?);
    let seed = a.get_parse("seed", 1u64).map_err(anyhow::Error::msg)?;
    let nnz = a.get_parse("nnz", 200_000usize).map_err(anyhow::Error::msg)?;
    let cfg = match a.get_or("preset", "order") {
        "netflix" => SynthConfig::netflix_like(nnz, seed),
        "yahoo" => SynthConfig::yahoo_like(nnz, seed),
        "order" => {
            let order = a.get_parse("order", 3usize).map_err(anyhow::Error::msg)?;
            let dim = a.get_parse("dim", 1000u32).map_err(anyhow::Error::msg)?;
            SynthConfig::order_sweep(order, dim, nnz, seed)
        }
        p => bail!("unknown preset {p:?}"),
    };
    let t = generate(&cfg);
    match out.extension().and_then(|e| e.to_str()) {
        Some("ftb") => io::write_binary(&t, &out)?,
        Some("ftb2") => {
            data::store::write_store(&t, &out, data::store::DEFAULT_PAGE_ENTRIES)?;
        }
        _ => io::write_text(&t, &out)?,
    }
    println!(
        "wrote {:?}: order {} dims {:?} nnz {} density {:.2e}",
        out,
        t.order(),
        t.dims,
        t.nnz(),
        t.density()
    );
    Ok(())
}

/// Streaming text/FTB1 → FTB2 conversion (constant memory: the resident
/// set is one section buffer regardless of tensor size).
fn cmd_ingest(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["input", "out", "page-entries"], &[]).map_err(anyhow::Error::msg)?;
    let input = PathBuf::from(a.get("input").context("--input FILE required")?);
    let out = PathBuf::from(a.get("out").context("--out FILE.ftb2 required")?);
    if out.extension().and_then(|e| e.to_str()) != Some("ftb2") {
        eprintln!(
            "note: {out:?} does not end in .ftb2 — train auto-detection keys on the \
             extension (use train --store to force the paged path)"
        );
    }
    let page: usize = a
        .get_parse("page-entries", data::store::DEFAULT_PAGE_ENTRIES)
        .map_err(anyhow::Error::msg)?;
    let t0 = Instant::now();
    let stats = data::ingest_file(&input, &out, page)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "ingested {input:?} -> {out:?}: {} entries in {} sections of {page}, \
         {:.2} MB on disk",
        stats.nnz,
        stats.pages,
        stats.out_bytes as f64 / 1e6
    );
    println!(
        "  {secs:.2} s ({:.2} Mentries/s); peak {} entries buffered (bounded by \
         --page-entries)",
        stats.nnz as f64 / secs.max(1e-9) / 1e6,
        stats.peak_buffered
    );
    Ok(())
}

/// Trainer configuration from the shared config flags (`--algo`,
/// `--backend`, ranks, hypers...).  With no `--backend` flag the backend
/// is auto-selected for this checkout ([`TrainConfig::auto_backend`]), so
/// a clean checkout without `artifacts/` trains out of the box.
fn train_config_from_flags(a: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    if let Some(s) = a.get("algo") {
        cfg.algo = Algo::parse(s).with_context(|| format!("bad --algo {s}"))?;
    }
    if let Some(s) = a.get("variant") {
        cfg.variant = Variant::parse(s).with_context(|| format!("bad --variant {s}"))?;
    }
    if let Some(s) = a.get("strategy") {
        cfg.strategy = Strategy::parse(s).with_context(|| format!("bad --strategy {s}"))?;
    }
    if let Some(s) = a.get("cpu-kernel") {
        cfg.cpu_kernel =
            KernelPolicy::parse(s).with_context(|| format!("bad --cpu-kernel {s}"))?;
    }
    cfg.artifact_dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    cfg.threads = a.get_parse("threads", cfg.threads).map_err(anyhow::Error::msg)?;
    cfg.backend = match a.get("backend") {
        Some(s) => Backend::parse(s).with_context(|| format!("bad --backend {s}"))?,
        // --threads only means something on the Hogwild engine, so it
        // overrides the artifact-based auto-selection
        None if cfg.threads > 0 => Backend::ParallelCpu,
        None => cfg.auto_backend(),
    };
    cfg.workers = a.get_parse("workers", cfg.workers).map_err(anyhow::Error::msg)?;
    if cfg.workers > 0 && a.get("backend").is_none() && a.get("threads").is_none() {
        // sharded workers are CPU-side; don't auto-select hlo under them
        cfg.backend = Backend::ParallelCpu;
    }
    cfg.j = a.get_parse("j", cfg.j).map_err(anyhow::Error::msg)?;
    cfg.r = a.get_parse("r", cfg.r).map_err(anyhow::Error::msg)?;
    cfg.seed = a.get_parse("seed", cfg.seed).map_err(anyhow::Error::msg)?;
    cfg.hyper.lr_a = a.get_parse("lr-a", cfg.hyper.lr_a).map_err(anyhow::Error::msg)?;
    cfg.hyper.lr_b = a.get_parse("lr-b", cfg.hyper.lr_b).map_err(anyhow::Error::msg)?;
    cfg.hyper.lam_a = a.get_parse("lam-a", cfg.hyper.lam_a).map_err(anyhow::Error::msg)?;
    cfg.hyper.lam_b = a.get_parse("lam-b", cfg.hyper.lam_b).map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

/// The full `train` spec from flags: data source + config + schedule.
/// `--store FILE.ftb2` selects the out-of-core paged path (no held-out
/// split, so `--test-frac` defaults to 0 there).
fn train_spec_from_flags(a: &Args) -> Result<RunSpec> {
    ensure!(
        usize::from(a.get_bool("toy"))
            + usize::from(a.get("data").is_some())
            + usize::from(a.get("store").is_some())
            <= 1,
        "--toy, --data and --store are mutually exclusive ways to pick the tensor"
    );
    let data = if a.get_bool("toy") {
        DataSource::Toy
    } else if let Some(path) = a.get("store") {
        DataSource::Store(PathBuf::from(path))
    } else {
        let path = a
            .get("data")
            .context("--data FILE, --store FILE.ftb2 or --toy required")?;
        DataSource::File(PathBuf::from(path))
    };
    let early_stop = match a.get("early-stop") {
        None => None,
        Some(_) => Some(EarlyStop {
            patience: a.get_parse("early-stop", 3).map_err(anyhow::Error::msg)?,
            min_delta: a.get_parse("min-delta", 1e-4).map_err(anyhow::Error::msg)?,
        }),
    };
    let lr_decay = match a.get("lr-decay") {
        None => None,
        Some(_) => Some(a.get_parse("lr-decay", 1.0f32).map_err(anyhow::Error::msg)?),
    };
    // paged stores have no in-RAM split, so their split defaults off
    let frac_default = if matches!(data, DataSource::Store(_)) { 0.0 } else { 0.2 };
    let test_frac: f64 = a
        .get_parse("test-frac", frac_default)
        .map_err(anyhow::Error::msg)?;
    // --test-frac 0 means "train on everything": without a held-out
    // split there is nothing to evaluate, so the cadence defaults off
    let eval_default = if test_frac == 0.0 { 0 } else { 1 };
    let schedule = Schedule {
        epochs: a.get_parse("epochs", 10).map_err(anyhow::Error::msg)?,
        eval_every: a.get_parse("eval-every", eval_default).map_err(anyhow::Error::msg)?,
        test_frac,
        early_stop,
        lr_decay,
        checkpoint_every: a.get_parse("checkpoint-every", 0).map_err(anyhow::Error::msg)?,
        checkpoint: a.get("checkpoint").map(PathBuf::from),
        publish_every: 0,
    };
    Ok(RunSpec {
        data,
        train: train_config_from_flags(a)?,
        schedule,
        metrics: a.get("metrics").map(PathBuf::from),
    })
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "data", "store", "algo", "variant", "strategy", "backend", "threads", "workers",
            "cpu-kernel", "epochs", "j", "r", "lr-a", "lr-b", "lam-a", "lam-b", "test-frac",
            "seed", "artifacts", "save", "checkpoint", "checkpoint-every", "eval-every",
            "early-stop", "min-delta", "lr-decay", "toy", "spec", "dump-spec", "metrics",
            "coordinator", "join", "timeout-ms",
        ],
        &["toy", "dump-spec"],
    )
    .map_err(anyhow::Error::msg)?;

    // `--join ADDR` turns this process into a TCP worker: all training
    // config arrives in the coordinator's welcome frame, so the only
    // flags that matter are --store (local data override), --timeout-ms
    // and the address itself
    if let Some(addr) = a.get("join") {
        let opts = dist::JoinOpts {
            store: a.get("store").map(PathBuf::from),
            timeout: Some(Duration::from_millis(
                a.get_parse("timeout-ms", 30_000u64).map_err(anyhow::Error::msg)?,
            )),
            fault: None,
        };
        println!("joining coordinator at {addr}");
        let summary = dist::run_worker(addr, &opts)?;
        println!(
            "worker {} finished: {} rounds trained",
            summary.member, summary.rounds
        );
        return Ok(());
    }

    let spec = match a.get("spec") {
        Some(path) => {
            let mut s = RunSpec::load(Path::new(path))?;
            // telemetry is observational, so the flag still applies on
            // top of a replayed spec without breaking bit-identity
            if let Some(p) = a.get("metrics") {
                s.metrics = Some(PathBuf::from(p));
            }
            s
        }
        None => train_spec_from_flags(&a)?,
    };
    if a.get_bool("dump-spec") {
        println!("{}", spec.dump());
        return Ok(());
    }

    // `--coordinator LISTEN` binds a TCP listener and waits for
    // --workers N worker processes (`train --join LISTEN`) instead of
    // spawning in-process threads; everything downstream of the wire is
    // the same distributed driver
    if let Some(listen) = a.get("coordinator") {
        spec.validate().map_err(anyhow::Error::msg)?;
        ensure!(
            spec.train.workers > 0,
            "--coordinator needs --workers N (the quorum of joining processes)"
        );
        println!(
            "data {} | algo {} backend {} | coordinator on {listen}, waiting for {} workers",
            spec.data.describe(),
            spec.train.algo.name(),
            spec.train.backend.name(),
            spec.train.workers
        );
        let run = dist::run_coordinator(&spec, listen, &mut ProgressPrinter)?;
        return finish_dist_run(run, &spec, &a);
    }

    // --workers N routes through the distributed driver instead of a
    // serial session: N in-process workers over disjoint section ranges
    // with barrier averaging (see ARCHITECTURE.md §The distributed layer)
    if spec.train.workers > 0 {
        spec.validate().map_err(anyhow::Error::msg)?;
        println!(
            "data {} | algo {} backend {} | {} sharded workers",
            spec.data.describe(),
            spec.train.algo.name(),
            spec.train.backend.name(),
            spec.train.workers
        );
        let run = dist::run_local(&spec, &mut ProgressPrinter)?;
        return finish_dist_run(run, &spec, &a);
    }

    let mut session = Session::from_spec(&spec)?;
    println!(
        "data {} | train nnz {} / test nnz {} | algo {} variant {} backend {}",
        spec.data.describe(),
        session.train_nnz(),
        session.test_tensor().nnz(),
        spec.train.algo.name(),
        spec.train.variant.name(),
        spec.train.backend.name()
    );
    println!("runtime platform: {}", session.platform());
    let report = session.run(&mut ProgressPrinter)?;
    if let Some(path) = &spec.metrics {
        println!("metrics written to {}", path.display());
    }
    if report.stopped_early {
        println!(
            "early stop: test RMSE plateaued after {} epochs (best {:.4})",
            report.epochs_run,
            report.best_rmse.unwrap_or(f64::NAN)
        );
    }
    if let Some(path) = a.get("save") {
        session.trainer().model.save(Path::new(path))?;
        println!("saved model to {path}");
    }
    if let Some(path) = &spec.schedule.checkpoint {
        println!(
            "saved serve checkpoint to {} (epoch {}, algo {})",
            path.display(),
            session.trainer().epoch_no,
            spec.train.algo.name()
        );
    }
    Ok(())
}

/// The common tail of a distributed run (channel or TCP backend): early
/// stop / final-state / metrics reporting and the --save / --checkpoint
/// confirmations — identical to what a serial session prints.
fn finish_dist_run(run: dist::DistRun, spec: &RunSpec, a: &Args) -> Result<()> {
    if run.report.stopped_early {
        println!(
            "early stop: test RMSE plateaued after {} epochs (best {:.4})",
            run.report.epochs_run,
            run.report.best_rmse.unwrap_or(f64::NAN)
        );
    }
    println!("dist: {}", run.final_state);
    if let Some(path) = &spec.metrics {
        println!("metrics + flight tape written to {}", path.display());
    }
    if let Some(path) = a.get("save") {
        run.model.save(Path::new(path))?;
        println!("saved model to {path}");
    }
    if let Some(path) = &spec.schedule.checkpoint {
        println!(
            "saved serve checkpoint to {} (epoch {}, algo {})",
            path.display(),
            run.report.epochs_run,
            spec.train.algo.name()
        );
    }
    Ok(())
}

/// The `serve` training-path spec from flags: synthetic Netflix-like data
/// unless `--data`/`--toy` is given, no held-out split (serving trains on
/// everything), and the checkpoint destination folded into the schedule
/// so the session writes the durable copy itself.  The trainer config
/// comes from the same flag resolver `train` uses.
fn serve_spec_from_flags(a: &Args) -> Result<RunSpec> {
    let data = if a.get_bool("toy") {
        DataSource::Toy
    } else if let Some(d) = a.get("data") {
        DataSource::File(PathBuf::from(d))
    } else {
        DataSource::Synth(SynthSpec {
            preset: SynthPreset::Netflix,
            nnz: a.get_parse("nnz", 60_000).map_err(anyhow::Error::msg)?,
            seed: a.get_parse("seed", 42).map_err(anyhow::Error::msg)?,
            ..SynthSpec::default()
        })
    };
    let schedule = Schedule {
        epochs: a.get_parse("epochs", 5).map_err(anyhow::Error::msg)?,
        eval_every: 0,
        test_frac: 0.0,
        early_stop: None,
        lr_decay: None,
        checkpoint_every: 0,
        checkpoint: a.get("checkpoint").map(PathBuf::from),
        publish_every: 0,
    };
    Ok(RunSpec {
        data,
        train: train_config_from_flags(a)?,
        schedule,
        metrics: a.get("metrics").map(PathBuf::from),
    })
}

/// Train-or-load a serving checkpoint, then answer a burst of batched
/// queries through the threaded serve loop (self-issued — runs offline).
/// With `--checkpoint FILE`: loads it if it exists, otherwise trains
/// (through the session layer) and checkpoints to it first, then serves
/// from the durable copy.
fn cmd_serve(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "checkpoint", "data", "toy", "epochs", "nnz", "algo", "variant", "strategy",
            "backend", "threads", "cpu-kernel", "j", "r", "lr-a", "lr-b", "lam-a", "lam-b",
            "seed", "artifacts", "serve-threads", "batch", "queries", "topk", "mode", "spec",
            "dump-spec", "metrics", "listen", "model", "max-pending", "deadline-ms",
            "cache-fibers", "publish-every",
        ],
        &["toy", "dump-spec"],
    )
    .map_err(anyhow::Error::msg)?;
    let spec = match a.get("spec") {
        Some(path) => {
            let mut s = RunSpec::load(Path::new(path))?;
            // --checkpoint decides load-vs-train for serve, so the flag
            // still applies on top of a spec file; --metrics likewise
            // (telemetry never alters the run it observes)
            if let Some(p) = a.get("checkpoint") {
                s.schedule.checkpoint = Some(PathBuf::from(p));
            }
            if let Some(p) = a.get("metrics") {
                s.metrics = Some(PathBuf::from(p));
            }
            s
        }
        None => serve_spec_from_flags(&a)?,
    };
    if a.get_bool("dump-spec") {
        println!("{}", spec.dump());
        return Ok(());
    }
    // for `serve`, --metrics means serving telemetry: take the path out
    // of the spec so a pre-serve training pass doesn't write (and the
    // post-burst dump then truncate) the same file
    let mut spec = spec;
    let metrics_path = spec.metrics.take();
    if let Some(addr) = a.get("listen") {
        let addr = addr.to_string();
        return cmd_serve_listen(&a, spec, metrics_path, &addr);
    }
    let ckpt = spec.schedule.checkpoint.clone();
    let snap = match &ckpt {
        Some(p) if p.exists() => {
            let s = ModelSnapshot::load(p)?;
            println!(
                "loaded checkpoint {p:?}: dims {:?} J {} R {} algo {} epoch {}",
                s.dims(),
                s.j(),
                s.r(),
                s.algo().name(),
                s.epoch()
            );
            s
        }
        _ => {
            println!(
                "training {} epochs of {} on {} before serving",
                spec.schedule.epochs,
                spec.train.algo.name(),
                spec.data.describe()
            );
            let mut session = Session::from_spec(&spec)?;
            session.run(&mut NullObserver)?;
            match &ckpt {
                // the session wrote the final checkpoint; serve the
                // durable copy so a restart sees the same model
                Some(p) => {
                    println!("checkpointed to {p:?}; serving from the durable copy");
                    ModelSnapshot::load(p)?
                }
                None => session.snapshot(),
            }
        }
    };

    let workers: usize = a.get_parse("serve-threads", 2).map_err(anyhow::Error::msg)?;
    let batch: usize = a.get_parse("batch", 32).map_err(anyhow::Error::msg)?;
    let queries: usize = a.get_parse("queries", 1000).map_err(anyhow::Error::msg)?;
    let k: usize = a.get_parse("topk", 5).map_err(anyhow::Error::msg)?;
    let mode: usize = a
        .get_parse("mode", 1usize.min(snap.order() - 1))
        .map_err(anyhow::Error::msg)?;
    ensure!(mode < snap.order(), "--mode {mode} out of range");
    let seed: u64 = a.get_parse("seed", 42).map_err(anyhow::Error::msg)?;

    let dims = snap.dims().to_vec();
    // serve's bulk scoring honours the same --cpu-kernel tier as training
    let server = Server::start_with_policy(snap, workers, batch, spec.train.cpu_kernel);
    let handle = server.handle();

    // a few demonstration top-K answers first
    let mut rng = Pcg32::new(seed, 0x5E);
    println!("\nsample top-{k} completions over mode {mode}:");
    for _ in 0..3 {
        let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
        let top = handle.topk(coords.clone(), mode, k).map_err(anyhow::Error::msg)?;
        let ranked: Vec<String> = top
            .iter()
            .map(|s| format!("{}:{:.3}", s.index, s.score))
            .collect();
        println!("  fixed {coords:?} -> {}", ranked.join(" "));
    }

    // query burst from concurrent clients (1 top-K per 8 predicts)
    let clients = workers.max(2);
    let per_client = queries.div_ceil(clients);
    let latencies = std::sync::Mutex::new(Vec::with_capacity(clients * per_client));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let handle = handle.clone();
            let dims = &dims;
            let latencies = &latencies;
            scope.spawn(move || {
                let mut rng = Pcg32::new(seed, 0x100 + c as u64);
                let mut local = Vec::with_capacity(per_client);
                for q in 0..per_client {
                    let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
                    let t = Instant::now();
                    let ok = if q % 8 == 7 {
                        handle.topk(coords, mode, k).is_ok()
                    } else {
                        handle.predict(coords).is_ok()
                    };
                    assert!(ok, "query failed");
                    local.push(t.elapsed().as_secs_f64());
                }
                latencies.lock().unwrap().extend(local);
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut lat = latencies.into_inner().unwrap();
    let obs_snap = server.metrics_snapshot();
    let stats = server.shutdown();
    // qps counts only the timed burst (the demo top-Ks above predate t0)
    println!(
        "\nburst: {} requests in {:.3} s ({:.0} qps); server total {} requests, \
         {} batches (mean batch {:.1})",
        lat.len(),
        wall,
        lat.len() as f64 / wall,
        stats.served,
        stats.batches,
        stats.served as f64 / stats.batches.max(1) as f64
    );
    if !lat.is_empty() {
        println!(
            "latency p50 {:.1} µs  p99 {:.1} µs",
            percentile(&mut lat, 50.0) * 1e6,
            percentile(&mut lat, 99.0) * 1e6
        );
    }
    if let Some(path) = &metrics_path {
        let mut mf = MetricsFile::create(path)
            .with_context(|| format!("creating metrics file {path:?}"))?;
        mf.write_snapshot("serve", &obs_snap)?;
        println!("\nserve metrics -> {}", path.display());
        print!("{}", render_text(&obs_snap));
    }
    Ok(())
}

/// Set by SIGTERM / SIGINT; the `serve --listen` loop polls it and turns
/// the signal into a graceful drain.
static TERM_SIGNAL: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_term_handler() {
    extern "C" fn on_term(_sig: i32) {
        TERM_SIGNAL.store(true, Ordering::SeqCst);
    }
    // libc is not in the offline crate set; `signal` comes straight from
    // the platform C library
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler: extern "C" fn(i32) = on_term;
    // SAFETY: the handler only stores to an atomic (async-signal-safe)
    unsafe {
        signal(15, handler as *const () as usize); // SIGTERM
        signal(2, handler as *const () as usize); // SIGINT
    }
}

#[cfg(not(unix))]
fn install_term_handler() {}

/// `serve --listen`: the network serving tier.  An existing
/// `--checkpoint` is registered and served directly; otherwise training
/// runs *behind the listener*, publishing a fresh active version into the
/// registry every `--publish-every` epochs, so clients query the model as
/// it converges.  Blocks until a drain completes (wire `shutdown` frame,
/// `query --connect .. --shutdown`, or SIGTERM) — every accepted request
/// is answered before exit.
fn cmd_serve_listen(
    a: &Args,
    mut spec: RunSpec,
    metrics_path: Option<PathBuf>,
    addr: &str,
) -> Result<()> {
    let model_name = a.get_or("model", "default").to_string();
    let net_cfg = NetConfig {
        workers: a.get_parse("serve-threads", 2usize).map_err(anyhow::Error::msg)?,
        max_pending: a.get_parse("max-pending", 256usize).map_err(anyhow::Error::msg)?,
        default_deadline_ms: a.get_parse("deadline-ms", 0u64).map_err(anyhow::Error::msg)?,
        policy: spec.train.cpu_kernel,
        cache_fibers: a.get_parse("cache-fibers", 1024usize).map_err(anyhow::Error::msg)?,
        ..NetConfig::default()
    };
    spec.schedule.publish_every = a
        .get_parse("publish-every", 1usize)
        .map_err(anyhow::Error::msg)?;

    let registry = Registry::shared();
    let ckpt = spec.schedule.checkpoint.clone();
    let mut pending_train: Option<Session> = None;
    match &ckpt {
        Some(p) if p.exists() => {
            let snap = ModelSnapshot::load(p)?;
            println!(
                "loaded checkpoint {p:?}: dims {:?} J {} R {} algo {} epoch {}",
                snap.dims(),
                snap.j(),
                snap.r(),
                snap.algo().name(),
                snap.epoch()
            );
            registry.insert(&model_name, snap);
        }
        _ => {
            let session = Session::from_spec(&spec)?;
            // version 1 is the initial model, so queries are answerable
            // from the first accepted connection; training below
            // publishes fresher versions as it goes
            registry.insert(&model_name, session.snapshot());
            pending_train = Some(session);
        }
    }

    let server = NetServer::bind(addr, registry.clone(), net_cfg)?;
    install_term_handler();
    println!(
        "listening on {} — model {:?}, {} workers, max-pending {}, default deadline {} ms",
        server.local_addr(),
        model_name,
        net_cfg.workers,
        net_cfg.max_pending,
        net_cfg.default_deadline_ms
    );
    println!("(drain with `fasttucker query --connect ADDR --shutdown` or SIGTERM)");

    if let Some(mut session) = pending_train {
        println!(
            "training {} epochs of {} on {} behind the listener (publish every {})",
            spec.schedule.epochs,
            spec.train.algo.name(),
            spec.data.describe(),
            spec.schedule.publish_every
        );
        session.run_with_registry(&registry, &model_name, &mut ProgressPrinter)?;
        // make sure the final model serves even when the cadence didn't
        // land on the last epoch
        if spec.schedule.publish_every == 0
            || spec.schedule.epochs % spec.schedule.publish_every != 0
        {
            registry.publish(&model_name, session.snapshot());
        }
        println!("training done; serving the final model");
    }

    while !server.drained() {
        if TERM_SIGNAL.load(Ordering::SeqCst) {
            server.handle().stop();
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let obs_snap = server.metrics_snapshot();
    let stats = server.shutdown();
    println!(
        "drained: {} connections, {} frames, {} requests answered, {} shed, \
         {} deadline-missed, {} errors",
        stats.connections,
        stats.frames,
        stats.requests,
        stats.shed,
        stats.deadline_missed,
        stats.errors
    );
    if let Some(path) = &metrics_path {
        let mut mf = MetricsFile::create(path)
            .with_context(|| format!("creating metrics file {path:?}"))?;
        mf.write_snapshot("serve.net", &obs_snap)?;
        println!("serve metrics -> {}", path.display());
        print!("{}", render_text(&obs_snap));
    }
    Ok(())
}

/// One-shot query against a checkpoint: predict an entry, or top-K
/// completion over `--mode` when given.
fn cmd_query(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "checkpoint", "coords", "mode", "topk", "cpu-kernel", "connect", "model",
            "deadline-ms", "timeout-ms", "stats", "epoch", "shutdown",
        ],
        &["stats", "epoch", "shutdown"],
    )
    .map_err(anyhow::Error::msg)?;
    if let Some(addr) = a.get("connect") {
        let addr = addr.to_string();
        return query_over_wire(&a, &addr);
    }
    ensure!(
        !a.get_bool("stats") && !a.get_bool("epoch") && !a.get_bool("shutdown"),
        "--stats / --epoch / --shutdown query a live server: add --connect HOST:PORT"
    );
    let path = PathBuf::from(a.get("checkpoint").context("--checkpoint FILE required")?);
    let snap = ModelSnapshot::load(&path)?;
    let coords = parse_u32_list(a.get("coords").context("--coords I1,I2,... required")?)
        .map_err(anyhow::Error::msg)?;
    let free_mode = match a.get("mode") {
        Some(_) => {
            let mode: usize = a.get_parse("mode", 0).map_err(anyhow::Error::msg)?;
            ensure!(mode < snap.order(), "--mode {mode} out of range");
            Some(mode)
        }
        None => None,
    };
    // same validation the serving workers apply (arity + bounds, free
    // mode exempt)
    check_coords(&snap, &coords, free_mode).map_err(anyhow::Error::msg)?;
    let policy = match a.get("cpu-kernel") {
        Some(s) => KernelPolicy::parse(s).with_context(|| format!("bad --cpu-kernel {s}"))?,
        None => KernelPolicy::Tiled,
    };
    let mut engine = Engine::with_policy(snap, policy);
    match free_mode {
        Some(mode) => {
            let k: usize = a.get_parse("topk", 10).map_err(anyhow::Error::msg)?;
            for s in mode_topk(&mut engine, &coords, mode, k) {
                println!("{:>8}  {:.6}", s.index, s.score);
            }
        }
        None => println!("{:.6}", engine.predict(&coords)),
    }
    Ok(())
}

/// Open a [`NetClient`] honoring `--timeout-ms` (socket read/write bound;
/// default 30 s — see `serve::net::client::DEFAULT_TIMEOUT`).
fn connect_client(a: &Args, addr: &str) -> Result<NetClient> {
    match a.get("timeout-ms") {
        Some(_) => {
            let ms: u64 = a.get_parse("timeout-ms", 30_000).map_err(anyhow::Error::msg)?;
            NetClient::connect_with_timeout(addr, Some(Duration::from_millis(ms)))
        }
        None => NetClient::connect(addr),
    }
}

/// The `query --connect` path: the same predict / top-K / epoch shapes as
/// the checkpoint path (identical output formats), plus `--stats` (remote
/// telemetry) and `--shutdown` (graceful drain), over the wire protocol.
fn query_over_wire(a: &Args, addr: &str) -> Result<()> {
    let mut client = connect_client(a, addr)?;
    let model = a.get("model");
    let deadline_ms = match a.get("deadline-ms") {
        Some(_) => Some(a.get_parse("deadline-ms", 0u64).map_err(anyhow::Error::msg)?),
        None => None,
    };
    if a.get_bool("shutdown") {
        client.shutdown()?;
        println!("server is draining");
        return Ok(());
    }
    if a.get_bool("stats") {
        match client.call(model, deadline_ms, Request::Stats)? {
            Response::Stats(snap) => print!("{}", render_text(&snap)),
            other => bail!("unexpected reply {other:?}"),
        }
        return Ok(());
    }
    if a.get_bool("epoch") {
        match client.call(model, deadline_ms, Request::Epoch)? {
            Response::Epoch(e) => println!("{e}"),
            other => bail!("unexpected reply {other:?}"),
        }
        return Ok(());
    }
    let coords = parse_u32_list(
        a.get("coords")
            .context("--coords I1,I2,... required (or --stats / --epoch / --shutdown)")?,
    )
    .map_err(anyhow::Error::msg)?;
    let resp = match a.get("mode") {
        Some(_) => {
            let mode: usize = a.get_parse("mode", 0).map_err(anyhow::Error::msg)?;
            let k: usize = a.get_parse("topk", 10).map_err(anyhow::Error::msg)?;
            client.call(model, deadline_ms, Request::TopK { coords, mode, k })?
        }
        None => client.call(model, deadline_ms, Request::Predict { coords })?,
    };
    match resp {
        Response::Predict(v) => println!("{v:.6}"),
        Response::TopK(top) => {
            for sc in top {
                println!("{:>8}  {:.6}", sc.index, sc.score);
            }
        }
        Response::Overloaded => bail!("server overloaded: request shed by admission control"),
        Response::DeadlineExceeded => bail!("deadline expired before a worker reached the request"),
        Response::Error(e) => bail!("{e}"),
        other => bail!("unexpected reply {other:?}"),
    }
    Ok(())
}

/// Registry admin over the wire: `list`, `promote`, `rollback`, `load`.
/// Every op prints the resulting registry table (the server answers admin
/// ops with the post-op listing).
fn cmd_registry(argv: Vec<String>) -> Result<()> {
    let Some((sub, rest)) = argv.split_first() else {
        bail!(
            "usage: registry <list|promote|rollback|load> --connect HOST:PORT \
             [--model NAME] [--version V] [--path FILE.ftck]"
        );
    };
    let a = Args::parse(
        rest.to_vec(),
        &["connect", "model", "version", "path", "timeout-ms"],
        &[],
    )
    .map_err(anyhow::Error::msg)?;
    let addr = a.get("connect").context("--connect HOST:PORT required")?;
    let mut client = connect_client(&a, addr)?;
    let model = || a.get("model").context("--model NAME required");
    let models = match sub.as_str() {
        "list" => client.list()?,
        "promote" => {
            let version = match a.get("version") {
                Some(_) => Some(a.get_parse("version", 0u64).map_err(anyhow::Error::msg)?),
                None => None,
            };
            client.promote(model()?, version)?
        }
        "rollback" => client.rollback(model()?)?,
        "load" => client.load(model()?, a.get("path").context("--path FILE.ftck required")?)?,
        other => bail!("unknown registry subcommand {other:?} (list|promote|rollback|load)"),
    };
    println!(
        "{:<16} {:>8} {:>8} {:>9} {:>7} {:>8} {:>12}  dims",
        "model", "active", "prev", "versions", "default", "epoch", "params"
    );
    for m in models {
        println!(
            "{:<16} {:>8} {:>8} {:>9} {:>7} {:>8} {:>12}  {:?}",
            m.name,
            m.active,
            m.previous.map_or_else(|| "-".to_string(), |v| v.to_string()),
            m.versions.len(),
            if m.is_default { "yes" } else { "no" },
            m.epoch,
            m.params,
            m.dims
        );
    }
    Ok(())
}

/// The closed-loop SLO harness against a live server: walk the offered-QPS
/// ladder, print the SLO table, and optionally write the
/// `BENCH_serve_slo.json` row format with `--json FILE`.
fn cmd_slo(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(
        argv,
        &[
            "connect", "model", "connections", "qps", "step-secs", "deadline-ms", "topk-every",
            "mode", "k", "seed", "json",
        ],
        &[],
    )
    .map_err(anyhow::Error::msg)?;
    let addr = a.get("connect").context("--connect HOST:PORT required")?;
    let steps: Vec<u64> = match a.get("qps") {
        Some(list) => parse_u32_list(list)
            .map_err(anyhow::Error::msg)?
            .into_iter()
            .map(u64::from)
            .collect(),
        None => vec![200, 800, 3200],
    };
    let deadline_ms = match a.get("deadline-ms") {
        Some(_) => Some(a.get_parse("deadline-ms", 0u64).map_err(anyhow::Error::msg)?),
        None => None,
    };
    let cfg = SloConfig {
        addr: addr.to_string(),
        model: a.get("model").map(str::to_string),
        connections: a.get_parse("connections", 4usize).map_err(anyhow::Error::msg)?,
        steps,
        step_duration: Duration::from_secs_f64(
            a.get_parse("step-secs", 2.0f64).map_err(anyhow::Error::msg)?,
        ),
        deadline_ms,
        topk_every: a.get_parse("topk-every", 8usize).map_err(anyhow::Error::msg)?,
        mode: a.get_parse("mode", 0usize).map_err(anyhow::Error::msg)?,
        k: a.get_parse("k", 10usize).map_err(anyhow::Error::msg)?,
        seed: a.get_parse("seed", 42u64).map_err(anyhow::Error::msg)?,
    };
    println!(
        "slo: {} connections, steps {:?} qps, {}s per step",
        cfg.connections,
        cfg.steps,
        cfg.step_duration.as_secs_f64()
    );
    let rows = run_slo(&cfg)?;
    println!("{}", slo_header());
    for row in &rows {
        println!("{}", row.render());
    }
    if let Some(path) = a.get("json") {
        let doc = json::obj(vec![
            ("bench", json::s("serve_slo")),
            ("status", json::s("measured")),
            (
                "rows",
                json::arr(rows.iter().map(SloRow::to_json).collect()),
            ),
        ]);
        std::fs::write(path, doc.dump() + "\n")
            .with_context(|| format!("writing {path:?}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Convert an FTM1 model into a serve checkpoint (`save`), or validate and
/// describe an existing checkpoint (`load`).
fn cmd_checkpoint(argv: Vec<String>) -> Result<()> {
    let Some((sub, rest)) = argv.split_first() else {
        bail!("usage: checkpoint <save|load> [flags]");
    };
    match sub.as_str() {
        "save" => {
            let a = Args::parse(rest.to_vec(), &["model", "out", "algo", "epoch"], &[])
                .map_err(anyhow::Error::msg)?;
            let model = TuckerModel::load(Path::new(
                a.get("model").context("--model FILE (FTM1) required")?,
            ))?;
            let out = PathBuf::from(a.get("out").context("--out FILE required")?);
            let algo = match a.get("algo") {
                Some(s) => Algo::parse(s).with_context(|| format!("bad --algo {s}"))?,
                None => Algo::Plus,
            };
            let epoch: u64 = a.get_parse("epoch", 0).map_err(anyhow::Error::msg)?;
            let snap = ModelSnapshot::from_model(&model, algo, epoch);
            snap.save(&out)?;
            println!(
                "wrote {out:?}: dims {:?} J {} R {} algo {} epoch {} ({} params)",
                snap.dims(),
                snap.j(),
                snap.r(),
                algo.name(),
                epoch,
                snap.param_count()
            );
        }
        "load" => {
            let a = Args::parse(rest.to_vec(), &["file", "model-out"], &[])
                .map_err(anyhow::Error::msg)?;
            let path = PathBuf::from(a.get("file").context("--file FILE required")?);
            let snap = ModelSnapshot::load(&path)?;
            println!(
                "{path:?}: checksum ok; dims {:?} J {} R {} algo {} epoch {} ({} params)",
                snap.dims(),
                snap.j(),
                snap.r(),
                snap.algo().name(),
                snap.epoch(),
                snap.param_count()
            );
            if let Some(out) = a.get("model-out") {
                snap.to_model().save(Path::new(out))?;
                println!("wrote FTM1 model to {out}");
            }
        }
        other => bail!("unknown checkpoint subcommand {other:?} (save|load)"),
    }
    Ok(())
}

fn cmd_cost(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["order", "j", "r", "m", "nnz"], &[]).map_err(anyhow::Error::msg)?;
    let shape = cost::Shape {
        n: a.get_parse("order", 3usize).map_err(anyhow::Error::msg)?,
        j: a.get_parse("j", 16usize).map_err(anyhow::Error::msg)?,
        r: a.get_parse("r", 16usize).map_err(anyhow::Error::msg)?,
        m: a.get_parse("m", 16usize).map_err(anyhow::Error::msg)?,
    };
    let nnz: usize = a.get_parse("nnz", 1_000_000).map_err(anyhow::Error::msg)?;
    println!(
        "Table 4 cost model (N={} J={} R={} M={}, |Ω|={nnz}):",
        shape.n, shape.j, shape.r, shape.m
    );
    println!(
        "{:<16} {:>14} {:>14} {:>14} {:>12} {:>10}",
        "algorithm", "params read", "D-chain muls", "B·D muls", "written", "MXU frac"
    );
    for algo in [
        cost::Algo::FastTucker,
        cost::Algo::FasterTucker,
        cost::Algo::FastTuckerPlus,
    ] {
        println!(
            "{:<16} {:>14} {:>14} {:>14} {:>12} {:>10.2}",
            algo.name(),
            cost::params_read(algo, shape),
            cost::d_chain_muls(algo, shape),
            cost::bd_muls(algo, shape),
            cost::params_written(algo, shape),
            cost::mxu_fraction(algo, shape),
        );
    }
    println!("\nper-pass estimates over |Ω| (bandwidth-scaled):");
    let bw = fasttucker::bench::measure_bandwidth();
    println!("measured host bandwidth: {:.2} GB/s", bw / 1e9);
    for algo in [
        cost::Algo::FastTucker,
        cost::Algo::FasterTucker,
        cost::Algo::FastTuckerPlus,
    ] {
        println!(
            "{:<16} memory {:>10}  flops {:.3e}",
            algo.name(),
            fasttucker::bench::fmt_secs(cost::memory_time_s(algo, shape, nnz, bw)),
            cost::flops_per_pass(algo, shape, nnz),
        );
    }
    Ok(())
}

/// Raw executable microbenchmark: `fasttucker profile --name <artifact>`
/// times `execute` with synthetic inputs, isolating PJRT/XLA cost from the
/// coordinator (gather/scatter/sampling).  The L2 §Perf numbers in
/// EXPERIMENTS.md come from this.
fn cmd_profile(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["artifacts", "name", "reps"], &[]).map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let engine = fasttucker::runtime::Engine::new(&dir)?;
    let reps: usize = a.get_parse("reps", 50).map_err(anyhow::Error::msg)?;
    let names: Vec<String> = match a.get("name") {
        Some(n) => n.split(',').map(|s| s.to_string()).collect(),
        None => engine.manifest().iter().map(|i| i.name.clone()).collect(),
    };
    for name in names {
        let exe = engine.load_named(&name)?;
        let inputs: Vec<Vec<f32>> = exe
            .info
            .inputs
            .iter()
            .map(|shape| vec![0.1f32; shape.iter().product()])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let row = fasttucker::bench::measure(&name, 3, reps, || {
            exe.run(&refs).expect("execute");
            0.0
        });
        println!(
            "{:<44} {:>12} (mad {})",
            row.label,
            fasttucker::bench::fmt_secs(row.median_s),
            fasttucker::bench::fmt_secs(row.mad_s)
        );
    }
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> Result<()> {
    let a = Args::parse(argv, &["artifacts"], &[]).map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let engine = fasttucker::runtime::Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts in {dir:?}: {}", engine.manifest().len());
    let mut kernels: Vec<&str> = engine.manifest().iter().map(|a| a.kernel.as_str()).collect();
    kernels.sort_unstable();
    kernels.dedup();
    for k in kernels {
        let configs: Vec<String> = engine
            .manifest()
            .iter()
            .filter(|a| a.kernel == k)
            .map(|a| format!("n{}j{}r{}s{}", a.n, a.j, a.r, a.s))
            .collect();
        println!("  {k}: {}", configs.join(" "));
    }
    Ok(())
}
