//! Synthetic sparse tensors (the paper's §5.1 synthetic datasets, plus
//! scaled surrogates for the license-gated Netflix / Yahoo!Music tensors —
//! see DESIGN.md §3 for why the substitution preserves behaviour).
//!
//! Entries are generated from a planted low-rank FastTucker model
//! (`x = Σ_r Π_n a^(n)·b^(n)_r + noise`) so SGD has a true signal to
//! recover (Fig. 1 convergence analog); coordinates are drawn from
//! per-mode Zipf distributions to reproduce real rating-data skew.

use crate::tensor::SparseTensor;
use crate::util::rng::{Pcg32, Zipf};

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Dimension sizes of the generated tensor.
    pub dims: Vec<u32>,
    /// Entries to draw (realized nnz may be slightly lower after dedup).
    pub nnz: usize,
    /// Planted Kruskal rank of the ground-truth core.
    pub rank: usize,
    /// Planted per-mode factor width (J of the ground truth).
    pub j: usize,
    /// Observation noise stddev.
    pub noise: f32,
    /// Zipf exponent for coordinate skew (0 => uniform).
    pub zipf: f64,
    /// Clamp values into `[min, max]` (rating scale), if set.
    pub clamp: Option<(f32, f32)>,
    /// Generator seed (fully deterministic output).
    pub seed: u64,
}

impl SynthConfig {
    /// Paper §5.1 synthetic family: order-N cubic tensor.  Dim and nnz are
    /// scaled (laptop-class substitute for I=10,000 / |Ω|=1e8).
    pub fn order_sweep(order: usize, dim: u32, nnz: usize, seed: u64) -> Self {
        Self {
            dims: vec![dim; order],
            nnz,
            rank: 4,
            j: 8,
            noise: 0.05,
            zipf: 0.0, // paper's synthetic tensors are uniform
            clamp: Some((1.0, 5.0)),
            seed,
        }
    }

    /// Netflix surrogate: 3-order users x movies x time, 1/100 dims
    /// (vs the real 480189 x 17770 x 2182 with 99M nnz).  The dim scale is
    /// chosen so nnz/row stays in the real data's regime (~10-200 ratings
    /// per user) at laptop-scale nnz — that ratio is what decides the
    /// storage-vs-calculation crossover (§5.6).
    pub fn netflix_like(nnz: usize, seed: u64) -> Self {
        Self {
            dims: vec![4_801, 1_777, 218],
            nnz,
            rank: 8,
            j: 16,
            noise: 0.25,
            zipf: 1.05,
            clamp: Some((1.0, 5.0)),
            seed,
        }
    }

    /// Yahoo!Music surrogate: 1/100 dims of 1000990 x 624961 x 3075
    /// (same regime rationale as [`netflix_like`](Self::netflix_like)).
    pub fn yahoo_like(nnz: usize, seed: u64) -> Self {
        Self {
            dims: vec![10_009, 6_249, 307],
            nnz,
            rank: 8,
            j: 16,
            noise: 0.3,
            zipf: 1.1,
            clamp: Some((0.025, 5.0)),
            seed,
        }
    }
}

/// Generate the tensor.  Duplicated coordinates are deduped (last wins), so
/// the realised nnz may be slightly below `cfg.nnz` for dense configs.
pub fn generate(cfg: &SynthConfig) -> SparseTensor {
    let n = cfg.dims.len();
    let mut rng = Pcg32::new(cfg.seed, 0xDA7A);
    // Planted model parameters.
    let factors: Vec<Vec<f32>> = cfg
        .dims
        .iter()
        .map(|&d| {
            (0..d as usize * cfg.j)
                .map(|_| rng.gen_normal() * (1.0 / (cfg.j as f32).sqrt()) + 0.3)
                .collect()
        })
        .collect();
    let cores: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            (0..cfg.j * cfg.rank)
                .map(|_| rng.gen_normal() * (1.0 / (cfg.rank as f32).sqrt()) + 0.2)
                .collect()
        })
        .collect();
    let zipfs: Vec<Option<Zipf>> = cfg
        .dims
        .iter()
        .map(|&d| {
            if cfg.zipf > 0.0 {
                Some(Zipf::new(d as usize, cfg.zipf))
            } else {
                None
            }
        })
        .collect();

    let mut t = SparseTensor::new(cfg.dims.clone());
    let mut coords = vec![0u32; n];
    let mut perm: Vec<Vec<u32>> = Vec::new();
    // Random per-mode permutation so Zipf "head" ids are scattered.
    for &d in &cfg.dims {
        let mut p: Vec<u32> = (0..d).collect();
        rng.shuffle(&mut p);
        perm.push(p);
    }
    for _ in 0..cfg.nnz {
        for m in 0..n {
            let raw = match &zipfs[m] {
                Some(z) => z.sample(&mut rng) as u32,
                None => rng.gen_range(cfg.dims[m]),
            };
            coords[m] = perm[m][raw as usize];
        }
        // planted value: Σ_r Π_n (a_{i_n,:} · b_{:,r})
        let mut v = 0.0f32;
        for r in 0..cfg.rank {
            let mut p = 1.0f32;
            for m in 0..n {
                let row = &factors[m][coords[m] as usize * cfg.j..(coords[m] as usize + 1) * cfg.j];
                let col = &cores[m];
                let mut dot = 0.0f32;
                for jj in 0..cfg.j {
                    dot += row[jj] * col[jj * cfg.rank + r];
                }
                p *= dot;
            }
            v += p;
        }
        v += rng.gen_normal() * cfg.noise;
        if let Some((lo, hi)) = cfg.clamp {
            v = v.clamp(lo, hi);
        }
        t.push(&coords, v);
    }
    t.sort_dedup();
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shape() {
        let cfg = SynthConfig::order_sweep(3, 64, 2000, 1);
        let t = generate(&cfg);
        assert_eq!(t.dims, vec![64, 64, 64]);
        assert!(t.nnz() > 1800); // some dedup loss allowed
        t.validate().unwrap();
    }

    #[test]
    fn deterministic() {
        let cfg = SynthConfig::order_sweep(4, 32, 500, 9);
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.values, b.values);
    }

    #[test]
    fn clamped_values() {
        let cfg = SynthConfig::netflix_like(5000, 3);
        let t = generate(&cfg);
        assert!(t.values.iter().all(|&v| (1.0..=5.0).contains(&v)));
    }

    #[test]
    fn zipf_skews_mode_popularity() {
        let mut cfg = SynthConfig::netflix_like(20_000, 5);
        cfg.dims = vec![2000, 500, 100];
        let t = generate(&cfg);
        let idx = crate::tensor::ModeSliceIndex::build(&t, 0);
        assert!(idx.imbalance() > 2.0, "imbalance {}", idx.imbalance());
    }

    #[test]
    fn higher_orders() {
        for order in [5, 8] {
            let cfg = SynthConfig::order_sweep(order, 16, 300, 2);
            let t = generate(&cfg);
            assert_eq!(t.order(), order);
            t.validate().unwrap();
        }
    }
}
