//! Serving-subsystem acceptance suite.
//!
//! Pins the four load-bearing guarantees of `serve::`:
//!
//! 1. checkpoints roundtrip losslessly (bit-identical model, byte-identical
//!    re-save) across random shapes, and corruption is detected;
//! 2. `Engine::predict` is bit-identical to the trainer's evaluation path
//!    on the same snapshot (exact f64 equality of RMSE/MAE);
//! 3. top-K mode completion agrees with a brute-force scalar scorer;
//! 4. hot-swapping snapshots under live queries never exposes a torn
//!    model, and the batched server answers exactly what a direct engine
//!    would.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

use fasttucker::coordinator::{Algo, Backend, Trainer, TrainConfig};
use fasttucker::kernel::KernelPolicy;
use fasttucker::model::TuckerModel;
use fasttucker::serve::{mode_topk, Engine, ModelSnapshot, Server};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::split::train_test_split;
use fasttucker::util::rng::Pcg32;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ft_serve_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Property: over random (order, dims, J, R, algo, epoch), a checkpoint
/// save → load roundtrip is bit-identical, and save → load → save produces
/// identical bytes.
#[test]
fn checkpoint_roundtrip_property() {
    let algos = [
        Algo::FastTucker,
        Algo::FasterTucker,
        Algo::FasterTuckerCoo,
        Algo::Plus,
    ];
    let mut rng = Pcg32::new(2024, 0xC4E);
    for case in 0..12u64 {
        let order = 2 + rng.gen_index(3); // 2..=4
        let dims: Vec<u32> = (0..order).map(|_| 3 + rng.gen_range(30)).collect();
        let j = 16 * (1 + rng.gen_index(2)); // 16 or 32
        let r = 16 * (1 + rng.gen_index(2));
        let algo = algos[rng.gen_index(algos.len())];
        let epoch = rng.next_u64() % 1000;
        let model = TuckerModel::init(&dims, j, r, 0xF00D + case);
        let snap = ModelSnapshot::from_model(&model, algo, epoch);

        let p1 = tmp(&format!("prop_{case}_a.ftc"));
        let p2 = tmp(&format!("prop_{case}_b.ftc"));
        snap.save(&p1).unwrap();
        let back = ModelSnapshot::load(&p1).unwrap();

        // bit-identical payload and header
        assert_eq!(back.dims(), &dims[..], "case {case}");
        assert_eq!(back.j(), j);
        assert_eq!(back.r(), r);
        assert_eq!(back.algo(), algo);
        assert_eq!(back.epoch(), epoch);
        let m2 = back.to_model();
        assert_eq!(m2.factors, model.factors, "case {case} factors diverged");
        assert_eq!(m2.cores, model.cores, "case {case} cores diverged");

        // save -> load -> save: identical bytes
        back.save(&p2).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "case {case} re-save not byte-identical"
        );
    }
}

#[test]
fn checkpoint_corruption_detected_on_disk() {
    let model = TuckerModel::init(&[12, 9, 7], 16, 16, 5);
    let snap = ModelSnapshot::from_model(&model, Algo::Plus, 3);
    let p = tmp("corrupt.ftc");
    snap.save(&p).unwrap();
    let good = std::fs::read(&p).unwrap();
    // flip one byte at a stride of positions across header and payload
    for at in (0..good.len()).step_by(good.len() / 7) {
        let mut bad = good.clone();
        bad[at] ^= 0x10;
        std::fs::write(&p, &bad).unwrap();
        assert!(
            ModelSnapshot::load(&p).is_err(),
            "byte flip at {at} loaded successfully"
        );
    }
    // truncation
    std::fs::write(&p, &good[..good.len() / 2]).unwrap();
    assert!(ModelSnapshot::load(&p).is_err());
    // restore and confirm the detector passes clean data
    std::fs::write(&p, &good).unwrap();
    assert!(ModelSnapshot::load(&p).is_ok());
}

/// `Engine::predict` must be bit-identical to the trainer's evaluation
/// path on the same snapshot: exact f64 equality of (RMSE, MAE) implies
/// exact f32 equality of every per-entry prediction (the sums are order-
/// and bit-sensitive), and per-entry spot checks pin it directly.
#[test]
fn engine_predict_bit_identical_to_trainer() {
    let t = generate(&SynthConfig::order_sweep(3, 40, 4000, 17));
    let (train, test) = train_test_split(&t, 0.25, 3);
    for kernel in [KernelPolicy::Tiled, KernelPolicy::Scalar] {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::CpuRef;
        cfg.cpu_kernel = kernel;
        let mut trainer = Trainer::new(&train, cfg).unwrap();
        for _ in 0..3 {
            trainer.epoch(&train).unwrap();
        }
        let (rmse, mae) = trainer.evaluate(&test).unwrap();
        let engine = Engine::new(trainer.snapshot());
        let (srmse, smae) = engine.rmse_mae(&test);
        assert_eq!(rmse, srmse, "serve RMSE diverged from trainer ({kernel:?})");
        assert_eq!(mae, smae, "serve MAE diverged from trainer ({kernel:?})");
        for e in (0..test.nnz()).step_by(97) {
            let c = test.coords(e);
            assert_eq!(
                engine.predict(c),
                trainer.model.predict_one(c),
                "entry {e} prediction diverged"
            );
        }
    }
}

/// Checkpoints preserve serving behavior exactly: predictions from a
/// revived snapshot equal predictions from the live one.
#[test]
fn revived_checkpoint_serves_identically() {
    let t = generate(&SynthConfig::netflix_like(8_000, 9));
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::ParallelCpu;
    cfg.threads = 2;
    let mut trainer = Trainer::new(&t, cfg).unwrap();
    for _ in 0..2 {
        trainer.epoch(&t).unwrap();
    }
    let live = Engine::new(trainer.snapshot());
    let p = tmp("revive.ftc");
    trainer.snapshot().save(&p).unwrap();
    let revived = Engine::new(ModelSnapshot::load(&p).unwrap());
    for e in (0..t.nnz()).step_by(131) {
        let c = t.coords(e);
        assert_eq!(live.predict(c), revived.predict(c));
    }
}

/// Top-K mode completion agrees with a brute-force scalar scorer that
/// recomputes the exclusion product per candidate from the raw factors.
#[test]
fn topk_matches_bruteforce_scalar_scorer() {
    let model = TuckerModel::init(&[23, 57, 11], 16, 16, 99);
    let snap = ModelSnapshot::from_model(&model, Algo::Plus, 0);
    let mut engine = Engine::new(snap.clone());
    let (n, r) = (3usize, 16usize);
    for (coords, mode) in [
        ([5u32, 0, 3], 1usize),
        ([0, 12, 9], 0),
        ([22, 56, 0], 2),
        ([7, 7, 7], 1),
    ] {
        let k = 9;
        let got = mode_topk(&mut engine, &coords, mode, k);

        // brute force: score every candidate independently, full sort
        let cands = model.dims[mode] as usize;
        let mut scores = Vec::with_capacity(cands);
        for i in 0..cands {
            // exclusion product from stored projections, ascending modes
            let mut d = vec![1f32; r];
            for m in 0..n {
                if m == mode {
                    continue;
                }
                let crow = snap.c_row(m, coords[m] as usize);
                for rr in 0..r {
                    d[rr] *= crow[rr];
                }
            }
            let crow = snap.c_row(mode, i);
            let mut s = 0f32;
            for rr in 0..r {
                s += crow[rr] * d[rr];
            }
            scores.push(s);
        }
        let mut order: Vec<u32> = (0..cands as u32).collect();
        order.sort_by(|a, b| {
            scores[*b as usize]
                .total_cmp(&scores[*a as usize])
                .then_with(|| a.cmp(b))
        });
        assert_eq!(got.len(), k);
        for (rank, s) in got.iter().enumerate() {
            assert_eq!(s.index, order[rank], "rank {rank} index (mode {mode})");
            assert_eq!(
                s.score,
                scores[s.index as usize],
                "rank {rank} score bits (mode {mode})"
            );
        }
    }
}

/// Constant-valued model whose prediction is the same for every coordinate
/// — lets the torn-read test distinguish snapshots by a single scalar.
fn constant_snapshot(a: f32, b: f32, epoch: u64) -> ModelSnapshot {
    let (j, r) = (16usize, 16usize);
    let dims = vec![6u32, 6];
    let model = TuckerModel {
        dims: dims.clone(),
        j,
        r,
        factors: dims.iter().map(|&d| vec![a; d as usize * j]).collect(),
        cores: dims.iter().map(|_| vec![b; j * r]).collect(),
    };
    ModelSnapshot::from_model(&model, Algo::Plus, epoch)
}

/// Queries racing a stream of publishes must only ever see whole models:
/// every response equals exactly one of the two snapshots' predictions.
#[test]
fn hot_swap_never_serves_torn_model() {
    let snap_a = constant_snapshot(0.1, 0.1, 0);
    let snap_b = constant_snapshot(0.2, 0.1, 1);
    let pred_a = Engine::new(snap_a.clone()).predict(&[0, 0]);
    let pred_b = Engine::new(snap_b.clone()).predict(&[0, 0]);
    assert_ne!(pred_a, pred_b);

    let server = Server::start(snap_a.clone(), 3, 4);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        // publisher: flip between the two snapshots as fast as possible
        {
            let server = &server;
            let stop = &stop;
            let (snap_a, snap_b) = (snap_a.clone(), snap_b.clone());
            scope.spawn(move || {
                let mut flip = false;
                while !stop.load(Ordering::Relaxed) {
                    server.publish(if flip { snap_a.clone() } else { snap_b.clone() });
                    flip = !flip;
                    // let reader batches interleave with the write storm
                    std::thread::yield_now();
                }
            });
        }
        // clients: every answer must be exactly pred_a or pred_b
        let mut clients = Vec::new();
        for c in 0..4u32 {
            let handle = server.handle();
            clients.push(scope.spawn(move || {
                let mut seen_a = 0u32;
                let mut seen_b = 0u32;
                for i in 0..500u32 {
                    let coords = vec![(i + c) % 6, i % 6];
                    let v = handle.predict(coords).expect("predict");
                    if v == pred_a {
                        seen_a += 1;
                    } else if v == pred_b {
                        seen_b += 1;
                    } else {
                        panic!("torn model: got {v}, expected {pred_a} or {pred_b}");
                    }
                }
                (seen_a, seen_b)
            }));
        }
        let mut total = (0u32, 0u32);
        for cjoin in clients {
            let (a, b) = cjoin.join().unwrap();
            total.0 += a;
            total.1 += b;
        }
        stop.store(true, Ordering::Relaxed);
        assert_eq!(total.0 + total.1, 2000);
    });
    let stats = server.shutdown();
    assert_eq!(stats.served, 2000);
    assert!(stats.swaps > 0);
}

/// The batched server answers exactly what a direct engine query on the
/// same snapshot answers, across concurrent clients and mixed request
/// types.
#[test]
fn server_batching_matches_direct_engine() {
    let model = TuckerModel::init(&[31, 29, 13], 16, 16, 4242);
    let snap = ModelSnapshot::from_model(&model, Algo::Plus, 8);
    let server = Server::start(snap.clone(), 3, 8);
    std::thread::scope(|scope| {
        for c in 0..4u64 {
            let handle = server.handle();
            let snap = snap.clone();
            scope.spawn(move || {
                let mut engine = Engine::new(snap);
                let dims = engine.snapshot().dims().to_vec();
                let mut rng = Pcg32::new(555, c);
                for i in 0..60 {
                    let coords: Vec<u32> = dims.iter().map(|&d| rng.gen_range(d)).collect();
                    if i % 4 == 3 {
                        let mode = rng.gen_index(3);
                        let got = handle.topk(coords.clone(), mode, 6).expect("topk");
                        let want = mode_topk(&mut engine, &coords, mode, 6);
                        assert_eq!(got, want);
                    } else {
                        let got = handle.predict(coords.clone()).expect("predict");
                        assert_eq!(got, engine.predict(&coords));
                    }
                }
            });
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.served, 240);
    assert_eq!(stats.swaps, 0);
}

/// Publish-before-query ordering: after `Trainer::publish` returns, every
/// subsequent call observes the new epoch.
#[test]
fn publish_is_immediately_visible() {
    let t = generate(&SynthConfig::order_sweep(3, 24, 1500, 5));
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::CpuRef;
    let mut trainer = Trainer::new(&t, cfg).unwrap();
    let server = Server::start(trainer.snapshot(), 2, 4);
    let h = server.handle();
    assert_eq!(h.epoch().unwrap(), 0);
    for want in 1..=3u64 {
        trainer.epoch(&t).unwrap();
        trainer.publish(&server);
        assert_eq!(h.epoch().unwrap(), want);
        // and the served predictions now match the freshly trained model
        let c = t.coords(0);
        assert_eq!(h.predict(c.to_vec()).unwrap(), trainer.model.predict_one(c));
    }
    server.shutdown();
}
