//! Distributed-layer tests: shard-assignment properties (disjoint,
//! covering, balanced, seed-reproducible, join-order invariant), the
//! coordinator's exhaustive (phase, event) tick-table, JSON round-trips
//! of every protocol type, and three end-to-end runs through the
//! in-process backend — 1-worker bit parity with the serial trainer,
//! 4-worker convergence to the serial plateau, and fault injection
//! (a worker killed mid-epoch is evicted and the run still converges).

use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::dist::{
    run_local, run_local_with, shard, Coordinator, CoordinatorState, Directive, DistConfig,
    DistPhase, Event, EventError, FaultSpec, LocalOpts, MemberId, ShardAssignment,
};
use fasttucker::model::TuckerModel;
use fasttucker::session::{
    DataSource, NullObserver, Observer, RunSpec, Schedule, Session, SynthPreset, SynthSpec,
};
use fasttucker::util::json::Json;
use fasttucker::util::rng::Pcg32;

// ======================================================================
// shard assignment properties
// ======================================================================

#[test]
fn assignments_are_disjoint_covering_balanced_and_reproducible() {
    let mut rng = Pcg32::new(0xD157, 99);
    for case in 0..200 {
        let n_sections = 1 + rng.gen_range(64);
        let k = 1 + rng.gen_index(8);
        let mut members: Vec<MemberId> = (0..k).map(|_| rng.next_u64()).collect();
        members.sort_unstable();
        members.dedup();
        let seed = rng.next_u64();
        let round = rng.gen_index(16) as u64;

        let a = shard::assign(seed, round, n_sections, &members);
        assert_eq!(a.round, round);
        assert_eq!(a.n_sections, n_sections);
        assert_eq!(a.shards.len(), members.len(), "case {case}");

        // disjoint + covering: flattening yields 0..n_sections exactly
        let mut seen: Vec<u32> = a.shards.iter().flat_map(|(_, s)| s.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n_sections).collect::<Vec<u32>>(), "case {case}");

        // balanced: shard sizes differ by at most one
        let sizes: Vec<usize> = a.shards.iter().map(|(_, s)| s.len()).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "case {case}: sizes {sizes:?}");

        // reproducible: same inputs, same deal
        assert_eq!(a, shard::assign(seed, round, n_sections, &members), "case {case}");

        // join-order invariant: a shuffled member list deals identically
        let mut shuffled = members.clone();
        rng.shuffle(&mut shuffled);
        assert_eq!(a, shard::assign(seed, round, n_sections, &shuffled), "case {case}");
    }
}

#[test]
fn consecutive_rounds_redeal_the_sections() {
    let members: Vec<MemberId> = vec![1, 2, 3];
    let a = shard::assign(5, 0, 48, &members);
    let b = shard::assign(5, 1, 48, &members);
    assert_ne!(a.shards, b.shards, "rounds must reshuffle the deal");
}

// ======================================================================
// coordinator tick-table
// ======================================================================

fn tick_cfg() -> DistConfig {
    DistConfig {
        min_members: 1,
        warmup_ticks: 2,
        heartbeat_timeout_ticks: 1_000,
        rounds: 3,
        sync_every: 1,
        seed: 7,
        n_sections: 4,
    }
}

/// Tick (bounded) until the coordinator reaches `phase`.
fn tick_to(c: &mut Coordinator, phase: DistPhase) {
    for _ in 0..100 {
        if c.phase() == phase {
            return;
        }
        c.tick();
    }
    panic!("never reached phase {}", phase.name());
}

/// A coordinator with member 1, driven to `phase` along the happy path.
fn drive_to(phase: DistPhase) -> Coordinator {
    let mut c = Coordinator::new(tick_cfg());
    if phase == DistPhase::WaitingForMembers {
        return c;
    }
    c.apply(&Event::Join { member: 1 }).unwrap();
    tick_to(&mut c, DistPhase::Warmup);
    if phase == DistPhase::Warmup {
        return c;
    }
    tick_to(&mut c, DistPhase::Train);
    if phase == DistPhase::Train {
        return c;
    }
    c.apply(&Event::StepComplete { member: 1, round: 0 }).unwrap();
    tick_to(&mut c, DistPhase::Sync);
    if phase == DistPhase::Sync {
        return c;
    }
    c.apply(&Event::Shutdown).unwrap();
    tick_to(&mut c, DistPhase::Done);
    c
}

/// The doc table on `Coordinator::apply`, asserted pair by pair:
///
/// | event          | Waiting | Warmup | Train | Sync | Done |
/// |----------------|---------|--------|-------|------|------|
/// | `Join`         | ok      | err    | err   | err  | err  |
/// | `Heartbeat`    | ok*     | ok*    | ok*   | ok*  | ok*  |
/// | `StepComplete` | err     | err    | ok*†  | err  | err  |
/// | `SyncComplete` | err     | err    | err   | ok†  | err  |
/// | `Shutdown`     | ok      | ok     | ok    | ok   | ok   |
#[test]
fn apply_tick_table_is_exhaustive() {
    for phase in DistPhase::ALL {
        // --- Join: only while waiting for members ----------------------
        let mut c = drive_to(phase);
        let joined = c.apply(&Event::Join { member: 50 });
        if phase == DistPhase::WaitingForMembers {
            joined.unwrap();
        } else {
            assert_eq!(joined, Err(EventError::JoinClosed { member: 50, phase }));
        }

        // --- Heartbeat: known members in every phase -------------------
        let mut c = drive_to(phase);
        if phase == DistPhase::WaitingForMembers {
            c.apply(&Event::Join { member: 1 }).unwrap();
        }
        c.apply(&Event::Heartbeat { member: 1 }).unwrap();
        // ... and a rejected event changes nothing observable
        let before = c.state();
        assert_eq!(
            c.apply(&Event::Heartbeat { member: 99 }),
            Err(EventError::UnknownMember { member: 99 })
        );
        assert_eq!(c.state(), before);

        // --- StepComplete: Train only, current round, known member -----
        let mut c = drive_to(phase);
        let round = c.round();
        let step = c.apply(&Event::StepComplete { member: 1, round });
        if phase == DistPhase::Train {
            step.unwrap();
            let mut c = drive_to(phase);
            assert_eq!(
                c.apply(&Event::StepComplete { member: 1, round: round + 1 }),
                Err(EventError::WrongRound { got: round + 1, want: round })
            );
            assert_eq!(
                c.apply(&Event::StepComplete { member: 99, round }),
                Err(EventError::UnknownMember { member: 99 })
            );
        } else {
            assert_eq!(
                step,
                Err(EventError::WrongPhase { event: "step_complete", phase })
            );
        }

        // --- SyncComplete: Sync only, current round --------------------
        let mut c = drive_to(phase);
        let round = c.round();
        let sync = c.apply(&Event::SyncComplete { round });
        if phase == DistPhase::Sync {
            sync.unwrap();
            assert_eq!(
                c.apply(&Event::SyncComplete { round: round + 1 }),
                Err(EventError::WrongRound { got: round + 1, want: round })
            );
        } else {
            assert_eq!(
                sync,
                Err(EventError::WrongPhase { event: "sync_complete", phase })
            );
        }

        // --- Shutdown: always legal; the next tick finishes the run ----
        let mut c = drive_to(phase);
        c.apply(&Event::Shutdown).unwrap();
        let d = c.tick();
        if phase == DistPhase::Done {
            assert!(d.is_empty(), "Done stays done, got {d:?}");
        } else {
            assert!(d.contains(&Directive::Finish), "phase {}: {d:?}", phase.name());
        }
        assert_eq!(c.phase(), DistPhase::Done);
    }
}

// ======================================================================
// protocol JSON round-trips
// ======================================================================

#[test]
fn every_protocol_type_roundtrips_through_json() {
    // events (all five kinds, including a >2^53 member id)
    for ev in [
        Event::Join { member: 3 },
        Event::Heartbeat { member: u64::MAX },
        Event::StepComplete { member: 1, round: 7 },
        Event::SyncComplete { round: 2 },
        Event::Shutdown,
    ] {
        let text = ev.to_json().dump();
        let back = Event::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, ev, "through {text}");
    }

    // a real shard assignment
    let assignment = shard::assign(42, 3, 9, &[4, 7, 11]);
    let text = assignment.to_json().dump();
    let back = ShardAssignment::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, assignment);
    assert_eq!(assignment.sections_for(12), &[] as &[u32]);

    // directives (all five kinds)
    for d in [
        Directive::EnterWarmup,
        Directive::BeginRound { round: 3, assignment },
        Directive::RunSync {
            round: 9,
            members: vec![1, 2, u64::MAX],
            average: true,
        },
        Directive::Evict { member: 6 },
        Directive::Finish,
    ] {
        let text = d.to_json().dump();
        let back = Directive::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, d, "through {text}");
    }

    // config + observable state
    let cfg = tick_cfg();
    let back = DistConfig::from_json(&Json::parse(&cfg.to_json().dump()).unwrap()).unwrap();
    assert_eq!(back, cfg);
    let st = drive_to(DistPhase::Sync).state();
    let back = CoordinatorState::from_json(&Json::parse(&st.to_json().dump()).unwrap()).unwrap();
    assert_eq!(back, st);
}

// ======================================================================
// end-to-end runs through the in-process backend
// ======================================================================

/// A synthetic spec the serial Session and the distributed driver both
/// accept: small order-3 tensor, deterministic CPU reference backend.
fn base_spec(nnz: usize, epochs: usize) -> RunSpec {
    RunSpec {
        data: DataSource::Synth(SynthSpec {
            preset: SynthPreset::Order,
            order: 3,
            dim: 24,
            nnz,
            seed: 11,
        }),
        train: TrainConfig {
            backend: Backend::CpuRef,
            ..TrainConfig::default()
        },
        schedule: Schedule {
            epochs,
            eval_every: 0,
            test_frac: 0.0,
            ..Schedule::default()
        },
        metrics: None,
    }
}

fn assert_models_bit_identical(a: &TuckerModel, b: &TuckerModel) {
    assert_eq!(a.dims, b.dims);
    assert_eq!((a.j, a.r), (b.j, b.r));
    for (n, (fa, fb)) in a.factors.iter().zip(&b.factors).enumerate() {
        assert!(
            fa.iter().zip(fb).all(|(x, y)| x.to_bits() == y.to_bits()),
            "factor {n} differs"
        );
    }
    for (n, (ca, cb)) in a.cores.iter().zip(&b.cores).enumerate() {
        assert!(
            ca.iter().zip(cb).all(|(x, y)| x.to_bits() == y.to_bits()),
            "core {n} differs"
        );
    }
}

#[test]
fn one_worker_run_matches_serial_bytes() {
    let mut spec = base_spec(2_000, 3);

    let mut session = Session::from_spec(&spec).unwrap();
    session.run(&mut NullObserver).unwrap();
    let serial = session.trainer_mut().model.clone();

    spec.train.workers = 1;
    let run = run_local(&spec, &mut NullObserver).unwrap();
    assert_eq!(run.final_state.phase, DistPhase::Done);
    assert_eq!(run.report.epochs_run, 3);
    assert_models_bit_identical(&serial, &run.model);

    // ... and the saved FTM1 checkpoints match byte for byte (the CI
    // dist-smoke job `cmp`-checks the same thing end to end via the CLI)
    let dir = std::env::temp_dir().join("ft_dist_parity_test");
    std::fs::create_dir_all(&dir).unwrap();
    let (pa, pb) = (dir.join("serial.ftm"), dir.join("dist.ftm"));
    serial.save(&pa).unwrap();
    run.model.save(&pb).unwrap();
    let (ba, bb) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    assert!(ba == bb, "FTM1 files differ ({} vs {} bytes)", ba.len(), bb.len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn four_workers_reach_serial_plateau() {
    let mut spec = base_spec(4_000, 5);
    spec.schedule.eval_every = 1;
    spec.schedule.test_frac = 0.25;

    let mut session = Session::from_spec(&spec).unwrap();
    let serial_rmse = session.run(&mut NullObserver).unwrap().final_rmse.unwrap();

    spec.train.workers = 4;
    let run = run_local(&spec, &mut NullObserver).unwrap();
    let dist_rmse = run.report.final_rmse.unwrap();
    let init_rmse = run.report.history[0].rmse.unwrap();

    assert!(
        dist_rmse < init_rmse,
        "dist run never improved: {dist_rmse} vs init {init_rmse}"
    );
    // Tolerance: barrier averaging is a different optimization trajectory
    // from the serial pass (each worker sees 1/4 of the entries per
    // round), so the runs plateau near — not at — the same RMSE.  25%
    // relative headroom is far above the observed gap and far below the
    // init RMSE, so it catches divergence without flaking.
    assert!(
        (dist_rmse - serial_rmse).abs() <= 0.25 * serial_rmse,
        "dist rmse {dist_rmse} strays from serial {serial_rmse}"
    );
}

/// Records every coordinator state the driver surfaces through
/// [`Observer::on_round`].
#[derive(Default)]
struct StateTrace {
    states: Vec<CoordinatorState>,
}

impl Observer for StateTrace {
    fn on_round(&mut self, state: &CoordinatorState) {
        self.states.push(state.clone());
    }
}

#[test]
fn fault_injection_recovers() {
    let mut spec = base_spec(3_000, 4);
    spec.schedule.eval_every = 1;
    spec.schedule.test_frac = 0.25;

    let mut session = Session::from_spec(&spec).unwrap();
    let serial_rmse = session.run(&mut NullObserver).unwrap().final_rmse.unwrap();

    // worker index 2 (member 3) dies silently partway through round 1:
    // no StepComplete, heartbeats stop
    spec.train.workers = 3;
    let opts = LocalOpts {
        fault: Some(FaultSpec {
            member_index: 2,
            round: 1,
        }),
    };
    let mut trace = StateTrace::default();
    let run = run_local_with(&spec, &opts, &mut trace).unwrap();

    // the run completed every round despite losing a worker mid-epoch
    assert_eq!(run.final_state.phase, DistPhase::Done);
    assert_eq!(run.report.epochs_run, 4);
    assert_eq!(run.final_state.members, vec![1, 2], "member 3 was not evicted");
    assert!(
        trace.states.iter().any(|s| s.members.len() == 3),
        "all three members should appear before the fault"
    );
    assert!(
        trace.states.iter().any(|s| s.members.len() == 2),
        "the eviction should surface through on_round"
    );

    // quality: the survivors still converge to the serial plateau.
    // Tolerance: member 3's round-1 updates (1/3 of that round's entries)
    // are lost outright and the remaining rounds re-deal over two members,
    // so this trajectory strays further than the no-fault run — 35%
    // relative headroom bounds the damage without flaking.
    let dist_rmse = run.report.final_rmse.unwrap();
    let init_rmse = run.report.history[0].rmse.unwrap();
    assert!(dist_rmse < init_rmse, "faulted run never improved");
    assert!(
        (dist_rmse - serial_rmse).abs() <= 0.35 * serial_rmse,
        "faulted rmse {dist_rmse} strays from serial {serial_rmse}"
    );
}

// ======================================================================
// telemetry: passivity and the flight recorder
// ======================================================================

/// Telemetry is strictly passive: the same 1-worker spec with and
/// without a metrics sink produces a bit-identical model (and the
/// 1-worker run is already pinned byte-for-byte against serial above,
/// so this transitively pins the serial trajectory too).
#[test]
fn dist_metrics_are_passive_and_the_file_is_well_formed() {
    let dir = std::env::temp_dir().join("ft_dist_metrics_passive");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.jsonl");

    let mut spec = base_spec(2_000, 3);
    spec.train.workers = 1;
    let plain = run_local(&spec, &mut NullObserver).unwrap();

    spec.metrics = Some(path.clone());
    let observed = run_local(&spec, &mut NullObserver).unwrap();

    assert_models_bit_identical(&plain.model, &observed.model);
    assert_eq!(plain.report.epochs_run, observed.report.epochs_run);

    // every line parses, kinds are from the known set, and both the
    // snapshot and the flight tape made it to disk
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("line parses"))
        .collect();
    assert!(!lines.is_empty());
    let kind = |j: &Json| j.get("kind").and_then(|k| k.as_str()).unwrap().to_string();
    assert!(lines.iter().all(|l| {
        matches!(kind(l).as_str(), "metrics" | "flight_head" | "flight")
    }));
    assert!(lines.iter().any(|l| kind(l) == "metrics"));
    assert!(lines.iter().filter(|l| kind(l) == "flight_head").count() == 1);
    assert!(lines.iter().any(|l| kind(l) == "flight"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance scenario: a 4-worker fault-injection run with
/// `--metrics` dumps a flight tape whose directives include the Evict
/// of the killed worker, and whose counters saw the eviction.
#[test]
fn fault_injection_writes_flight_tape_with_the_evict() {
    let dir = std::env::temp_dir().join("ft_dist_flight_tape");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.jsonl");

    let mut spec = base_spec(3_000, 3);
    spec.train.workers = 4;
    spec.metrics = Some(path.clone());
    // worker index 3 = member 4 dies silently in round 1
    let opts = LocalOpts {
        fault: Some(FaultSpec {
            member_index: 3,
            round: 1,
        }),
    };
    let run = run_local_with(&spec, &opts, &mut NullObserver).unwrap();
    assert_eq!(run.final_state.phase, DistPhase::Done);
    assert!(!run.final_state.members.contains(&4), "member 4 survived");

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("line parses"))
        .collect();

    // the tape holds the Evict directive for the member that died
    let evicts: Vec<u64> = lines
        .iter()
        .filter(|l| l.get("kind").and_then(|k| k.as_str()) == Some("flight"))
        .filter(|l| l.get("role").and_then(|r| r.as_str()) == Some("directive"))
        .filter_map(|l| l.get("body"))
        .filter(|b| b.get("kind").and_then(|k| k.as_str()) == Some("evict"))
        .filter_map(|b| b.get("member").and_then(|m| m.as_f64()))
        .map(|m| m as u64)
        .collect();
    assert!(
        evicts.contains(&4),
        "no Evict for member 4 on the flight tape: {evicts:?}"
    );

    // heartbeats and the protocol's happy-path messages are on tape too
    let has = |role: &str, k: &str| {
        lines.iter().any(|l| {
            l.get("role").and_then(|r| r.as_str()) == Some(role)
                && l.get("body").and_then(|b| b.get("kind")).and_then(|x| x.as_str()) == Some(k)
        })
    };
    assert!(has("event", "heartbeat"));
    assert!(has("event", "step_complete"));
    assert!(has("directive", "begin_round"));

    // the final registry snapshot counted the eviction and the rounds
    let snap = lines
        .iter()
        .find(|l| l.get("kind").and_then(|k| k.as_str()) == Some("metrics"))
        .expect("a metrics snapshot line");
    let counter = |name: &str| {
        snap.get("counters")
            .and_then(|c| c.get(name))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    assert!(counter("dist.evictions") >= 1.0);
    assert!(counter("dist.ticks") > 0.0);
    assert!(counter("dist.heartbeats") > 0.0);
    assert!(counter("dist.rounds") >= 3.0);
    let barrier_count = snap
        .get("hists")
        .and_then(|h| h.get("dist.barrier_ns"))
        .and_then(|h| h.get("count"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    assert!(barrier_count >= 3.0, "barrier hist recorded {barrier_count}");
    let _ = std::fs::remove_dir_all(&dir);
}
