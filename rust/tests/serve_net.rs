//! Network serving tier acceptance suite.
//!
//! Pins the load-bearing guarantees of `serve::net` over real loopback
//! sockets:
//!
//! 1. a prediction answered over the wire is **bit-identical** to the
//!    in-process [`Engine::predict`] on the same snapshot (and top-K
//!    agrees index-for-index, score bits included);
//! 2. promote / rollback are atomic under concurrent queries — every
//!    answer matches exactly one registered version, never a torn mix,
//!    and the completion cache replays bit-identical fibers across the
//!    generation change;
//! 3. admission control and deadlines degrade loudly: a slow handler
//!    makes over-bound frames come back `Overloaded` and expired frames
//!    `DeadlineExceeded` — never silence, never a corrupted neighbor
//!    frame (every id is answered exactly once on the right connection);
//! 4. graceful drain answers every accepted request before the server
//!    exits — a pipelined burst followed by `shutdown` yields every
//!    response plus the stopping ack, then EOF;
//! 5. `stats` round-trips the server's metrics registry over the wire.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use fasttucker::coordinator::Algo;
use fasttucker::model::TuckerModel;
use fasttucker::serve::net::{NetConfig, NetHandler, NetResponse, NetServer};
use fasttucker::serve::{
    mode_topk, Engine, ModelSnapshot, NetClient, Registry, Request, Response,
};
use fasttucker::util::rng::Pcg32;

mod common;

const DIMS: [u32; 3] = [19, 13, 11];

fn snap(seed: u64, epoch: u64) -> ModelSnapshot {
    let model = TuckerModel::init(&DIMS, 16, 16, seed);
    ModelSnapshot::from_model(&model, Algo::Plus, epoch)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("ft_serve_net_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Spin up a registry-backed server on an ephemeral loopback port.
fn start_server(cfg: NetConfig) -> (NetServer, std::sync::Arc<Registry>, String) {
    let registry = Registry::shared();
    let server = NetServer::bind("127.0.0.1:0", registry.clone(), cfg).unwrap();
    let addr = server.local_addr().to_string();
    (server, registry, addr)
}

/// Acceptance criterion: the wire path (engine → f32 → JSON → f32) is
/// bit-identical to calling [`Engine::predict`] in process, and top-K
/// survives the trip index-for-index with score bits intact.
#[test]
fn wire_predictions_bit_identical_to_engine() {
    let cfg = NetConfig::default();
    let (server, registry, addr) = start_server(cfg);
    let s = snap(0xF1DE, 4);
    registry.publish("main", s.clone());
    let mut engine = Engine::with_policy(s, cfg.policy);

    let mut client = NetClient::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rng = Pcg32::new(77, 0xB17);
    for _ in 0..200 {
        let coords: Vec<u32> = DIMS.iter().map(|&d| rng.gen_range(d)).collect();
        let over_wire = client.predict(Some("main"), &coords).unwrap();
        let in_process = engine.predict(&coords);
        assert_eq!(
            over_wire.to_bits(),
            in_process.to_bits(),
            "wire prediction diverged at {coords:?}: {over_wire} vs {in_process}"
        );
    }

    // top-K over the wire == mode_topk in process (the cache is empty on
    // the first call and warm on the second; both must match exactly)
    for round in 0..2 {
        let coords = vec![3, 0, 7];
        let expect = mode_topk(&mut engine, &coords, 1, 5);
        match client
            .call(Some("main"), None, Request::TopK { coords, mode: 1, k: 5 })
            .unwrap()
        {
            Response::TopK(got) => {
                assert_eq!(got.len(), expect.len());
                for (g, e) in got.iter().zip(&expect) {
                    assert_eq!(g.index, e.index, "round {round}");
                    assert_eq!(g.score.to_bits(), e.score.to_bits(), "round {round}");
                }
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }

    // the second top-K hit the completion cache
    let stats = server.metrics_snapshot();
    assert!(
        stats.counters.get("serve.cache.hits").copied().unwrap_or(0) >= 1,
        "warm top-K should hit the fiber cache: {:?}",
        stats.counters
    );
    server.shutdown();
}

/// Promote / rollback flip the answering snapshot atomically: under a
/// storm of concurrent queries, every epoch and every prediction matches
/// exactly one of the two registered versions — no torn reads, no stale
/// errors — and after rollback the original version answers again.
#[test]
fn promote_rollback_atomic_under_concurrent_queries() {
    let (server, registry, addr) = start_server(NetConfig {
        workers: 4,
        ..NetConfig::default()
    });
    let s1 = snap(0xAAA, 1);
    let s2 = snap(0xBBB, 2);
    registry.insert("main", s1.clone()); // v1 activates (first version)
    registry.insert("main", s2.clone()); // v2 staged
    let coords = vec![5, 6, 7];
    let v1 = Engine::new(s1).predict(&coords);
    let v2 = Engine::new(s2).predict(&coords);
    assert_ne!(v1.to_bits(), v2.to_bits(), "seeds must give distinct models");

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let addr = &addr;
                let coords = &coords;
                let stop = &stop;
                scope.spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    client
                        .set_read_timeout(Some(Duration::from_secs(30)))
                        .unwrap();
                    let mut checked = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let p = client.predict(Some("main"), coords).unwrap();
                        assert!(
                            p.to_bits() == v1.to_bits() || p.to_bits() == v2.to_bits(),
                            "torn or stale prediction {p}: not v1 ({v1}) or v2 ({v2})"
                        );
                        match client.call(Some("main"), None, Request::Epoch).unwrap() {
                            Response::Epoch(e) => {
                                assert!(e == 1 || e == 2, "epoch {e} is neither version")
                            }
                            other => panic!("unexpected reply {other:?}"),
                        }
                        checked += 1;
                    }
                    checked
                })
            })
            .collect();

        // flip versions under the readers
        let mut admin = NetClient::connect(&addr).unwrap();
        admin.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        for _ in 0..10 {
            let listing = admin.promote("main", None).unwrap();
            assert_eq!(listing[0].active, 2);
            assert_eq!(listing[0].previous, Some(1));
            let listing = admin.rollback("main").unwrap();
            assert_eq!(listing[0].active, 1);
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(total > 0, "readers never got a query through");
    });

    // rolled back: v1 answers again, over the wire
    let mut client = NetClient::connect(&addr).unwrap();
    assert_eq!(
        client.predict(Some("main"), &coords).unwrap().to_bits(),
        v1.to_bits()
    );
    server.shutdown();
}

/// Registry lifecycle over the wire: a checkpoint saved to disk is
/// loadable as a staged version via `load`, `list` reflects it, and
/// promoting by explicit version activates it.
#[test]
fn load_and_promote_checkpoint_over_wire() {
    let (server, registry, addr) = start_server(NetConfig::default());
    registry.publish("main", snap(0x111, 7));
    let staged = snap(0x222, 9);
    let path = tmp("staged.ftck");
    staged.save(&path).unwrap();

    let mut client = NetClient::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let listing = client.load("main", path.to_str().unwrap()).unwrap();
    assert_eq!(listing[0].versions, vec![1, 2]);
    assert_eq!(listing[0].active, 1, "load stages, it must not activate");

    let listing = client.promote("main", Some(2)).unwrap();
    assert_eq!(listing[0].active, 2);
    match client.call(Some("main"), None, Request::Epoch).unwrap() {
        Response::Epoch(e) => assert_eq!(e, 9, "the staged checkpoint answers"),
        other => panic!("unexpected reply {other:?}"),
    }

    // loading a garbage path fails loudly and changes nothing
    let err = client.load("main", "/nonexistent/nope.ftck").unwrap_err();
    assert!(format!("{err:#}").contains("bad_request"), "{err:#}");
    assert_eq!(client.list().unwrap()[0].versions, vec![1, 2]);
    server.shutdown();
}

/// A deliberately slow handler pins the overload story: frames beyond
/// the admission bound come back `Overloaded`, frames that expire in the
/// queue come back `DeadlineExceeded`, every single id is answered
/// exactly once, and a second connection's frames are never corrupted by
/// the shed traffic racing the slow completions.
#[test]
fn slow_handler_sheds_expires_and_never_corrupts_framing() {
    struct SlowHandler;
    impl NetHandler for SlowHandler {
        fn call(&mut self, _model: Option<&str>, _req: &Request) -> Response {
            std::thread::sleep(Duration::from_millis(30));
            Response::Predict(1.0)
        }
    }
    let server = NetServer::bind_with_handler(
        "127.0.0.1:0",
        NetConfig {
            workers: 1,
            max_pending: 2,
            ..NetConfig::default()
        },
        || Box::new(SlowHandler) as Box<dyn NetHandler>,
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    const BURST: usize = 12;
    let run_conn = || {
        let mut client = NetClient::connect(&addr).unwrap();
        client
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        // pipeline the burst: a 20 ms deadline with a 30 ms handler means
        // anything queued behind one job has already expired at pop time
        let ids: Vec<u64> = (0..BURST)
            .map(|_| {
                client
                    .send(None, Some(20), Request::Predict { coords: vec![1, 2, 3] })
                    .unwrap()
            })
            .collect();
        let mut answered: HashMap<u64, &'static str> = HashMap::new();
        for _ in 0..BURST {
            let frame = client.recv().unwrap();
            let (id, kind) = match frame {
                NetResponse::Call {
                    id,
                    resp: Response::Predict(v),
                } => {
                    assert_eq!(v.to_bits(), 1.0f32.to_bits());
                    (id, "ok")
                }
                NetResponse::Failure { id, code, .. } => (
                    id,
                    match code.as_str() {
                        "overloaded" => "shed",
                        "deadline" => "expired",
                        other => panic!("unexpected error code {other:?}"),
                    },
                ),
                other => panic!("unexpected frame {other:?}"),
            };
            assert!(
                answered.insert(id, kind).is_none(),
                "id {id} answered twice"
            );
        }
        let sent: HashSet<u64> = ids.iter().copied().collect();
        let got: HashSet<u64> = answered.keys().copied().collect();
        assert_eq!(sent, got, "every sent id answered exactly once, no others");
        answered
    };

    // two connections burst concurrently: sheds and slow completions
    // interleave on the wire, framing must survive on both
    let (a, b) = std::thread::scope(|scope| {
        let ta = scope.spawn(run_conn);
        let tb = scope.spawn(run_conn);
        (ta.join().unwrap(), tb.join().unwrap())
    });
    let count = |m: &HashMap<u64, &str>, k: &str| m.values().filter(|v| **v == k).count();
    let shed = count(&a, "shed") + count(&b, "shed");
    let expired = count(&a, "expired") + count(&b, "expired");
    let ok = count(&a, "ok") + count(&b, "ok");
    assert!(shed > 0, "burst of {BURST}x2 over a 2-deep queue must shed");
    assert!(expired > 0, "a 20 ms deadline behind a 30 ms job must expire");
    assert!(ok > 0, "some requests must still succeed");

    let stats = server.shutdown();
    assert_eq!(stats.shed, shed as u64);
    assert_eq!(stats.deadline_missed, expired as u64);
}

/// Graceful drain: a pipelined burst followed by `shutdown` on the same
/// connection yields every single response plus the stopping ack, then a
/// clean EOF — no accepted request is ever dropped (regression pin).
#[test]
fn drain_answers_every_accepted_request() {
    let (server, registry, addr) = start_server(NetConfig {
        workers: 2,
        ..NetConfig::default()
    });
    registry.publish("main", snap(0xD0D0, 3));

    let mut client = NetClient::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    const BURST: usize = 40;
    let mut pending: HashSet<u64> = (0..BURST)
        .map(|_| {
            client
                .send(Some("main"), None, Request::Predict { coords: vec![1, 2, 3] })
                .unwrap()
        })
        .collect();
    // the shutdown frame races the workers; everything sent before it
    // was accepted and must still be answered
    client.send_shutdown().unwrap();

    let mut stopped = false;
    while !pending.is_empty() || !stopped {
        match client.recv().unwrap() {
            NetResponse::Call {
                id,
                resp: Response::Predict(_),
            } => {
                assert!(pending.remove(&id), "unknown or duplicate id {id}");
            }
            NetResponse::Stopping { .. } => stopped = true,
            other => panic!("unexpected frame {other:?}"),
        }
    }
    // after the drain the server closes the socket: clean EOF
    let eof = client.recv().unwrap_err();
    assert!(
        format!("{eof:#}").contains("closed"),
        "expected EOF after drain, got {eof:#}"
    );

    // the poll thread exits on its own (no external stop() needed)
    let t0 = std::time::Instant::now();
    while !server.drained() {
        assert!(t0.elapsed() < Duration::from_secs(30), "drain never finished");
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, BURST as u64);
    assert_eq!(stats.shed, 0);
}

/// `stats` round-trips the server's own metrics registry over the wire:
/// after traffic, the snapshot a client receives carries the serve.net
/// counters and latency histograms.
#[test]
fn stats_round_trip_over_wire() {
    let (server, registry, addr) = start_server(NetConfig::default());
    registry.publish("main", snap(0x57A7, 5));

    let mut client = NetClient::connect(&addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for _ in 0..7 {
        client.predict(Some("main"), &[1, 2, 3]).unwrap();
    }
    let snap = match client.call(None, None, Request::Stats).unwrap() {
        Response::Stats(s) => s,
        other => panic!("unexpected reply {other:?}"),
    };
    assert!(snap.counters.get("serve.net.requests").copied().unwrap_or(0) >= 7);
    assert!(snap.counters.get("serve.net.connections").copied().unwrap_or(0) >= 1);
    let lat = snap
        .hists
        .get("serve.net.latency.predict")
        .expect("predict latency histogram present");
    assert!(lat.count() >= 7, "histogram count {} < 7", lat.count());
    // the wire snapshot is the server's own snapshot, not a facsimile
    let direct = server.metrics_snapshot();
    assert!(
        direct.counters.get("serve.net.requests").copied().unwrap_or(0)
            >= snap.counters["serve.net.requests"],
        "server-side counters can only have moved forward"
    );
    server.shutdown();
}

/// Hardening pin: adversarial frames — garbage between valid frames,
/// truncated lines, an oversized `k`, integers beyond 2^53, non-finite
/// values — come back as loud `bad_request` errors (or a dropped
/// connection for unbounded input), never a panic, and never corrupt a
/// neighboring frame: a bit-exact predict still answers right after
/// every piece of garbage, on the same connection.
#[test]
fn adversarial_frames_never_corrupt_the_wire() {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{Shutdown, TcpStream};

    use fasttucker::serve::net::wire;
    use fasttucker::util::json::Json;

    fn read_frame(reader: &mut BufReader<TcpStream>) -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "server hung up unexpectedly");
        Json::parse(line.trim()).expect("server emitted invalid JSON")
    }
    fn op_of(j: &Json) -> String {
        j.get("op").and_then(Json::as_str).unwrap_or("?").to_string()
    }

    let cfg = NetConfig::default();
    let (server, registry, addr) = start_server(cfg);
    let s = snap(0xBAD, 6);
    registry.publish("main", s.clone());
    let mut engine = Engine::with_policy(s, cfg.policy);
    let coords = [3u32, 4, 5];
    let expect = engine.predict(&coords);

    let sock = TcpStream::connect(&addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());

    // garbage between valid frames: each hostile line earns exactly one
    // bad_request, and the pipelined predict right behind it still
    // answers with the engine's exact bits
    let mut id = 0u64;
    for frame in common::malformed_control_frames() {
        if frame.is_empty() || frame.len() > 1 << 20 {
            continue; // the hangup / oversize cases get their own connections below
        }
        (&sock).write_all(&frame).unwrap();
        id += 1;
        let req =
            format!("{{\"id\":{id},\"op\":\"predict\",\"model\":\"main\",\"coords\":[3,4,5]}}\n");
        (&sock).write_all(req.as_bytes()).unwrap();
        let (mut got_err, mut got_val) = (false, false);
        for _ in 0..2 {
            let j = read_frame(&mut reader);
            match op_of(&j).as_str() {
                "error" => {
                    assert_eq!(
                        j.get("code").and_then(Json::as_str),
                        Some("bad_request"),
                        "garbage must be a bad_request: {j:?}"
                    );
                    got_err = true;
                }
                "predict" => {
                    assert_eq!(j.get("id").and_then(Json::as_usize), Some(id as usize));
                    let v = j.get("value").and_then(Json::as_f64).unwrap() as f32;
                    assert_eq!(
                        v.to_bits(),
                        expect.to_bits(),
                        "prediction corrupted by preceding garbage"
                    );
                    got_val = true;
                }
                other => panic!("unexpected frame op {other:?}: {j:?}"),
            }
        }
        assert!(got_err && got_val, "garbage frame swallowed a reply");
    }

    // validation failures at decode: a k beyond u32 and a coordinate
    // beyond 2^53 are both unsatisfiable and rejected loudly
    for bad in [
        r#"{"id":90,"op":"topk","model":"main","coords":[3,4,5],"mode":1,"k":4294967296}"#,
        r#"{"id":91,"op":"predict","model":"main","coords":[9007199254740993]}"#,
    ] {
        (&sock).write_all(format!("{bad}\n").as_bytes()).unwrap();
        let j = read_frame(&mut reader);
        assert_eq!(op_of(&j), "error", "{bad} must be rejected: {j:?}");
        assert_eq!(j.get("code").and_then(Json::as_str), Some("bad_request"));
    }

    // an id beyond 2^53 still answers (f64-rounded) — documented client
    // contract is id < 2^53, but violating it must never panic or wedge
    (&sock)
        .write_all(b"{\"id\":9007199254740994,\"op\":\"epoch\",\"model\":\"main\"}\n")
        .unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(op_of(&j), "epoch", "huge-id frame must still answer: {j:?}");

    // non-finite floats encode as null (valid JSON) and fail decoding
    // loudly on the client side — never an invalid frame on the wire
    let nan_frame = wire::response_frame(7, &fasttucker::serve::Response::Predict(f32::NAN));
    assert!(Json::parse(&nan_frame).is_ok(), "NaN frame must stay valid JSON");
    assert!(
        wire::parse_response(&nan_frame).is_err(),
        "a null value must fail decoding loudly"
    );

    // an unterminated frame over the bound drops that connection only
    let sock2 = TcpStream::connect(&addr).unwrap();
    sock2.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let big = vec![b'x'; 2 << 20];
    let _ = (&sock2).write_all(&big);
    let mut sink = Vec::new();
    match sock2.try_clone().unwrap().read_to_end(&mut sink) {
        Ok(_) => {}
        Err(e) => assert!(
            !matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ),
            "oversize frame wedged the server: {e}"
        ),
    }
    assert!(sink.is_empty(), "an oversize frame must never be answered");

    // a line truncated by a hangup is discarded, not parsed
    let sock3 = TcpStream::connect(&addr).unwrap();
    sock3.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    (&sock3).write_all(b"{\"id\":1,\"op\":\"pre").unwrap();
    sock3.shutdown(Shutdown::Write).unwrap();
    sink.clear();
    let _ = sock3.try_clone().unwrap().read_to_end(&mut sink);
    assert!(sink.is_empty(), "a truncated line must never be answered");

    // the original connection and a fresh client both still answer
    // bit-exactly: nothing above touched the shared state
    id += 1;
    let req = format!("{{\"id\":{id},\"op\":\"predict\",\"model\":\"main\",\"coords\":[3,4,5]}}\n");
    (&sock).write_all(req.as_bytes()).unwrap();
    let j = read_frame(&mut reader);
    assert_eq!(op_of(&j), "predict");
    let v = j.get("value").and_then(Json::as_f64).unwrap() as f32;
    assert_eq!(v.to_bits(), expect.to_bits());
    let mut client = NetClient::connect(&addr).unwrap();
    let fresh = client.predict(Some("main"), &coords).unwrap();
    assert_eq!(fresh.to_bits(), expect.to_bits());
    server.shutdown();
}
