//! Golden reference-trajectory harness: the full (epoch, RMSE, MAE)
//! trajectory of a small fixed run, pinned bit for bit against a
//! committed fixture — so any change to the numerics (sampler order,
//! gradient math, averaging, evaluation) is caught as a diff, not a
//! silent drift.
//!
//! The fixture lives at `tests/data/reference_trajectory.txt` and stores
//! one trajectory per CPU kernel policy (`scalar` — the paper-faithful
//! oracle — and `tiled` — the production microkernels), plus an FNV-1a
//! hash of the input tensor's bytes so a changed synthetic generator
//! fails loudly instead of producing a confusing trajectory mismatch.
//!
//! Self-capture flow: a fixture whose first line is `# PENDING` puts the
//! test in capture mode — it verifies each policy replays *itself*
//! bit-identically (two runs, same bits), writes the real fixture, and
//! passes; the captured file is then committed and every later run
//! replays against it exactly.  A capture run pins nothing across
//! commits, so CI refuses to stay green on one: a dedicated workflow
//! step fails whenever this test rewrote the fixture, printing the
//! captured file for a maintainer to commit verbatim.

use fasttucker::coordinator::{Backend, TrainConfig};
use fasttucker::kernel::KernelPolicy;
use fasttucker::session::{DataSource, Recorder, RunSpec, Schedule, Session, SynthPreset, SynthSpec};
use fasttucker::synth::{generate, SynthConfig};
use fasttucker::tensor::SparseTensor;
use fasttucker::util::fnv::{FNV_OFFSET, FNV_PRIME};

/// Fixture path, anchored at the workspace root (`CARGO_MANIFEST_DIR`
/// is the repo root — the package manifest lives there, with the test
/// roots routed to `rust/tests/` — so the path must carry the `rust/`
/// prefix; stable under `cargo test` from any working directory).
const FIXTURE: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/rust/tests/data/reference_trajectory.txt"
);

// The reference recipe.  Changing any of these invalidates the committed
// fixture — re-capture by resetting the file to `# PENDING`.
const ORDER: usize = 3;
const DIM: u32 = 32;
const NNZ: usize = 1_500;
const DATA_SEED: u64 = 23;
const EPOCHS: usize = 6;
const TEST_FRAC: f64 = 0.25;

/// FNV-1a over the tensor's structure and payload: dims, nnz, then every
/// entry's coordinates and value bits in storage order.
fn input_hash(t: &SparseTensor) -> u64 {
    fn mix(h: &mut u64, x: u64) {
        *h ^= x;
        *h = h.wrapping_mul(FNV_PRIME);
    }
    let mut h = FNV_OFFSET;
    for &d in &t.dims {
        mix(&mut h, d as u64);
    }
    mix(&mut h, t.values.len() as u64);
    for e in 0..t.values.len() {
        for &c in t.coords(e) {
            mix(&mut h, c as u64);
        }
        mix(&mut h, t.values[e].to_bits() as u64);
    }
    h
}

fn reference_spec(policy: KernelPolicy) -> RunSpec {
    RunSpec {
        data: DataSource::Synth(SynthSpec {
            preset: SynthPreset::Order,
            order: ORDER,
            dim: DIM,
            nnz: NNZ,
            seed: DATA_SEED,
        }),
        train: TrainConfig {
            backend: Backend::CpuRef,
            cpu_kernel: policy,
            ..TrainConfig::default()
        },
        schedule: Schedule {
            epochs: EPOCHS,
            eval_every: 1,
            test_frac: TEST_FRAC,
            ..Schedule::default()
        },
        metrics: None,
    }
}

/// One full run: every evaluated `(epoch, rmse bits, mae bits)` row,
/// including the epoch-0 random-init evaluation.
fn trajectory(policy: KernelPolicy) -> Vec<(usize, u64, u64)> {
    let spec = reference_spec(policy);
    let mut session = Session::from_spec(&spec).unwrap();
    let mut rec = Recorder::default();
    session.run(&mut rec).unwrap();
    assert_eq!(rec.events.len(), EPOCHS + 1, "init eval + one row per epoch");
    rec.events
        .iter()
        .map(|e| {
            (
                e.epoch,
                e.rmse.expect("eval_every=1 evaluates every epoch").to_bits(),
                e.mae.expect("eval_every=1 evaluates every epoch").to_bits(),
            )
        })
        .collect()
}

const POLICIES: [(&str, KernelPolicy); 2] = [
    ("scalar", KernelPolicy::Scalar),
    ("tiled", KernelPolicy::Tiled),
];

fn render_fixture(hash: u64, runs: &[(&str, Vec<(usize, u64, u64)>)]) -> String {
    let mut out = String::from("# fasttucker reference trajectory v1\n");
    out.push_str(&format!("# input fnv1a: {hash:016x}\n"));
    for (name, rows) in runs {
        out.push_str(&format!("# policy {name}\n"));
        for (epoch, rmse, mae) in rows {
            out.push_str(&format!("{epoch} {rmse:016x} {mae:016x}\n"));
        }
    }
    out
}

/// Parse the committed fixture: `(input hash, policy name -> rows)`.
fn parse_fixture(text: &str) -> (u64, Vec<(String, Vec<(usize, u64, u64)>)>) {
    let mut lines = text.lines();
    assert_eq!(
        lines.next(),
        Some("# fasttucker reference trajectory v1"),
        "unknown fixture header"
    );
    let hash_line = lines.next().expect("missing input-hash line");
    let hash_hex = hash_line
        .strip_prefix("# input fnv1a: ")
        .expect("malformed input-hash line");
    let hash = u64::from_str_radix(hash_hex, 16).expect("bad input hash hex");
    let mut runs: Vec<(String, Vec<(usize, u64, u64)>)> = Vec::new();
    for line in lines {
        if let Some(name) = line.strip_prefix("# policy ") {
            runs.push((name.to_string(), Vec::new()));
            continue;
        }
        let mut parts = line.split_whitespace();
        let epoch: usize = parts.next().unwrap().parse().expect("bad epoch");
        let rmse = u64::from_str_radix(parts.next().expect("missing rmse"), 16).unwrap();
        let mae = u64::from_str_radix(parts.next().expect("missing mae"), 16).unwrap();
        runs.last_mut()
            .expect("trajectory row before any `# policy` line")
            .1
            .push((epoch, rmse, mae));
    }
    (hash, runs)
}

#[test]
fn reference_trajectory_replays_bit_identically() {
    let tensor = generate(&SynthConfig::order_sweep(ORDER, DIM, NNZ, DATA_SEED));
    let hash = input_hash(&tensor);

    let text = std::fs::read_to_string(FIXTURE)
        .unwrap_or_else(|e| panic!("fixture {FIXTURE} unreadable: {e}"));

    if text.starts_with("# PENDING") {
        // Capture mode: prove each policy is deterministic (a flaky
        // trajectory must never become the golden one), then write the
        // real fixture for the committer to check in.
        let mut runs: Vec<(&str, Vec<(usize, u64, u64)>)> = Vec::new();
        for (name, policy) in POLICIES {
            let a = trajectory(policy);
            let b = trajectory(policy);
            assert_eq!(a, b, "policy {name} did not replay bit-identically");
            runs.push((name, a));
        }
        std::fs::write(FIXTURE, render_fixture(hash, &runs)).unwrap();
        eprintln!("reference_trajectory: fixture captured at {FIXTURE}; commit it");
        return;
    }

    // Replay mode: the committed trajectory must reproduce exactly.
    let (want_hash, want_runs) = parse_fixture(&text);
    assert_eq!(
        hash, want_hash,
        "input tensor changed (synthetic generator drift?) — \
         reset the fixture to `# PENDING` to re-capture"
    );
    assert_eq!(want_runs.len(), POLICIES.len(), "fixture policy count");
    for (name, policy) in POLICIES {
        let want = &want_runs
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("fixture has no `# policy {name}` section"))
            .1;
        let got = trajectory(policy);
        assert_eq!(
            &got, want,
            "policy {name}: trajectory diverged from the committed reference \
             (bit-level RMSE/MAE mismatch)"
        );
    }
}
